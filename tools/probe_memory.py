import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Bisect per-device temp memory: forward | grad | grad+opt."""
import sys
import jax
import jax.numpy as jnp

from repro.config import CELLS
from repro.configs import get_config, input_specs
from repro.core import apply_updates
from repro.distributed import sharding as SH
from repro.launch.dryrun import dryrun_optimizer, microbatches_for
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.train.steps import TrainState, build_train_step

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-7b"
what = sys.argv[2] if len(sys.argv) > 2 else "all"

cfg = get_config(arch)
cell = CELLS["train_4k"]
mesh = make_production_mesh()
model = build_model(cfg, mesh)
model.constrain = SH.make_act_constrainer(mesh, "train")
params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
pshard = SH.param_shardings(model, mesh, "train")
pspecs = SH.param_pspecs(model, mesh, "train")
batch_struct = input_specs(cfg, cell)
bshard = SH.batch_shardings(cfg, "train", mesh, batch_struct)
mb = microbatches_for(arch, "train_4k")


def report(name, fn, *structs):
    co = fn.lower(*structs).compile()
    m = co.memory_analysis()
    print(f"{name:12s} temp={m.temp_size_in_bytes/2**30:7.2f} GiB  "
          f"args={m.argument_size_in_bytes/2**30:6.2f} GiB", flush=True)


if what in ("fwd", "all"):
    def fwd(params, batch):
        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
        micro = jax.tree.map(split, batch)
        def body(c, b):
            l, _ = model.loss(params, b)
            return c + l, None
        out, _ = jax.lax.scan(body, 0.0, micro)
        return out
    report("fwd", jax.jit(fwd, in_shardings=(pshard, bshard)),
           params_struct, batch_struct)

if what in ("grad", "all"):
    def gstep(params, batch):
        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
        micro = jax.tree.map(split, batch)
        def body(acc, b):
            g = jax.grad(lambda p: model.loss(p, b)[0])(params)
            return jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                acc, g), None
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        g, _ = jax.lax.scan(body, zeros, micro)
        return g
    report("grad", jax.jit(gstep, in_shardings=(pshard, bshard)),
           params_struct, batch_struct)

if what in ("opt", "all"):
    opt = dryrun_optimizer(arch)
    def ostep(params, opt_state, grads):
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state
    ostruct = jax.eval_shape(opt.init, params_struct)
    oshard = SH.opt_state_shardings("adapprox", ostruct, params_struct,
                                    pspecs, mesh)
    gshard = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s.spec), pshard,
        is_leaf=lambda s: isinstance(s, jax.sharding.NamedSharding))
    report("opt", jax.jit(ostep, in_shardings=(pshard, oshard, gshard),
                          donate_argnums=(0, 1)),
           params_struct, ostruct, params_struct)

if what in ("train", "all"):
    opt = dryrun_optimizer(arch)
    sstruct = jax.eval_shape(lambda p: TrainState.create(p, opt),
                             params_struct)
    oshard = SH.opt_state_shardings("adapprox", sstruct.opt_state,
                                    params_struct, pspecs, mesh)
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    sshard = TrainState(params=pshard, opt_state=oshard, step=rep)
    step = build_train_step(model, opt, microbatches=mb)
    report("train", jax.jit(step, in_shardings=(sshard, bshard),
                            donate_argnums=(0,)), sstruct, batch_struct)
