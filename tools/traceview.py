"""Trace viewer: reconstruct span waterfalls from a telemetry JSONL dir.

    PYTHONPATH=src python tools/traceview.py /tmp/trace
    PYTHONPATH=src python tools/traceview.py /tmp/trace --check
    PYTHONPATH=src python tools/traceview.py /tmp/trace \
        --chrome-trace /tmp/trace.json

Prints per-span-kind p50/p95/p99 latency, the train step-time breakdown
(where each step went: data wait / dispatch / device sync / checkpoint,
refresh vs fold steps) when ``train_step`` spans are present, and a
per-request serve waterfall summary when request roots are present.

``--chrome-trace OUT.json`` additionally exports the spans as a
Chrome-trace/Perfetto JSON (load it in ``chrome://tracing`` or
https://ui.perfetto.dev).

``--check`` runs the structural validation (``trace.check_events``) and
exits nonzero on any schema violation, negative duration, orphaned
parent span, or serve request whose waterfall is incomplete — CI gates
the observability smoke on it.
"""
import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path

from repro.telemetry.trace import (check_events, chrome_trace,
                                   format_breakdown, format_span_stats,
                                   load_events, span_events, span_stats,
                                   step_breakdown)


def serve_waterfalls(events: list) -> dict:
    """Per-request phase summary from the serving engines' span
    waterfalls: one row per ``request`` root span, phases keyed by
    span name (chunked prefill aggregated)."""
    by_trace = defaultdict(list)
    for e in span_events(events):
        by_trace[e["trace"]].append(e)
    rows = []
    for trace, spans in by_trace.items():
        root = next((s for s in spans if s["name"] == "request"), None)
        if root is None:
            continue
        phases = defaultdict(float)
        chunks = 0
        for s in spans:
            if s is root:
                continue
            phases[s["name"]] += float(s["dur_s"])
            if s["name"] == "prefill_chunk":
                chunks += 1
        rows.append({
            "trace": trace, "uid": root.get("uid"),
            "total_s": float(root["dur_s"]),
            "tokens": root.get("attrs", {}).get("tokens"),
            "rejected": bool(root.get("attrs", {}).get("rejected")),
            "prefill_chunks": chunks,
            "phases_s": dict(phases),
        })
    rows.sort(key=lambda r: (r["uid"] is None, r["uid"]))
    return {"requests": len(rows), "rows": rows}


def format_waterfalls(wf: dict, limit: int = 12) -> str:
    phase_order = ["queued", "admitted", "prefill", "prefill_chunk",
                   "decode"]
    lines = [f"serve waterfalls ({wf['requests']} requests):",
             f"  {'uid':>5} {'total ms':>9} {'tokens':>6} "
             + " ".join(f"{p + ' ms':>12}" for p in phase_order)]
    for r in wf["rows"][:limit]:
        cells = []
        for p in phase_order:
            v = r["phases_s"].get(p)
            cells.append(f"{v * 1e3:>12.2f}" if v is not None
                         else f"{'-':>12}")
        tok = r["tokens"] if r["tokens"] is not None else "-"
        flag = " REJECTED" if r["rejected"] else ""
        lines.append(f"  {r['uid'] if r['uid'] is not None else '?':>5} "
                     f"{r['total_s'] * 1e3:>9.2f} {tok:>6} "
                     + " ".join(cells) + flag)
    if wf["requests"] > limit:
        lines.append(f"  ... {wf['requests'] - limit} more")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="telemetry directory or one .jsonl file")
    ap.add_argument("--glob", default=None,
                    help="event-file glob under PATH (default "
                         "'events-*.jsonl'; e.g. '**/events-*.jsonl' "
                         "for nested run dirs)")
    ap.add_argument("--chrome-trace", default=None, metavar="OUT.json",
                    help="export spans as Chrome-trace/Perfetto JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on schema violations, orphaned spans or "
                         "incomplete request waterfalls")
    args = ap.parse_args(argv)

    events = load_events(args.path, pattern=args.glob)
    spans = span_events(events)
    if not spans:
        print(f"no kind=\"span\" events under {args.path}",
              file=sys.stderr)
        return 1
    print(f"{len(events)} events, {len(spans)} spans, "
          f"{len({e['trace'] for e in spans})} traces\n")
    print(format_span_stats(span_stats(events)))

    bd = step_breakdown(events)
    if bd["steps"]:
        print()
        print(format_breakdown(bd))

    wf = serve_waterfalls(events)
    if wf["requests"]:
        print()
        print(format_waterfalls(wf))

    if args.chrome_trace:
        out = Path(args.chrome_trace)
        out.write_text(json.dumps(chrome_trace(events)))
        print(f"\nchrome trace -> {out}")

    if args.check:
        problems = check_events(events)
        if problems:
            print(f"\nCHECK FAILED ({len(problems)} problems):",
                  file=sys.stderr)
            for p in problems:
                print(f"  {p}", file=sys.stderr)
            return 1
        print("\ncheck OK: schema valid, no orphans, waterfalls complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
