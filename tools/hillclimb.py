import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Perf hillclimbing harness (§Perf): lower a (arch, cell) under config
overrides, re-derive the roofline terms, log hypothesis -> before/after.

    PYTHONPATH=src python tools/hillclimb.py qwen2-7b train_4k \
        --set attn_impl=chunked remat=dots --mb 4 --tag chunked+dots

Records land in experiments/perf/<arch>__<cell>__<tag>.json.
"""
import argparse
import dataclasses
import json
from pathlib import Path

import jax

import repro.launch.dryrun as DR
from repro.configs import archs as ARCHS
from repro.launch.hlo_cost import parse_hlo_costs
from repro.launch.mesh import make_production_mesh

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def measure(arch, cell, overrides, mb=None, opt_overrides=None, tag="exp"):
    cfg0 = ARCHS.get_config(arch)
    cfg = dataclasses.replace(cfg0, **overrides) if overrides else cfg0
    # monkeypatch config + knobs into the dry-run builder
    orig_get = ARCHS.get_config
    DRget = DR.get_config
    DR.get_config = lambda a: cfg if a == arch else orig_get(a)
    if mb is not None:
        DR.microbatches_for = (lambda *a, **k: mb)
    if opt_overrides:
        base_opt = DR.dryrun_optimizer

        def patched_opt(a):
            import dataclasses as _dc
            from repro.core import build_optimizer
            ocfg = _dc.replace(DR.dryrun_opt_config(a), **opt_overrides)
            return build_optimizer(ocfg)
        DR.dryrun_optimizer = patched_opt

    mesh = make_production_mesh()
    fn, structs, _, cellobj = DR.build_cell(arch, cell, mesh)
    compiled = fn.lower(*structs).compile()
    cost = parse_hlo_costs(compiled.as_text())
    mem = compiled.memory_analysis()
    coll_bytes = sum(v["bytes"] for v in cost.coll.values())
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": arch, "cell": cell, "tag": tag,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "microbatches": mb, "opt_overrides": opt_overrides,
        "flops": cost.flops, "bytes": cost.bytes,
        "collective_bytes": coll_bytes,
        "coll": {k: dict(v) for k, v in cost.coll.items()},
        "t_compute": cost.flops / PEAK_FLOPS,
        "t_memory": cost.bytes / HBM_BW,
        "t_collective": coll_bytes / ICI_BW,
        "peak_gib": peak / 2**30,
        "top_sites": [[s, b] for s, b in cost.top_sites(10)],
    }
    DR.get_config = DRget
    out = Path("experiments/perf")
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{arch}__{cell}__{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("cell")
    ap.add_argument("--set", nargs="*", default=[],
                    help="ModelConfig overrides k=v")
    ap.add_argument("--opt", nargs="*", default=[],
                    help="optimizer overrides k=v")
    ap.add_argument("--mb", type=int, default=None)
    ap.add_argument("--tag", default="exp")
    args = ap.parse_args()

    def parse_kv(items):
        out = {}
        for item in items:
            k, v = item.split("=", 1)
            for cast in (int, float):
                try:
                    v = cast(v)
                    break
                except ValueError:
                    continue
            out[k] = v
        return out

    rec = measure(args.arch, args.cell, parse_kv(args.set), args.mb,
                  parse_kv(args.opt) or None, args.tag)
    print(f"{args.tag}: t_comp={rec['t_compute']:.2f}s "
          f"t_mem={rec['t_memory']:.2f}s t_coll={rec['t_collective']:.2f}s "
          f"peak={rec['peak_gib']:.1f}GiB")
    for s, b in rec["top_sites"][:6]:
        print(f"  {b:10.3g}  {s}")


if __name__ == "__main__":
    main()
