"""Chaos smoke driver: a tiny guarded training run under a deterministic
NaN/Inf gradient burst, asserting the resilience layer's contract from
the outside.

    PYTHONPATH=src python tools/chaos.py --out /tmp/chaos-events \
        --steps 30 --nan-steps 7,8,19

What it checks (exit 0 only if ALL hold):

  * the guard skipped EXACTLY the injected steps — ``guard/skipped``
    equals the schedule length and ``guard/last_skip`` equals its max;
  * every parameter is finite at the end of the run;
  * the loss recovered — final logged loss is finite and below the first;
  * the sink emitted at least one ``kind="fault"`` event per injected
    burst boundary, and the whole stream passes the telemetry schema
    (``repro.telemetry.validate_dir``).

CI runs this (single- and multi-device), uploads ``--out`` as the
fault-event artifact, and separately re-validates it with
``python -m repro.telemetry.validate``.
"""
import os

if os.environ.get("REPRO_TRAIN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_TRAIN_DEVICES"]
                               + " " + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys
from pathlib import Path

import jax
import numpy as np

from repro.config import OptimizerConfig, TelemetryConfig
from repro.configs import get_smoke_config
from repro.core import build_optimizer, chain
from repro.data import DataConfig
from repro.models import build_model
from repro.resilience import FaultPlan, inject_faults
from repro.telemetry import TelemetryRuntime, chain_guard_state, validate_dir
from repro.train import LoopConfig, train


def parse_steps(spec: str) -> tuple:
    return tuple(int(s) for s in spec.split(",") if s)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True,
                    help="telemetry JSONL directory (the CI artifact)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--nan-steps", default="7,8,19",
                    help="comma-separated 1-based steps to poison with NaN")
    ap.add_argument("--inf-steps", default="",
                    help="comma-separated 1-based steps to poison with Inf")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)

    plan = FaultPlan(nan_steps=parse_steps(args.nan_steps),
                     inf_steps=parse_steps(args.inf_steps))
    if not plan.fault_steps:
        print("chaos: empty fault plan — nothing to test", file=sys.stderr)
        return 2
    if max(plan.fault_steps) >= args.steps:
        print(f"chaos: fault step {max(plan.fault_steps)} must land before "
              f"the last step ({args.steps}) so the loss can recover",
              file=sys.stderr)
        return 2

    cfg = get_smoke_config("gpt2-117m", vocab=64, max_seq_len=32)
    model = build_model(cfg)
    # guards=True wraps the whole chain in the skip-step guard; the
    # injector sits in front of it, poisoning grads the way a real
    # overflow would arrive
    opt = chain(inject_faults(plan), build_optimizer(OptimizerConfig(
        name="adapprox", schedule="constant", lr=args.lr, weight_decay=0.1,
        k=4, rank_mode="static", min_dim_factor=32, implicit=False,
        telemetry=True, guards=True)))
    runtime = TelemetryRuntime(TelemetryConfig(
        enabled=True, dir=args.out, emit_every=5))
    data_cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=0)

    try:
        state, hist = train(model, opt, data_cfg,
                            LoopConfig(total_steps=args.steps, log_every=5),
                            telemetry=runtime)
    finally:
        runtime.close()

    failures = []
    n_injected = len(set(plan.nan_steps) | set(plan.inf_steps))
    gs = chain_guard_state(state.opt_state)
    if gs is None:
        failures.append("no chain guard state in the optimizer state")
        skipped = last_skip = -1
    else:
        skipped = int(np.asarray(gs.skipped))
        last_skip = int(np.asarray(gs.last_skip))
        if skipped != n_injected:
            failures.append(f"guard skipped {skipped} steps, injected "
                            f"{n_injected} ({plan.fault_steps})")
        if last_skip != max(plan.fault_steps):
            failures.append(f"last skip at step {last_skip}, last injection "
                            f"at {max(plan.fault_steps)}")

    bad = [str(p) for p, leaf in
           jax.tree_util.tree_flatten_with_path(state.params)[0]
           if not bool(np.all(np.isfinite(np.asarray(leaf))))]
    if bad:
        failures.append(f"non-finite params after the run: {bad}")

    first, last = hist[0]["loss"], hist[-1]["loss"]
    if not (np.isfinite(last) and last < first):
        failures.append(f"loss did not recover: first {first}, last {last}")

    try:
        ok_events = validate_dir(args.out)
    except ValueError as e:
        ok_events = 0
        failures.append(f"schema-invalid event stream: {e}")
    n_fault = sum(
        1 for f in sorted(Path(args.out).glob("events-*.jsonl"))
        for line in f.read_text().splitlines()
        if json.loads(line).get("kind") == "fault")
    if n_fault == 0:
        failures.append("no kind=fault events in the stream")

    print(f"chaos: {args.steps} steps, injected {n_injected} "
          f"({plan.fault_steps}), guard skipped {skipped} "
          f"(last at {last_skip}); loss {first:.3f} -> {last:.3f}; "
          f"{n_fault} fault events / {ok_events} valid lines in {args.out}")
    if failures:
        for f in failures:
            print(f"chaos: FAIL: {f}", file=sys.stderr)
        return 1
    print("chaos: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
