"""Span tracing: waterfalls for train steps and serve requests.

Zero-dependency (stdlib-only) span API over the existing async JSONL
sink: context-manager spans with monotonic clocks, trace/span ids and a
thread-local span stack record ``kind="span"`` events through the same
:class:`~repro.telemetry.sink.TelemetrySink` every other telemetry kind
uses — one stream, one schema, one ``validate_dir``.  Spans are
HOST-SIDE ONLY: nothing here runs inside jit, so the bitwise
default-chain contract (tests/test_compose.py) is untouched; a span
around a dispatch measures host wall time, and a span around an explicit
``block_until_ready`` measures device drain.

Two recording styles:

  * ``with tracer.span("data_wait"): ...`` — live spans.  Nesting is
    tracked per thread: an inner span's ``parent`` is the enclosing
    span's id, and an inner span inherits the enclosing trace id.
  * ``tracer.record(name, t0_s, dur_s, trace, ...)`` — after-the-fact
    spans for lifecycles whose phases are only known at the end (a serve
    request's queued/admitted/prefill/decode waterfall).  The serving
    engines use the fixed span id ``"root"`` for the per-request
    ``"request"`` root and parent every phase under it.

Trace-id join contract with ``kind="serve"``: the continuous/wave
engines stamp each request's trace id into its per-request serve events
(``admit`` / ``first_token`` / ``finish`` / ``reject`` carry an optional
``trace`` field), so a consumer joins the span waterfall to the serve
lifecycle by trace id alone.  ``check_events`` enforces the resulting
completeness invariant (every finished request reconstructs a
queued→finish waterfall) and is what ``tools/traceview.py --check``
gates CI on.

Signal-safety mirrors the sink: the tracer keeps its open-span table in
a plain dict (GIL-atomic ops, no mutex), so ``drain_open()`` — which the
train loop's preemption handler calls to flush in-flight spans as
``"truncated": true`` events — can run from a signal handler that
interrupted ``emit`` mid-call without deadlocking.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import math
import os
import threading
import time
from collections import defaultdict
from pathlib import Path
from typing import Optional

from repro.telemetry.sink import validate_event

ROOT_SPAN = "root"          # fixed span id for per-request root spans

# span names a finished serve request must have recorded (see
# check_events): prefill may be chunked (continuous) or whole (wave)
_PREFILL_NAMES = {"prefill", "prefill_chunk"}


class SpanHandle:
    """Mutable handle a live span yields: set attributes mid-span
    (e.g. the refresh-vs-fold phase, known only after the device sync)."""

    __slots__ = ("trace", "id", "name", "t0_s", "parent", "attrs")

    def __init__(self, name, trace, sid, t0_s, parent, attrs):
        self.name = name
        self.trace = trace
        self.id = sid
        self.t0_s = t0_s
        self.parent = parent
        self.attrs = attrs

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)


class _NullHandle:
    trace = ""
    id = ""

    def set(self, **attrs) -> None:
        pass


_NULL_HANDLE = _NullHandle()


class NullTracer:
    """No-op twin of :class:`Tracer` so instrumented code paths need no
    ``if tracer is not None`` forests; ``engine.py`` / ``manager.py``
    default to the shared :data:`NULL_TRACER` instance."""

    sink = None
    registry = None

    def span(self, name, trace=None, **attrs):
        return contextlib.nullcontext(_NULL_HANDLE)

    def record(self, *args, **kwargs) -> None:
        pass

    def new_trace(self, tag=None) -> str:
        return ""

    def now(self) -> float:
        return 0.0

    def drain_open(self) -> None:
        pass

    def flush(self) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Span recorder over a :class:`TelemetrySink` (both optional: with
    ``sink=None`` spans are timed and discarded, with ``registry`` set
    every span duration is also observed into the
    ``span_duration_seconds`` histogram labelled by span name)."""

    def __init__(self, sink=None, registry=None):
        self.sink = sink
        self.registry = registry
        self._epoch = time.monotonic()
        self._ids = itertools.count()
        # distinct per process so streams from restarts never collide
        self._run = f"{os.getpid():x}"
        self._local = threading.local()
        # open-span table: plain dict (GIL-atomic), readable from a
        # signal handler — see module docstring
        self._open: "dict[str, SpanHandle]" = {}

    # -- clocks / ids ------------------------------------------------------
    def now(self) -> float:
        """Seconds since this tracer was created (monotonic)."""
        return time.monotonic() - self._epoch

    def new_trace(self, tag: Optional[str] = None) -> str:
        return f"{self._run}-{tag or 't'}-{next(self._ids):x}"

    def _new_span_id(self) -> str:
        return f"s{next(self._ids):x}"

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    # -- live spans --------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, trace: Optional[str] = None, **attrs):
        """Context-manager span.  With no explicit ``trace``, nests under
        the innermost open span on this thread (inheriting its trace) or
        starts a fresh trace."""
        stack = self._stack()
        parent = None
        if trace is None:
            if stack:
                trace, parent = stack[-1]
            else:
                trace = self.new_trace(name)
        elif stack and stack[-1][0] == trace:
            parent = stack[-1][1]
        sid = self._new_span_id()
        handle = SpanHandle(name, trace, sid, self.now(), parent, dict(attrs))
        self._open[sid] = handle
        stack.append((trace, sid))
        try:
            yield handle
        finally:
            stack.pop()
            # drain_open may have already emitted this span (truncated)
            # from the preemption handler: the pop decides exactly one
            # event per span id
            if self._open.pop(sid, None) is not None:
                self._emit(handle.name, handle.trace, sid, handle.t0_s,
                           self.now() - handle.t0_s, handle.parent,
                           handle.attrs)

    # -- after-the-fact spans ----------------------------------------------
    def record(self, name: str, t0_s: float, dur_s: float, trace: str,
               span: Optional[str] = None, parent: Optional[str] = None,
               attrs: Optional[dict] = None) -> None:
        """Emit a span whose boundaries were measured by the caller —
        request waterfalls are reconstructed this way at finish time."""
        self._emit(name, trace, span if span is not None
                   else self._new_span_id(), t0_s, dur_s, parent,
                   attrs or {})

    # -- preemption --------------------------------------------------------
    def drain_open(self) -> None:
        """Emit every still-open span with ``"truncated": true`` — the
        preemption-handler chain calls this so a SIGTERM'd run's trace
        ends with explicit partial spans instead of silent holes.
        Acquires no locks (dict ops + the sink's lock-free emit)."""
        now = self.now()
        for sid in list(self._open):
            handle = self._open.pop(sid, None)
            if handle is None:          # closed concurrently
                continue
            self._emit(handle.name, handle.trace, sid, handle.t0_s,
                       now - handle.t0_s, handle.parent, handle.attrs,
                       truncated=True)

    def flush(self) -> None:
        if self.sink is not None:
            self.sink.flush()

    # -- event assembly ----------------------------------------------------
    def _emit(self, name, trace, sid, t0_s, dur_s, parent, attrs,
              truncated=False) -> None:
        if self.registry is not None:
            self.registry.histogram(
                "span_duration_seconds",
                help="span wall time by span name").observe(
                    max(float(dur_s), 0.0), name=name)
        if self.sink is None:
            return
        ev = {"kind": "span", "name": name, "trace": trace, "span": sid,
              "t0_s": round(float(t0_s), 6), "dur_s": round(float(dur_s), 6)}
        if parent:
            ev["parent"] = parent
        if truncated:
            ev["truncated"] = True
        if attrs:
            a = dict(attrs)
            step = a.pop("step", None)
            uid = a.pop("uid", None)
            if step is not None:
                ev["step"] = int(step)
            if uid is not None:
                ev["uid"] = int(uid)
            if a:
                ev["attrs"] = a
        self.sink.emit(ev)


# ---------------------------------------------------------------------------
# analysis helpers (shared by tools/traceview.py, benches, quickstart)
# ---------------------------------------------------------------------------

def load_events(path, pattern: Optional[str] = None) -> list:
    """Read every event from a JSONL file, or every ``events-*.jsonl``
    under a directory (``pattern`` overrides the default glob, e.g.
    ``"**/events-*.jsonl"`` for nested run dirs).  Files are read in
    numeric rotation order."""
    p = Path(path)
    if p.is_file():
        files = [p]
    else:
        files = sorted(p.glob(pattern or "events-*.jsonl"), key=str)
    events = []
    for f in files:
        with open(f) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
    return events


def span_events(events: list) -> list:
    return [e for e in events if e.get("kind") == "span"]


def _pct(sorted_vals: list, q: float) -> float:
    """Percentile with linear interpolation (numpy default), stdlib-only."""
    if not sorted_vals:
        return 0.0
    k = (len(sorted_vals) - 1) * q / 100.0
    lo, hi = math.floor(k), math.ceil(k)
    if lo == hi:
        return float(sorted_vals[lo])
    return float(sorted_vals[lo] + (sorted_vals[hi] - sorted_vals[lo])
                 * (k - lo))


def span_stats(events: list) -> dict:
    """Per-span-name duration stats: count / total / mean / p50 / p95 /
    p99 (seconds)."""
    durs = defaultdict(list)
    for e in span_events(events):
        durs[e["name"]].append(float(e["dur_s"]))
    out = {}
    for name, d in sorted(durs.items()):
        d.sort()
        out[name] = {
            "count": len(d),
            "total_s": sum(d),
            "mean_s": sum(d) / len(d),
            "p50_s": _pct(d, 50),
            "p95_s": _pct(d, 95),
            "p99_s": _pct(d, 99),
        }
    return out


def format_span_stats(stats: dict) -> str:
    lines = [f"{'span':<20} {'count':>6} {'p50 ms':>9} {'p95 ms':>9} "
             f"{'p99 ms':>9} {'total s':>9}"]
    for name, s in stats.items():
        lines.append(f"{name:<20} {s['count']:>6} "
                     f"{s['p50_s'] * 1e3:>9.2f} {s['p95_s'] * 1e3:>9.2f} "
                     f"{s['p99_s'] * 1e3:>9.2f} {s['total_s']:>9.3f}")
    return "\n".join(lines)


def step_breakdown(events: list) -> dict:
    """Where train-step time went: per-phase totals/shares from the
    children of ``train_step`` spans, plus the refresh-vs-fold split
    from the step spans' ``phase`` attribution."""
    spans = span_events(events)
    by_id = {(e["trace"], e["span"]): e for e in spans}
    steps = [e for e in spans if e["name"] == "train_step"]
    total = sum(float(e["dur_s"]) for e in steps)
    child = defaultdict(list)
    for e in spans:
        parent = by_id.get((e["trace"], e.get("parent")))
        if parent is not None and parent["name"] == "train_step":
            child[e["name"]].append(float(e["dur_s"]))
    phases = []
    accounted = 0.0
    for name, d in sorted(child.items(), key=lambda kv: -sum(kv[1])):
        tot = sum(d)
        accounted += tot
        phases.append({"phase": name, "count": len(d), "total_s": tot,
                       "mean_ms": tot / len(d) * 1e3,
                       "share": tot / total if total else 0.0})
    if steps and total > accounted:
        phases.append({"phase": "(other)", "count": len(steps),
                       "total_s": total - accounted,
                       "mean_ms": (total - accounted) / len(steps) * 1e3,
                       "share": (total - accounted) / total})
    split = {}
    for mode in ("refresh", "fold"):
        d = [float(e["dur_s"]) for e in steps
             if e.get("attrs", {}).get("phase") == mode]
        if d:
            split[mode] = {"count": len(d),
                           "mean_ms": sum(d) / len(d) * 1e3}
    return {"steps": len(steps), "total_s": total, "phases": phases,
            "refresh_vs_fold": split}


def format_breakdown(bd: dict) -> str:
    if not bd["steps"]:
        return "no train_step spans"
    lines = [f"step-time breakdown over {bd['steps']} steps "
             f"({bd['total_s']:.3f}s total):",
             f"  {'phase':<18} {'count':>6} {'mean ms':>9} {'share':>7}"]
    for p in bd["phases"]:
        lines.append(f"  {p['phase']:<18} {p['count']:>6} "
                     f"{p['mean_ms']:>9.2f} {p['share'] * 100:>6.1f}%")
    for mode, s in bd["refresh_vs_fold"].items():
        lines.append(f"  {mode + ' steps':<18} {s['count']:>6} "
                     f"{s['mean_ms']:>9.2f}")
    return "\n".join(lines)


def chrome_trace(events: list) -> dict:
    """Chrome-trace/Perfetto JSON (``chrome://tracing`` loads it): one
    complete-duration ("X") event per span, traces mapped to tids."""
    tids: "dict[str, int]" = {}
    trace_events = []
    for e in span_events(events):
        tid = tids.setdefault(e["trace"], len(tids))
        args = dict(e.get("attrs", {}))
        for key in ("step", "uid", "truncated"):
            if key in e:
                args[key] = e[key]
        trace_events.append({
            "name": e["name"], "ph": "X", "cat": "span",
            "ts": round(float(e["t0_s"]) * 1e6, 3),
            "dur": round(float(e["dur_s"]) * 1e6, 3),
            "pid": 0, "tid": tid, "args": args,
        })
    for trace, tid in tids.items():
        trace_events.append({"name": "thread_name", "ph": "M", "pid": 0,
                             "tid": tid, "args": {"name": trace}})
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def check_events(events: list) -> list:
    """Structural validation for a JSONL event set; returns a list of
    problem strings (empty = clean).  Checks: every event conforms to
    the schema; no negative span durations; every span's ``parent``
    resolves within its trace (no orphans); and every ``kind="serve"``
    ``finish`` event that carries a trace id joins to a COMPLETE
    waterfall — ``request`` + ``queued`` spans, a prefill span when any
    token was emitted, a ``decode`` span when more than one was.  Traces
    holding truncated spans (preempted runs) are exempt from the
    completeness rule, not from the structural ones."""
    problems = []
    for i, e in enumerate(events):
        try:
            validate_event(e)
        except ValueError as err:
            problems.append(f"event {i}: schema violation: {err}")
    spans = span_events(events)
    by_trace = defaultdict(list)
    for e in spans:
        if float(e.get("dur_s", 0.0)) < 0:
            problems.append(f"span {e.get('trace')}/{e.get('span')} "
                            f"({e.get('name')}): negative duration")
        by_trace[e.get("trace")].append(e)
    for trace, tspans in by_trace.items():
        ids = {e["span"] for e in tspans}
        for e in tspans:
            parent = e.get("parent")
            if parent is not None and parent not in ids:
                problems.append(f"orphaned span {trace}/{e['span']} "
                                f"({e['name']}): parent {parent!r} "
                                f"not in trace")
    for e in events:
        if (e.get("kind") != "serve" or e.get("event") != "finish"
                or "trace" not in e):
            continue
        tspans = by_trace.get(e["trace"], [])
        if any(s.get("truncated") for s in tspans):
            continue
        names = {s["name"] for s in tspans}
        uid = e.get("uid")
        missing = {"request", "queued"} - names
        tokens = e.get("tokens", 0)
        if tokens >= 1 and not (_PREFILL_NAMES & names):
            missing.add("prefill")
        if tokens > 1 and "decode" not in names:
            missing.add("decode")
        if missing:
            problems.append(f"request uid={uid} trace={e['trace']}: "
                            f"incomplete waterfall, missing "
                            f"{sorted(missing)} (has {sorted(names)})")
    return problems
