"""Async, buffered JSONL telemetry sink with size-based rotation.

One event stream, one schema: optimizer snapshots, cadence changes,
straggler flags and dry-run compile records all flow through
:class:`TelemetrySink` as single-line JSON objects (see
``repro.telemetry``'s package docstring for the field reference, and
:func:`validate_event` for the machine-checkable form CI validates
against).

Design mirrors ``checkpoint/manager.py``'s async save: ``emit`` validates
and enqueues (never blocks on IO), a daemon writer thread drains the
queue to the current ``<prefix>-NNNNN.jsonl`` file (rotating when it
exceeds ``rotate_bytes``), and any writer-side exception is captured and
re-raised on the next ``flush()`` / ``close()`` instead of dying
silently.  ``flush()`` blocks until every emitted event is on disk — the
train loop's preemption handler chain calls it before the final
checkpoint flush hands the signal on.

Signal-safety: the producer/consumer channel is a lock-free
``collections.deque`` plus single-writer counters, NOT a
``queue.Queue``.  A SIGTERM can land while the main thread is inside
``emit`` — with a mutex-based queue, a ``flush()`` from the preemption
handler (same thread) would then try to re-acquire the mutex the
interrupted ``emit`` still holds and deadlock the teardown.  Here
``emit`` is an atomic ``deque.append`` + int increment and ``flush``
spin-waits on counters each owned by exactly one thread, so the handler
path acquires no lock at all.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from pathlib import Path
from typing import Optional

SCHEMA_VERSION = 1

# kind -> (required fields, optional fields); values are accepted types.
# Numbers: int is always an acceptable float (JSON does not distinguish).
_NUM = (int, float)
EVENT_SCHEMA = {
    "optimizer": {
        "required": {"step": int, "group": str, "refresh_every": int,
                     "did_refresh": bool, "refresh_steps": int,
                     "fold_steps": int, "clip_rate": _NUM},
        "optional": {"xi": list, "k": list, "k_frac": list,
                     "mean_xi": _NUM, "max_xi": _NUM, "mean_k": _NUM,
                     "mean_k_frac": _NUM, "leaf_indices": list,
                     "dense_indices": list},
    },
    "sketch": {
        "required": {"step": int, "group": str, "mean_occupancy": _NUM,
                     "mean_overestimate": _NUM},
        "optional": {"occupancy": list, "overestimate": list,
                     "max_occupancy": _NUM, "max_overestimate": _NUM,
                     "leaf_indices": list},
    },
    "cadence": {
        "required": {"step": int, "group": str, "old": int, "new": int,
                     "interval_mean_xi": _NUM},
        "optional": {"reason": str},
    },
    "straggler": {
        "required": {"event": str, "n_steps": int, "step_time_s": _NUM,
                     "median_s": _NUM},
        "optional": {"z": _NUM, "flags": int, "detail": str},
    },
    "dryrun_cell": {
        "required": {"arch": str, "cell": str, "mesh": str, "devices": int,
                     "flops": _NUM, "bytes_accessed": _NUM},
        "optional": {"peak_bytes": _NUM, "collective_bytes": _NUM,
                     "compile_s": _NUM, "kind": str, "params": _NUM},
    },
    "run_meta": {
        "required": {"source": str},
        "optional": {"argv": list, "config": dict, "note": str},
    },
    # resilience guards (repro.resilience): skip-steps from the chain-level
    # non-finite guard, per-leaf xi trips / forced refreshes / dense
    # demotions from the Adapprox xi watchdog.  ``event`` names the fault
    # ("skip" | "xi_trip" | "demote"); counters are CUMULATIVE, so a
    # consumer diffs consecutive events to recover per-interval rates.
    "fault": {
        "required": {"step": int, "group": str, "event": str},
        "optional": {"skipped": int, "last_skip": int, "trips": int,
                     "demotions": int, "leaf": int, "xi": _NUM,
                     "detail": str},
    },
    # serving engines (repro.serve): request lifecycle ("admit" |
    # "first_token" | "finish" | "reject"), admission back-pressure
    # ("backoff" when KV-block occupancy crosses the watermark) and
    # periodic "stats" lines.  ``t_s`` is seconds since the engine run
    # started; ``tokens`` counters are CUMULATIVE on "stats" lines and
    # per-request on "finish" lines.
    # ``trace`` joins a request's serve events to its span waterfall
    # (kind="span" events sharing the trace id) — see telemetry/trace.py.
    "serve": {
        "required": {"event": str, "t_s": _NUM, "scheduler": str},
        "optional": {"uid": int, "step": int, "queue_depth": int,
                     "ttft_s": _NUM, "latency_s": _NUM, "tokens": int,
                     "tok_per_s": _NUM, "occupancy": _NUM,
                     "slots_active": int, "reason": str, "trace": str},
    },
    # host-side timing spans (telemetry/trace.py): ``trace`` groups a
    # waterfall (one train run / serve request / engine), ``span`` is
    # unique within it, ``parent`` nests.  ``t0_s``/``dur_s`` are seconds
    # on the emitting tracer's monotonic clock.  ``truncated`` marks a
    # span the preemption drain closed early.
    "span": {
        "required": {"name": str, "trace": str, "span": str,
                     "t0_s": _NUM, "dur_s": _NUM},
        "optional": {"parent": str, "step": int, "uid": int,
                     "truncated": bool, "attrs": dict},
    },
    # periodic registry snapshot (telemetry/metrics.py): sample keys are
    # the Prometheus sample names, so the JSONL and text expositions
    # agree; histogram values carry buckets/counts/sum/count.
    "metric": {
        "required": {"t_s": _NUM, "counters": dict, "gauges": dict,
                     "histograms": dict},
        "optional": {"step": int},
    },
}


def _json_default(x):
    """JSON fallback for numpy scalars / arrays."""
    if hasattr(x, "tolist"):
        return x.tolist()
    if hasattr(x, "item"):
        return x.item()
    raise TypeError(f"not JSON-serializable: {type(x).__name__}")


def validate_event(event: dict) -> None:
    """Raise ``ValueError`` when ``event`` does not conform to the schema
    (unknown kind, missing required field, wrong type).  Extra fields not
    listed in the schema are rejected so the schema stays the single
    source of truth for consumers."""
    if not isinstance(event, dict):
        raise ValueError(f"event must be a dict, got {type(event).__name__}")
    kind = event.get("kind")
    if kind not in EVENT_SCHEMA:
        raise ValueError(f"unknown event kind {kind!r}; "
                         f"known: {sorted(EVENT_SCHEMA)}")
    if event.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"schema must be {SCHEMA_VERSION}, "
                         f"got {event.get('schema')!r}")
    spec = EVENT_SCHEMA[kind]
    for field, typ in spec["required"].items():
        if field not in event:
            raise ValueError(f"{kind} event missing required field "
                             f"{field!r}")
        if not isinstance(event[field], typ) or (
                typ is int and isinstance(event[field], bool)):
            raise ValueError(f"{kind} event field {field!r}: expected "
                             f"{typ}, got {type(event[field]).__name__}")
    known = set(spec["required"]) | set(spec["optional"]) | {"kind", "schema"}
    for field, value in event.items():
        if field not in known:
            raise ValueError(f"{kind} event has unknown field {field!r}")
        if field in spec["optional"] and not isinstance(
                value, spec["optional"][field]):
            raise ValueError(f"{kind} event field {field!r}: expected "
                             f"{spec['optional'][field]}, "
                             f"got {type(value).__name__}")


def validate_file(path: "str | Path") -> int:
    """Validate every line of one JSONL file; returns the event count."""
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                validate_event(json.loads(line))
            except (ValueError, json.JSONDecodeError) as e:
                raise ValueError(f"{path}:{lineno}: {e}") from e
            n += 1
    return n


def _file_index(p: Path) -> int:
    """Rotation sequence number parsed from ``<prefix>-NNNNN.jsonl``
    (-1 for files that don't carry one)."""
    try:
        return int(p.stem.rsplit("-", 1)[-1])
    except ValueError:
        return -1


def validate_dir(directory: "str | Path", prefix: str = "events") -> int:
    """Validate every ``<prefix>-*.jsonl`` under ``directory``; returns
    the total event count (0 when no files exist)."""
    total = 0
    for p in sorted(Path(directory).glob(f"{prefix}-*.jsonl"),
                    key=_file_index):
        total += validate_file(p)
    return total


@dataclasses.dataclass
class SinkConfig:
    directory: str
    prefix: str = "events"
    rotate_bytes: int = 32 * 1024 * 1024
    validate: bool = True          # schema-check at emit (cheap, catches
                                   # producer bugs at the source)


class TelemetrySink:
    # writer-thread poll period while the channel is idle; also the
    # flush() spin period (no condition variables: see module docstring)
    _IDLE_S = 0.005
    _FLUSH_TIMEOUT_S = 30.0

    def __init__(self, cfg: SinkConfig):
        self.cfg = cfg
        self.directory = Path(cfg.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._dq: "deque[str]" = deque()
        # single-writer counters (ints are GIL-atomic to read): _emitted
        # is written only by the producer thread, _written / _flushed
        # only by the writer thread
        self._emitted = 0
        self._written = 0
        self._flushed = 0
        self._error: Optional[BaseException] = None
        self._file = None
        self._bytes = 0
        # Monotonic rotation sequence: resume PAST the highest existing
        # index, not at the file count — with a gap in the sequence
        # (pruned early files) count-based numbering would collide with a
        # live later file and interleave two streams, and ordering in
        # validate_dir / paths() would be ambiguous.
        self._index = max((_file_index(p) for p in
                           self.directory.glob(f"{cfg.prefix}-*.jsonl")),
                          default=-1) + 1
        self._closed = False
        self._stop = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- producer side ----------------------------------------------------
    def emit(self, event: dict) -> None:
        """Validate + enqueue one event (non-blocking, lock-free; IO
        happens on the writer thread)."""
        if self._closed:
            raise RuntimeError("sink is closed")
        event.setdefault("schema", SCHEMA_VERSION)
        if self.cfg.validate:
            validate_event(event)
        self._dq.append(json.dumps(event, default=_json_default))
        self._emitted += 1

    def flush(self) -> None:
        """Block until every event emitted so far is written AND flushed
        to disk.  Acquires no locks, so it is safe to call from a signal
        handler that interrupted ``emit`` mid-call."""
        target = self._emitted
        deadline = time.monotonic() + self._FLUSH_TIMEOUT_S
        while self._flushed < target and self._error is None \
                and self._thread.is_alive():
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"telemetry sink flush timed out "
                    f"({self._flushed}/{target} events on disk)")
            time.sleep(self._IDLE_S)
        self._raise_if_failed()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._stop = True
        self._thread.join()
        if self._file is not None:
            self._file.close()
            self._file = None
        self._raise_if_failed()

    def paths(self) -> "list[Path]":
        return sorted(self.directory.glob(f"{self.cfg.prefix}-*.jsonl"),
                      key=_file_index)

    # -- writer thread -----------------------------------------------------
    def _open_next(self):
        if self._file is not None:
            # rotation is the file's last write: flush + fsync before
            # letting go, so a crash right after rotation can't lose the
            # tail of a file readers already consider complete
            self._file.flush()
            os.fsync(self._file.fileno())
            self._file.close()
        path = self.directory / f"{self.cfg.prefix}-{self._index:05d}.jsonl"
        self._index += 1
        self._file = open(path, "a")
        self._bytes = path.stat().st_size

    def _worker(self):
        while True:
            try:
                item = self._dq.popleft()
            except IndexError:
                # drained: sync the file so flush() waiters can finish,
                # then idle-poll (or exit once close() asked us to stop)
                if self._file is not None and self._flushed < self._written:
                    try:
                        self._file.flush()
                    except BaseException as e:  # noqa: BLE001
                        self._error = e
                self._flushed = self._written
                if self._stop:
                    return
                time.sleep(self._IDLE_S)
                continue
            try:
                if self._file is None or self._bytes >= self.cfg.rotate_bytes:
                    self._open_next()
                line = item + "\n"
                self._file.write(line)
                self._bytes += len(line.encode())
            except BaseException as e:  # noqa: BLE001 — surfaced on flush()
                self._error = e
            self._written += 1

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("telemetry sink write failed") from err
