"""In-jit telemetry state: the fixed-shape ``TelemetrySnapshot`` pytree.

One snapshot per ``scale_by_adapprox`` instance (so a ``partition`` chain
carries one per group that runs Adapprox).  It is assembled INSIDE the
jitted optimizer update from quantities the update already computes —
collection adds no extra reductions over the parameter arrays beyond a
handful of per-leaf scalar means — and rides out of the jitted train step
as part of the optimizer state, so it:

  * needs no extra host sync (the train loop already blocks on the loss;
    the host fetch of these scalars piggybacks on that),
  * is checkpointed with the state (cumulative counters survive restarts
    bit-exactly, which is what makes the closed-loop controller's
    decisions reproducible across kill/restore),
  * shards trivially: every leaf is a scalar or a small per-leaf vector,
    replicated on every device (``snapshot_spec``).

Every array has a FIXED shape derived from the parameter tree (number of
leaves / number of factored leaves), so enabling telemetry never changes
shapes step to step and the jit cache stays warm.

``leaf_indices`` / ``dense_indices`` are *static* pytree metadata mapping
the vector entries back to positions in ``jax.tree.flatten(params)``
order — they live in the treedef, not in any array.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TelemetrySnapshot:
    """Per-step optimizer telemetry for one Adapprox instance.

    step:          int32 scalar — optimizer step the snapshot describes
                   (counts from 1; 0 = freshly initialised, nothing ran).
    xi:            (n_factored,) f32 — per-leaf approximation error rate
                   (mean over the leaf's batch dims).
    k:             (n_factored,) f32 — per-leaf effective rank (mean over
                   batch dims).
    k_frac:        (n_factored,) f32 — rank occupancy k / k_max per leaf.
    clip_rate:     (n_leaves,) f32, param flatten order — fraction of the
                   leaf's matrices whose update-RMS clip was ACTIVE this
                   step (RMS(u) > d).
    did_refresh:   f32 scalar — 1.0 if this step ran a full S-RSI refresh,
                   0.0 if it folded under the frozen basis.
    refresh_steps: int32 scalar — cumulative refresh-step count.
    fold_steps:    int32 scalar — cumulative fold-step count
                   (refresh_steps + fold_steps == step).
    refresh_every: int32 scalar — the cadence in effect this step (the
                   traced value under ``dynamic_refresh``, else the
                   config constant).
    leaf_indices:  static tuple — flat param index of each ``xi``/``k``
                   entry (factored leaves, flatten order).
    dense_indices: static tuple — flat param indices of the remaining
                   (dense) leaves, so event emitters can label which
                   ``clip_rate`` entries are dense fallbacks.
    """

    step: jnp.ndarray
    xi: jnp.ndarray
    k: jnp.ndarray
    k_frac: jnp.ndarray
    clip_rate: jnp.ndarray
    did_refresh: jnp.ndarray
    refresh_steps: jnp.ndarray
    fold_steps: jnp.ndarray
    refresh_every: jnp.ndarray
    leaf_indices: tuple = dataclasses.field(
        default=(), metadata=dict(static=True))
    dense_indices: tuple = dataclasses.field(
        default=(), metadata=dict(static=True))


def init_snapshot(n_factored: int, n_leaves: int, refresh_every: int,
                  leaf_indices: tuple = (),
                  dense_indices: tuple = ()) -> TelemetrySnapshot:
    """The step-0 snapshot (all zeros, cadence = configured value)."""
    return TelemetrySnapshot(
        step=jnp.zeros((), jnp.int32),
        xi=jnp.zeros((n_factored,), jnp.float32),
        k=jnp.zeros((n_factored,), jnp.float32),
        k_frac=jnp.zeros((n_factored,), jnp.float32),
        clip_rate=jnp.zeros((n_leaves,), jnp.float32),
        did_refresh=jnp.zeros((), jnp.float32),
        refresh_steps=jnp.zeros((), jnp.int32),
        fold_steps=jnp.zeros((), jnp.int32),
        refresh_every=jnp.asarray(refresh_every, jnp.int32),
        leaf_indices=tuple(leaf_indices),
        dense_indices=tuple(dense_indices),
    )


def snapshot_spec(snap):
    """Sharding spec: every telemetry leaf is replicated (scalars and tiny
    per-leaf vectors — there is nothing to shard).  Works for both
    ``TelemetrySnapshot`` and ``SketchSnapshot``."""
    return jax.tree.map(lambda _: P(), snap)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SketchSnapshot:
    """Per-step telemetry for one ``scale_by_sketch`` instance.

    step:         int32 scalar — optimizer step the snapshot describes.
    occupancy:    (n_sketched,) f32 — per sketched leaf, the fraction of
                  (depth x width) buckets holding any mass.  Climbs toward
                  1.0 as rows touch the table; a saturated sketch is the
                  signal to widen it.
    overestimate: (n_sketched,) f32 — collision over-estimate proxy: total
                  queried mass over total table mass (one depth row holds
                  the whole EMA'd G^2 mass).  >= 1 by the count-min bound;
                  == 1 exactly when no rows collide.
    leaf_indices: static tuple — flat param index of each entry, in
                  ``jax.tree.flatten(params)`` order.
    """

    step: jnp.ndarray
    occupancy: jnp.ndarray
    overestimate: jnp.ndarray
    leaf_indices: tuple = dataclasses.field(
        default=(), metadata=dict(static=True))


def init_sketch_snapshot(n_sketched: int,
                         leaf_indices: tuple = ()) -> SketchSnapshot:
    """The step-0 sketch snapshot (empty table: occupancy 0, ratio 1)."""
    return SketchSnapshot(
        step=jnp.zeros((), jnp.int32),
        occupancy=jnp.zeros((n_sketched,), jnp.float32),
        overestimate=jnp.ones((n_sketched,), jnp.float32),
        leaf_indices=tuple(leaf_indices),
    )
