"""``TelemetryRuntime`` — the train loop's single telemetry handle.

Owns the JSONL sink and the closed-loop refresh controller, and runs the
per-step host side of the subsystem:

    state, metrics = jitted_step(state, batch)     # snapshot rides inside
    state = runtime.on_step(step, state)           # fetch -> emit -> control

``on_step`` fetches the (replicated, scalar-sized) snapshots from the
optimizer state — the loop has already blocked on the loss, so this adds
no extra device sync — emits one ``optimizer`` event per group per
``emit_every`` steps, feeds the controller, and when the controller moves
a group's cadence, writes the new traced scalar back into the state
(:func:`repro.telemetry.collect.set_refresh_every`; zero recompilation).

Checkpoint integration: :meth:`manifest_meta` returns the controller
state + current cadences for the checkpoint manifest, and
:meth:`restore_meta` reloads them, so a killed-and-restored run
reproduces the exact cadence-change sequence (the cadence scalar itself
lives in the optimizer state and restores with it).  :meth:`flush` rides
the preemption handler chain (sink drained before the signal is handed
on).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional

import jax
import numpy as np

log = logging.getLogger(__name__)

from repro.config import TelemetryConfig
from repro.telemetry import collect
from repro.telemetry.controller import ControllerConfig, RefreshController
from repro.telemetry.sink import SinkConfig, TelemetrySink


def _controller_cfg(cfg: TelemetryConfig) -> ControllerConfig:
    return ControllerConfig(
        interval=cfg.interval, t_min=cfg.t_min, t_max=cfg.t_max,
        xi_high=cfg.xi_high, xi_low=cfg.xi_low,
        relax_patience=cfg.relax_patience, tighten_div=cfg.tighten_div,
        relax_add=cfg.relax_add)


class TelemetryRuntime:
    def __init__(self, cfg: TelemetryConfig,
                 sink: Optional[TelemetrySink] = None):
        self.cfg = cfg
        if sink is None and cfg.dir is not None:
            sink = TelemetrySink(SinkConfig(directory=cfg.dir,
                                            rotate_bytes=cfg.rotate_bytes))
        self.sink = sink
        self.controller = (RefreshController(_controller_cfg(cfg))
                           if cfg.auto_refresh else None)
        self.cadence_log: "list[tuple[int, str, int, int]]" = []
        self._cadence: "dict[str, int]" = {}
        self._checked_dynamic = False
        self._warned_no_snaps = False
        # last-seen cumulative guard counters, so fault events fire only
        # on transitions (the counters ride the state every step)
        self._fault_seen: "dict[tuple, int]" = {}

    # -- resilience guards -------------------------------------------------
    def _observe_faults(self, step: int, opt_state) -> bool:
        """Diff the guard counters against the last step, emit one
        ``kind="fault"`` event per transition (bypassing ``emit_every`` —
        faults are rare and always worth a line), and return whether ANY
        guard activity happened this step (the controller's anomaly
        flag)."""
        gs = collect.chain_guard_state(opt_state)
        guards = collect.named_guard_states(opt_state)
        if gs is None and not guards:
            return False
        anomaly = False

        def bump(key, now, event: dict) -> None:
            nonlocal anomaly
            prev = self._fault_seen.get(key, 0)
            if now > prev:
                anomaly = True
                if self.sink is not None:
                    self.sink.emit(event)
            self._fault_seen[key] = now

        if gs is not None:
            skipped = int(np.asarray(gs.skipped))
            bump(("skip",), skipped, {
                "kind": "fault", "step": int(step), "group": "chain",
                "event": "skip", "skipped": skipped,
                "last_skip": int(np.asarray(gs.last_skip))})
        for name, g in sorted(guards.items()):
            trips = int(np.asarray(g.trip_total))
            demos = int(np.asarray(g.demotions))
            bump(("trip", name), trips, {
                "kind": "fault", "step": int(step), "group": name,
                "event": "xi_trip", "trips": trips})
            bump(("demote", name), demos, {
                "kind": "fault", "step": int(step), "group": name,
                "event": "demote", "demotions": demos})
        return anomaly

    # -- per-step ----------------------------------------------------------
    def on_step(self, step: int, state):
        """Process one completed step.  ``state`` is the TrainState the
        jitted step returned (or a bare optimizer state); returns it,
        possibly with retuned cadence scalars."""
        opt_state = getattr(state, "opt_state", state)
        anomaly = self._observe_faults(step, opt_state)
        sketch_snaps = collect.named_sketch_snapshots(opt_state)
        if sketch_snaps and self.sink is not None \
                and step % self.cfg.emit_every == 0:
            for name, snap in sorted(jax.device_get(sketch_snaps).items()):
                self.sink.emit(self._sketch_event(step, name, snap))
        snaps = collect.named_snapshots(opt_state)
        if self.controller is not None and not self._checked_dynamic:
            # Fail on the FIRST step, not at the first cadence decision
            # (which lands interval steps — possibly hours — into the
            # run): auto_refresh needs in-jit collection to observe xi
            # AND at least one group with a traced cadence to act on.
            # This must run before the empty-snapshots early return, or
            # a collection-off optimizer trains the whole run at a fixed
            # cadence while the operator believes the loop is closed.
            if not snaps:
                raise ValueError(
                    "auto_refresh is on but the optimizer carries no "
                    "telemetry snapshots; build it with telemetry=True")
            if all(v is None
                   for v in collect.get_refresh_every(opt_state).values()):
                raise ValueError(
                    "auto_refresh is on but no optimizer group carries a "
                    "dynamic refresh cadence; build the optimizer with "
                    "dynamic_refresh=True")
            self._checked_dynamic = True
        if not snaps:
            if not self._warned_no_snaps and self.cfg.enabled \
                    and not sketch_snaps:
                # Sink-only misconfig (optimizer built without
                # telemetry=True): no error — the stream legitimately
                # carries straggler events for non-adapprox optimizers —
                # but say it once instead of silently emitting nothing.
                log.warning(
                    "telemetry runtime is enabled but the optimizer "
                    "carries no snapshots; no optimizer events will be "
                    "emitted (build it with telemetry=True to collect)")
                self._warned_no_snaps = True
            return state
        if self.controller is None and not (
                self.sink is not None and step % self.cfg.emit_every == 0):
            # nothing will consume the snapshots this step: skip the
            # device fetch — emit_every exists to bound telemetry
            # overhead, and the host round-trip is the dominant cost
            return state
        host = jax.device_get(snaps)
        changes = {}
        for name in sorted(host):
            snap = host[name]
            t_now = int(np.asarray(snap.refresh_every))
            self._cadence[name] = t_now
            if self.sink is not None and step % self.cfg.emit_every == 0:
                self.sink.emit(self._optimizer_event(step, name, snap))
            if self.controller is not None and snap.xi.shape[0] > 0:
                # guard activity anywhere this step pauses relaxation for
                # every group's current interval — a burst that poisons
                # one group's gradients rarely respects group boundaries
                change = self.controller.observe(
                    step, name, float(np.mean(snap.xi)), t_now,
                    anomaly=anomaly)
                if change is not None:
                    changes[name] = change.new
                    self.cadence_log.append(
                        (change.step, name, change.old, change.new))
                    if self.sink is not None:
                        self.sink.emit({
                            "kind": "cadence", "step": change.step,
                            "group": name, "old": change.old,
                            "new": change.new,
                            "interval_mean_xi": change.interval_mean_xi})
        if changes:
            new_opt = collect.set_refresh_every(opt_state, changes)
            self._cadence.update(changes)
            if opt_state is state:
                return new_opt
            return dataclasses.replace(state, opt_state=new_opt)
        return state

    @staticmethod
    def _optimizer_event(step: int, group: str, snap) -> dict:
        ev = {
            "kind": "optimizer", "step": int(step), "group": group,
            "refresh_every": int(np.asarray(snap.refresh_every)),
            "did_refresh": bool(np.asarray(snap.did_refresh) > 0),
            "refresh_steps": int(np.asarray(snap.refresh_steps)),
            "fold_steps": int(np.asarray(snap.fold_steps)),
            "clip_rate": float(np.mean(snap.clip_rate)),
        }
        if snap.xi.shape[0] > 0:
            xi = np.asarray(snap.xi)
            k = np.asarray(snap.k)
            kf = np.asarray(snap.k_frac)
            ev.update(xi=xi.tolist(), k=k.tolist(), k_frac=kf.tolist(),
                      mean_xi=float(xi.mean()), max_xi=float(xi.max()),
                      mean_k=float(k.mean()), mean_k_frac=float(kf.mean()),
                      leaf_indices=list(snap.leaf_indices))
        return ev

    @staticmethod
    def _sketch_event(step: int, group: str, snap) -> dict:
        ev = {"kind": "sketch", "step": int(step), "group": group}
        occ = np.asarray(snap.occupancy)
        over = np.asarray(snap.overestimate)
        if occ.shape[0] > 0:
            ev.update(occupancy=occ.tolist(), overestimate=over.tolist(),
                      mean_occupancy=float(occ.mean()),
                      max_occupancy=float(occ.max()),
                      mean_overestimate=float(over.mean()),
                      max_overestimate=float(over.max()),
                      leaf_indices=list(snap.leaf_indices))
        else:
            # the group exists but owns no sketched leaves this run
            ev.update(mean_occupancy=0.0, mean_overestimate=1.0)
        return ev

    # -- checkpoint integration --------------------------------------------
    def manifest_meta(self) -> dict:
        """Controller state + dynamic cadences for the checkpoint
        manifest (JSON-safe)."""
        meta = {"cadence": dict(self._cadence),
                "cadence_log": [list(c) for c in self.cadence_log]}
        if self.controller is not None:
            meta["controller"] = self.controller.state_dict()
        return {"telemetry": meta}

    def restore_meta(self, meta: Optional[dict]) -> None:
        tel = (meta or {}).get("telemetry")
        if not tel:
            return
        self._cadence = {k: int(v) for k, v in tel.get("cadence", {}).items()}
        self.cadence_log = [tuple(c) for c in tel.get("cadence_log", [])]
        if self.controller is not None and "controller" in tel:
            self.controller.load_state_dict(tel["controller"])

    # -- lifecycle -----------------------------------------------------------
    def flush(self) -> None:
        if self.sink is not None:
            self.sink.flush()

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()
