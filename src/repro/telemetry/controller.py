"""Closed-loop refresh-cadence controller.

The paper's mechanism is *adaptive* approximation: rank follows the
observed relative error xi.  The amortized-refresh runtime (PR 2) added a
second lever — ``refresh_every``, how often the basis Q is re-computed —
but left it a static constant.  This controller closes that loop: it
watches the per-group interval-mean xi that the in-jit snapshot already
carries and retunes the (traced) cadence per parameter group.

Policy (hysteresis band, per group, evaluated every ``interval`` steps):

  * TIGHTEN — interval-mean xi >= ``xi_high`` (the approximation is
    drifting toward the warm-start guard ``warm_drift_xi``): divide the
    cadence by ``tighten_div`` (refresh more often).  Tightening reacts
    immediately (error is expensive) and resets the relax streak.
  * RELAX — interval-mean xi <= ``xi_low`` for ``relax_patience``
    CONSECUTIVE intervals (the frozen basis is tracking well): add
    ``relax_add`` to the cadence (refresh less often).  Relaxing is slow
    and additive; tightening is fast and multiplicative — the usual
    AIMD-style asymmetry that keeps the loop stable.
  * In the dead band between the thresholds nothing moves (and the relax
    streak resets), so the cadence cannot oscillate on noise.
  * ANOMALY PAUSE — when the runtime reports guard activity in an
    interval (skip-steps, xi trips, demotions; ``observe(...,
    anomaly=True)``), relaxation is suppressed for that interval and the
    calm streak resets: an xi average over steps where the guard was
    skipping poisoned updates says nothing about how well the frozen
    basis tracks.  Tightening stays armed — a fault burst is exactly
    when refreshing MORE often helps.

Cadences are clamped to ``[t_min, t_max]``.

Determinism: the controller is a pure fold over the observed
``(step, group, xi)`` sequence — no wall-clock, no RNG — and its full
state round-trips through :meth:`state_dict` (stored in checkpoint
manifests by the train loop).  A run killed and restored mid-interval
therefore reproduces the identical cadence-change sequence
(tests/test_train_integration.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    interval: int = 25            # steps between cadence decisions
    t_min: int = 1
    t_max: int = 50
    xi_high: float = 0.25         # tighten when interval-mean xi >= this
    xi_low: float = 0.10          # relax when <= this (with patience)
    relax_patience: int = 2       # consecutive calm intervals before relaxing
    tighten_div: int = 2          # T <- max(t_min, T // tighten_div)
    relax_add: int = 1            # T <- min(t_max, T + relax_add)

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
        if not (1 <= self.t_min <= self.t_max):
            raise ValueError(f"need 1 <= t_min <= t_max, "
                             f"got [{self.t_min}, {self.t_max}]")
        if self.xi_low > self.xi_high:
            raise ValueError(f"hysteresis band inverted: xi_low "
                             f"{self.xi_low} > xi_high {self.xi_high}")
        if self.tighten_div < 2:
            raise ValueError("tighten_div must be >= 2")


@dataclasses.dataclass
class CadenceChange:
    step: int
    group: str
    old: int
    new: int
    interval_mean_xi: float


class RefreshController:
    """Deterministic per-group cadence feedback.  Feed
    :meth:`observe` once per step per group; it returns a
    :class:`CadenceChange` on the interval boundaries where the policy
    decides to move, else ``None``."""

    def __init__(self, cfg: ControllerConfig = ControllerConfig()):
        self.cfg = cfg
        # group -> {"xi_sum": float, "n": int, "calm": int}
        self._groups: dict = {}

    def _g(self, group: str) -> dict:
        return self._groups.setdefault(
            group, {"xi_sum": 0.0, "n": 0, "calm": 0, "anomalies": 0})

    def observe(self, step: int, group: str, xi: float, t_now: int,
                anomaly: bool = False) -> Optional[CadenceChange]:
        """``anomaly=True`` flags guard activity at this step (skip-step,
        xi trip or demotion): the current interval will not relax."""
        cfg = self.cfg
        g = self._g(group)
        g["xi_sum"] += float(xi)
        g["n"] += 1
        if anomaly:
            g["anomalies"] = g.get("anomalies", 0) + 1
        if step % cfg.interval != 0:
            return None
        mean = g["xi_sum"] / max(g["n"], 1)
        burst = g.get("anomalies", 0) > 0
        g["xi_sum"], g["n"], g["anomalies"] = 0.0, 0, 0
        if mean >= cfg.xi_high:
            g["calm"] = 0
            new_t = max(cfg.t_min, min(cfg.t_max,
                                       int(t_now) // cfg.tighten_div))
        elif burst:
            # faults this interval: hold the cadence, reset the streak
            g["calm"] = 0
            return None
        elif mean <= cfg.xi_low:
            g["calm"] += 1
            if g["calm"] < cfg.relax_patience:
                return None
            g["calm"] = 0
            new_t = max(cfg.t_min, min(cfg.t_max, int(t_now) + cfg.relax_add))
        else:
            g["calm"] = 0
            return None
        if new_t == int(t_now):
            return None
        return CadenceChange(step=int(step), group=group, old=int(t_now),
                             new=new_t, interval_mean_xi=mean)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-safe full state (floats round-trip exactly through JSON)."""
        return {"groups": {k: dict(v) for k, v in self._groups.items()}}

    def load_state_dict(self, state: dict) -> None:
        # ``anomalies`` entered the state with the resilience layer;
        # manifests written before it load as 0 (no anomaly observed).
        self._groups = {k: {"xi_sum": float(v["xi_sum"]), "n": int(v["n"]),
                            "calm": int(v["calm"]),
                            "anomalies": int(v.get("anomalies", 0))}
                        for k, v in state.get("groups", {}).items()}
