"""CLI: validate a telemetry JSONL event stream against the schema.

    python -m repro.telemetry.validate DIR_OR_FILE [--min-events N]

Exits 0 when every event parses and conforms (and at least ``N`` events
exist, default 1 — an empty stream usually means the producer was never
wired up); exits 1 with a diagnostic otherwise.  CI runs this against
the artifacts the dry-run smoke emits.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.telemetry.sink import validate_dir, validate_file


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="telemetry directory or one .jsonl file")
    ap.add_argument("--min-events", type=int, default=1)
    ap.add_argument("--prefix", default="events")
    args = ap.parse_args(argv)

    p = Path(args.path)
    try:
        if p.is_dir():
            n = validate_dir(p, prefix=args.prefix)
        else:
            n = validate_file(p)
    except (ValueError, OSError) as e:
        print(f"INVALID: {e}", file=sys.stderr)
        return 1
    if n < args.min_events:
        print(f"INVALID: {n} events found, expected >= {args.min_events}",
              file=sys.stderr)
        return 1
    print(f"OK: {n} events conform to schema")
    return 0


if __name__ == "__main__":
    sys.exit(main())
