"""CLI: validate a telemetry JSONL event stream against the schema.

    python -m repro.telemetry.validate DIR_OR_FILE [--min-events N]
    python -m repro.telemetry.validate RUNS_DIR --glob '**/events-*.jsonl'

Exits 0 when every event parses and conforms (and at least ``N`` events
exist, default 1 — an empty stream usually means the producer was never
wired up); exits 1 with a diagnostic otherwise — an unknown ``kind`` is
a schema violation, never skipped.  ``--glob`` validates nested run
directories (one parent holding many per-run telemetry dirs) in one
pass.  CI runs this against the artifacts the dry-run and observability
smokes emit.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.telemetry.sink import validate_dir, validate_file


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="telemetry directory or one .jsonl file")
    ap.add_argument("--min-events", type=int, default=1)
    ap.add_argument("--prefix", default="events")
    ap.add_argument("--glob", default=None,
                    help="validate every file matching this pattern "
                         "under PATH (e.g. '**/events-*.jsonl' for "
                         "nested run dirs) instead of the flat "
                         "<prefix>-*.jsonl layout")
    args = ap.parse_args(argv)

    p = Path(args.path)
    try:
        if args.glob is not None:
            if not p.is_dir():
                raise ValueError(f"--glob needs a directory, "
                                 f"got {p}")
            files = sorted(p.glob(args.glob), key=str)
            if not files:
                raise ValueError(f"no files match {args.glob!r} "
                                 f"under {p}")
            n = sum(validate_file(f) for f in files)
        elif p.is_dir():
            n = validate_dir(p, prefix=args.prefix)
        else:
            n = validate_file(p)
    except (ValueError, OSError) as e:
        print(f"INVALID: {e}", file=sys.stderr)
        return 1
    if n < args.min_events:
        print(f"INVALID: {n} events found, expected >= {args.min_events}",
              file=sys.stderr)
        return 1
    print(f"OK: {n} events conform to schema")
    return 0


if __name__ == "__main__":
    sys.exit(main())
