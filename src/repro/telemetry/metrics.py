"""Process-wide metrics registry: counters, gauges, histograms.

Deterministic, dependency-free metrics with two exposition surfaces:

  * ``MetricsRegistry.render()`` — Prometheus text format (``# HELP`` /
    ``# TYPE`` + samples; histograms as cumulative ``_bucket{le=...}`` +
    ``_sum`` / ``_count``), suitable for a textfile collector or any
    scraper; ``parse_prometheus`` is the matching stdlib parser the
    round-trip test pins the format with.
  * ``MetricsRegistry.snapshot(t_s)`` — a ``kind="metric"`` JSONL event
    for the shared telemetry sink, so periodic metric snapshots ride the
    same stream (and the same ``validate_dir``) as spans and serve
    events.  Sample keys in the snapshot are EXACTLY the Prometheus
    sample names (``name{label="v"}``), so the two surfaces agree.

Histogram bucket boundaries are FIXED (``DEFAULT_BUCKETS``, overridable
per histogram at first creation only) so output across runs is
deterministic and diffs cleanly.

Signal-safety: all mutation is plain-dict arithmetic under the GIL — no
locks — so the tracer may observe span durations into a histogram from
the preemption handler's ``drain_open`` without deadlock (the same rule
the sink's lock-free deque enforces; see sink.py's module docstring).
"""
from __future__ import annotations

import bisect
import re
from typing import Optional

# second-scaled latency buckets: 0.5ms .. 10s, fixed for determinism
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _label_str(labels: dict) -> str:
    """Canonical (sorted, escaped) Prometheus label block; "" if none."""
    if not labels:
        return ""
    parts = []
    for k, v in sorted(labels.items()):
        if not _NAME_RE.match(k):
            raise ValueError(f"bad label name {k!r}")
        v = str(v).replace("\\", r"\\").replace('"', r'\"')
        v = v.replace("\n", r"\n")
        parts.append(f'{k}="{v}"')
    return "{" + ",".join(parts) + "}"


def _merge_le(label_key: str, le: str) -> str:
    if not label_key:
        return f'{{le="{le}"}}'
    return label_key[:-1] + f',le="{le}"}}'


def _fmt(v: float) -> str:
    return format(float(v), "g")


class Counter:
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: "dict[str, float]" = {}

    def inc(self, n: float = 1.0, **labels) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        key = _label_str(labels)
        self._values[key] = self._values.get(key, 0.0) + n

    def value(self, **labels) -> float:
        return self._values.get(_label_str(labels), 0.0)

    def samples(self) -> dict:
        return {self.name + k: v for k, v in sorted(self._values.items())}


class Gauge:
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: "dict[str, float]" = {}

    def set(self, v: float, **labels) -> None:
        self._values[_label_str(labels)] = float(v)

    def value(self, **labels) -> float:
        return self._values.get(_label_str(labels), 0.0)

    def samples(self) -> dict:
        return {self.name + k: v for k, v in sorted(self._values.items())}


class Histogram:
    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets=DEFAULT_BUCKETS):
        self.name, self.help = name, help
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError("buckets must be strictly increasing")
        self._counts: "dict[str, list]" = {}   # per-bucket (+overflow)
        self._sums: "dict[str, float]" = {}
        self._totals: "dict[str, int]" = {}

    def observe(self, v: float, **labels) -> None:
        key = _label_str(labels)
        row = self._counts.get(key)
        if row is None:
            row = self._counts[key] = [0] * (len(self.buckets) + 1)
            self._sums[key] = 0.0
            self._totals[key] = 0
        # le semantics: v lands in the first bucket with v <= bound
        row[bisect.bisect_left(self.buckets, v)] += 1
        self._sums[key] += float(v)
        self._totals[key] += 1

    def count(self, **labels) -> int:
        return self._totals.get(_label_str(labels), 0)

    def sum(self, **labels) -> float:
        return self._sums.get(_label_str(labels), 0.0)

    def samples(self) -> dict:
        out = {}
        for key in sorted(self._counts):
            out[self.name + key] = {
                "buckets": list(self.buckets),
                "counts": list(self._counts[key]),
                "sum": self._sums[key],
                "count": self._totals[key],
            }
        return out


class MetricsRegistry:
    """Get-or-create registry; re-registering a name with a different
    metric type (or different histogram buckets) is a programming error
    and raises."""

    def __init__(self):
        self._metrics: "dict[str, object]" = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            if not _NAME_RE.match(name):
                raise ValueError(f"bad metric name {name!r}")
            m = self._metrics[name] = cls(name, help, **kwargs)
        elif type(m) is not cls:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}, not {cls.__name__}")
        elif kwargs.get("buckets") is not None \
                and tuple(float(b) for b in kwargs["buckets"]) != m.buckets:
            raise ValueError(f"histogram {name!r} already registered "
                             f"with different buckets")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> Histogram:
        return self._get(Histogram, name, help,
                         buckets=buckets if buckets is not None
                         else DEFAULT_BUCKETS)

    def clear(self) -> None:
        self._metrics.clear()

    # -- exposition --------------------------------------------------------
    def snapshot(self, t_s: float, step: Optional[int] = None) -> dict:
        """One ``kind="metric"`` event for the telemetry sink."""
        counters, gauges, histograms = {}, {}, {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                counters.update(m.samples())
            elif isinstance(m, Gauge):
                gauges.update(m.samples())
            else:
                histograms.update(m.samples())
        ev = {"kind": "metric", "t_s": round(float(t_s), 6),
              "counters": counters, "gauges": gauges,
              "histograms": histograms}
        if step is not None:
            ev["step"] = int(step)
        return ev

    def render(self) -> str:
        """Prometheus text exposition format."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, (Counter, Gauge)):
                for key in sorted(m._values):
                    lines.append(f"{name}{key} {_fmt(m._values[key])}")
            else:
                for key in sorted(m._counts):
                    cum = 0
                    for bound, c in zip(m.buckets, m._counts[key]):
                        cum += c
                        lines.append(f"{name}_bucket"
                                     f"{_merge_le(key, _fmt(bound))} {cum}")
                    cum += m._counts[key][-1]
                    lines.append(f"{name}_bucket"
                                 f"{_merge_le(key, '+Inf')} {cum}")
                    lines.append(f"{name}_sum{key} {_fmt(m._sums[key])}")
                    lines.append(f"{name}_count{key} {m._totals[key]}")
        return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> dict:
    """Parse Prometheus text exposition back into ``{"types": {name:
    type}, "help": {name: text}, "samples": {sample_name: value}}`` —
    the round-trip half of the exposition contract (label values must
    not contain a literal space followed by nothing; values are the last
    space-separated token, as the format specifies)."""
    types, helps, samples = {}, {}, {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.rstrip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, typ = line.split(" ", 3)
            types[name] = typ
        elif line.startswith("# HELP "):
            _, _, name, rest = line.split(" ", 3)
            helps[name] = rest
        elif line.startswith("#"):
            continue
        else:
            try:
                key, val = line.rsplit(" ", 1)
                samples[key] = float(val)
            except ValueError as e:
                raise ValueError(f"line {lineno}: {line!r}: {e}") from e
    return {"types": types, "help": helps, "samples": samples}


_DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry the launchers and engines share."""
    return _DEFAULT_REGISTRY
