"""repro.telemetry — optimizer observability + closed-loop refresh control.

The paper's mechanism is *adaptive*: rank follows the observed relative
error xi.  This package makes that observability a production surface and
closes the remaining control loop (the S-RSI refresh cadence) on top of
it.  Three layers:

  In-jit collection (snapshot.py; gated by ``AdapproxConfig.telemetry``)
      ``TelemetrySnapshot`` — a fixed-shape pytree of per-leaf xi,
      effective rank k, rank occupancy k/k_max, update-RMS clip
      activation, refresh-vs-fold step counters and the cadence in
      effect — assembled inside ``scale_by_adapprox.update`` from values
      the update already computes (updates stay BITWISE identical to
      telemetry-off) and carried in the optimizer state: it rides the
      sharded train step (every leaf replicated, ``snapshot_spec``),
      checkpoints with the state, and reaches the host on the train
      loop's existing post-step sync.  ``collect.py`` walks any
      chain/partition state for named snapshots and scalar aggregates
      (``telemetry_metrics`` runs inside the jitted step).

  Host-side sink (sink.py)
      ``TelemetrySink`` — async, buffered JSONL writer with size-based
      rotation (``events-NNNNN.jsonl``).  ONE event stream, one schema,
      shared by the optimizer snapshots, cadence decisions, the
      straggler monitor and the dry-run driver; ``validate_event`` /
      ``validate_dir`` are the machine-checkable schema CI runs
      (``python -m repro.telemetry.validate DIR``).

  Closed-loop controller (controller.py + runtime.py; ``--auto-refresh``)
      ``RefreshController`` — deterministic, checkpointable hysteresis
      feedback that retunes ``refresh_every`` per parameter group from
      observed xi drift: tighten (divide) when xi regresses toward the
      warm-start drift guard, relax (add) after sustained calm, dead
      band in between.  Requires ``AdapproxConfig.dynamic_refresh``,
      which carries the cadence as a traced int32 state scalar — retunes
      NEVER recompile (tests/test_telemetry.py pins the jit cache size).
      ``TelemetryRuntime`` is the train-loop handle tying all three
      together (``train_loop.train(..., telemetry=runtime)``).

JSONL event schema (version 1; authoritative machine form in
``sink.EVENT_SCHEMA``).  Every line is one JSON object with ``"schema":
1`` and a ``"kind"``:

  kind="optimizer"  — one per Adapprox group per ``emit_every`` steps:
      step, group, refresh_every, did_refresh, refresh_steps, fold_steps,
      clip_rate; plus per-leaf vectors xi / k / k_frac (+ leaf_indices
      into param flatten order) and mean/max aggregates when the group
      has factored leaves.
  kind="sketch"     — one per count-min sketch group (``scale_by_sketch``
      with ``telemetry``) per ``emit_every`` steps:
      step, group, mean_occupancy (fraction of depth x width buckets
      holding mass, averaged over sketched leaves), mean_overestimate
      (collision proxy: queried mass over table mass, >= 1, == 1 with no
      collisions); plus per-leaf vectors occupancy / overestimate
      (+ leaf_indices into param flatten order) and max aggregates when
      the group owns sketched leaves.
  kind="cadence"    — a controller decision:
      step, group, old, new, interval_mean_xi.
  kind="straggler"  — StragglerMonitor flag/escalation:
      event ("flagged" | "escalated"), n_steps, step_time_s, median_s
      (+ z, flags).
  kind="dryrun_cell" — one compiled dry-run cell (launch/dryrun.py
      --telemetry-dir): arch, cell, mesh, devices, flops, bytes_accessed
      (+ peak_bytes, collective_bytes, compile_s, params).
  kind="run_meta"   — stream header: source (+ argv, config, note).
  kind="fault"      — resilience-guard activity (repro.resilience;
      emitted by ``TelemetryRuntime`` on counter TRANSITIONS, bypassing
      ``emit_every`` — faults are rare and always worth a line):
      step, group ("chain" for the skip-step wrapper, else the partition
      group label), event ("skip" | "xi_trip" | "demote"); plus the
      cumulative counters skipped/last_skip (skip), trips (xi_trip),
      demotions (demote) — consumers diff consecutive events for rates.
      The controller treats any fault in an interval as an anomaly:
      cadence RELAXATION pauses for that interval (tightening stays
      armed).
  kind="serve"      — serving-engine observability (repro.serve; both
      schedulers stream through the same sink):
      event ("admit" | "first_token" | "finish" | "reject" | "backoff" |
      "stats"), t_s (seconds since run start), scheduler ("wave" |
      "continuous"); plus uid/ttft_s/latency_s/tokens per request,
      queue_depth / occupancy (KV-block pool, incl. reservations) /
      slots_active / tok_per_s on stats lines, and reason on admission
      backoff ("occupancy_watermark" | "reservation").  The continuous
      engine's admission gate is driven by the same occupancy signal it
      emits here.  With a tracer attached, per-request events also
      carry ``trace`` — the span-waterfall join key (below).
  kind="span"       — one host-side timing span (trace.py; train-loop
      phases, engine steps, request lifecycles, checkpoint IO):
      name, trace (waterfall id), span (unique within the trace),
      t0_s / dur_s (seconds on the emitting tracer's monotonic clock);
      plus parent (nesting), step, uid, attrs (free-form dict, e.g. the
      refresh-vs-fold ``phase`` on train_step spans), and truncated
      (true when the preemption drain closed the span early).
  kind="metric"     — periodic MetricsRegistry snapshot (metrics.py):
      t_s, counters / gauges / histograms keyed by PROMETHEUS sample
      name (``name{label="v"}``, identical to the text exposition);
      histogram values are {buckets, counts, sum, count}; plus step.

Trace-id join contract (kind="span" x kind="serve"): each request the
serving engines process under a tracer gets a trace id, stamped into BOTH
its span waterfall (request/queued/admitted/prefill_chunk/decode spans
sharing ``trace``, phases parented under the fixed span id "root") and
its per-request serve events (optional ``trace`` field on admit /
first_token / finish / reject).  A consumer joins the two streams on the
trace id alone; ``trace.check_events`` (CI: ``tools/traceview.py
--check``) enforces that every finished request reconstructs a complete
queued→finish waterfall.
"""
from repro.telemetry.collect import (chain_guard_state, get_refresh_every,
                                     named_guard_states,
                                     named_sketch_snapshots,
                                     named_sketch_states, named_snapshots,
                                     named_states, set_refresh_every,
                                     telemetry_metrics)
from repro.telemetry.controller import (CadenceChange, ControllerConfig,
                                        RefreshController)
from repro.telemetry.metrics import (DEFAULT_BUCKETS, Counter, Gauge,
                                     Histogram, MetricsRegistry,
                                     default_registry, parse_prometheus)
from repro.telemetry.runtime import TelemetryRuntime
from repro.telemetry.trace import (NULL_TRACER, NullTracer, Tracer,
                                   check_events, chrome_trace,
                                   format_breakdown, format_span_stats,
                                   load_events, span_stats, step_breakdown)
from repro.telemetry.sink import (EVENT_SCHEMA, SCHEMA_VERSION, SinkConfig,
                                  TelemetrySink, validate_dir,
                                  validate_event, validate_file)
from repro.telemetry.snapshot import (SketchSnapshot, TelemetrySnapshot,
                                      init_sketch_snapshot, init_snapshot,
                                      snapshot_spec)
