"""Walk optimizer-state pytrees for telemetry: find every Adapprox
instance (chains, ``partition`` groups, arbitrary nesting), name it by its
parameter group, read its :class:`~repro.telemetry.snapshot.TelemetrySnapshot`,
and get/set its dynamic refresh cadence.

Group naming: states inside a ``partition`` are named by their group label
(the ``PartitionState.inner`` dict key, e.g. ``"factored"`` in the
production mixed chain); a bare chain's single instance is ``"default"``.

All functions are pure pytree walks (``tree_map_with_path`` with the
Adapprox state class as the leaf type), so they work on live device
arrays, host arrays, and tracers alike — :func:`telemetry_metrics` runs
INSIDE the jitted train step.  Imports of ``repro.core`` are deferred to
call time to keep ``repro.telemetry`` import-cycle-free (core imports the
snapshot module).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def _adapprox_cls():
    from repro.core.adapprox import AdapproxState
    return AdapproxState


def _sketch_cls():
    from repro.core.sketch import SketchState
    return SketchState


def _group_name(path) -> str:
    """Last dict key on the path (partition group label), else 'default'."""
    name = "default"
    for entry in path:
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            name = key
    return name


def named_states(opt_state) -> "dict[str, Any]":
    """``{group_name: AdapproxState}`` for every Adapprox instance inside
    an (arbitrarily nested) optimizer state."""
    cls = _adapprox_cls()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        opt_state, is_leaf=lambda x: isinstance(x, cls))
    out = {}
    for path, leaf in flat:
        if isinstance(leaf, cls):
            out[_group_name(path)] = leaf
    return out


def named_snapshots(opt_state) -> "dict[str, Any]":
    """``{group_name: TelemetrySnapshot}`` for every Adapprox instance
    that carries one (``cfg.telemetry``); empty dict when telemetry is
    off everywhere.  Sketch instances have their own walker
    (:func:`named_sketch_snapshots`) — their snapshot schema differs."""
    return {name: st.telemetry for name, st in named_states(opt_state).items()
            if st.telemetry is not None}


def named_sketch_states(opt_state) -> "dict[str, Any]":
    """``{group_name: SketchState}`` for every ``scale_by_sketch``
    instance inside an (arbitrarily nested) optimizer state."""
    cls = _sketch_cls()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        opt_state, is_leaf=lambda x: isinstance(x, cls))
    out = {}
    for path, leaf in flat:
        if isinstance(leaf, cls):
            out[_group_name(path)] = leaf
    return out


def named_sketch_snapshots(opt_state) -> "dict[str, Any]":
    """``{group_name: SketchSnapshot}`` for every sketch instance that
    carries one (``cfg.telemetry``); empty dict when telemetry is off."""
    return {name: st.telemetry
            for name, st in named_sketch_states(opt_state).items()
            if st.telemetry is not None}


def _guarded_cls():
    from repro.resilience.guards import GuardedState
    return GuardedState


def named_guard_states(opt_state) -> "dict[str, Any]":
    """``{group_name: GuardState}`` for every Adapprox instance carrying
    xi-guard state (``AdapproxConfig.guards``); empty when guards are off
    everywhere."""
    return {name: st.guards for name, st in named_states(opt_state).items()
            if st.guards is not None}


def chain_guard_state(opt_state):
    """The outermost :class:`~repro.resilience.guards.GuardedState`
    (the chain-level skip-step wrapper) inside ``opt_state``, or ``None``
    when the chain is unguarded.  The wrapper sits at the root, so the
    first instance found IS the chain guard."""
    cls = _guarded_cls()
    for leaf in jax.tree.leaves(
            opt_state, is_leaf=lambda x: isinstance(x, cls)):
        if isinstance(leaf, cls):
            return leaf
    return None


def get_refresh_every(opt_state) -> "dict[str, Optional[int]]":
    """Current refresh cadence per group; ``None`` for groups whose
    cadence is compile-time static (``dynamic_refresh`` off)."""
    import numpy as np
    out = {}
    for name, st in named_states(opt_state).items():
        out[name] = (int(np.asarray(st.refresh_every))
                     if st.refresh_every is not None else None)
    return out


def set_refresh_every(opt_state, changes: "dict[str, int] | int"):
    """Return a copy of ``opt_state`` with the dynamic refresh cadence of
    the named groups replaced (an int applies to every dynamic group).

    The cadence is a traced int32 state scalar, so feeding the returned
    state back into the jitted train step re-uses the compiled executable
    — zero recompilation.  The replacement scalar is placed under the old
    leaf's sharding (replicated) when one exists.  Groups without a
    dynamic cadence (``dynamic_refresh`` off) raise ``ValueError`` when
    named explicitly.
    """
    cls = _adapprox_cls()
    if not isinstance(changes, dict):
        changes = {name: int(changes)
                   for name, st in named_states(opt_state).items()
                   if st.refresh_every is not None}
    applied = set()

    def one(path, leaf):
        if not isinstance(leaf, cls):
            return leaf
        name = _group_name(path)
        if name not in changes:
            return leaf
        if leaf.refresh_every is None:
            raise ValueError(
                f"group {name!r} has no dynamic refresh cadence; build it "
                f"with dynamic_refresh=True to control it at runtime")
        value = int(changes[name])
        if value < 1:
            raise ValueError(f"refresh_every must be >= 1, got {value}")
        applied.add(name)
        new = jnp.asarray(value, jnp.int32)
        old = leaf.refresh_every
        # Mirror the old scalar's placement EXACTLY: device_put yields a
        # COMMITTED array, and a committed-vs-uncommitted argument flips
        # jit's sharding resolution — two silent relowerings right after a
        # cadence change (observed; pinned by the zero-recompile test).
        # Only re-place when the old leaf was itself committed (the
        # mesh-sharded path, where in_shardings expect the placement).
        if getattr(old, "_committed", False) and \
                getattr(old, "sharding", None) is not None:
            new = jax.device_put(new, old.sharding)
        return dataclasses.replace(leaf, refresh_every=new)

    out = jax.tree_util.tree_map_with_path(
        one, opt_state, is_leaf=lambda x: isinstance(x, cls))
    missing = set(changes) - applied
    if missing:
        raise ValueError(f"no Adapprox group named {sorted(missing)}; "
                         f"known: {sorted(named_states(opt_state))}")
    return out


def telemetry_metrics(opt_state) -> dict:
    """Scalar per-group aggregates of every snapshot in ``opt_state`` —
    jit-traceable, so ``train/steps.py`` folds them into the step metrics
    (empty dict when telemetry is off: the metrics pytree is unchanged)."""
    out = {}
    for name, snap in named_snapshots(opt_state).items():
        pre = f"telemetry/{name}/"
        if snap.xi.shape[0] > 0:
            out[pre + "mean_xi"] = jnp.mean(snap.xi)
            out[pre + "max_xi"] = jnp.max(snap.xi)
            out[pre + "mean_k"] = jnp.mean(snap.k)
            out[pre + "mean_k_frac"] = jnp.mean(snap.k_frac)
        out[pre + "clip_rate"] = jnp.mean(snap.clip_rate)
        out[pre + "refresh_every"] = snap.refresh_every
        out[pre + "did_refresh"] = snap.did_refresh
    for name, snap in named_sketch_snapshots(opt_state).items():
        pre = f"telemetry/{name}/"
        if snap.occupancy.shape[0] > 0:
            out[pre + "mean_occupancy"] = jnp.mean(snap.occupancy)
            out[pre + "max_occupancy"] = jnp.max(snap.occupancy)
            out[pre + "mean_overestimate"] = jnp.mean(snap.overestimate)
    gs = chain_guard_state(opt_state)
    if gs is not None:
        out["guard/skipped"] = gs.skipped
        out["guard/last_skip"] = gs.last_skip
    for name, g in named_guard_states(opt_state).items():
        pre = f"guard/{name}/"
        out[pre + "trip_total"] = g.trip_total
        out[pre + "demotions"] = g.demotions
    return out
