"""Deterministic fault injection for the chaos harness.

Every injector here is a pure function of explicit inputs (the step
counter, a byte offset) — never of wall-clock or RNG — so a chaos run
replays bit-identically and tests can assert *exact* counter matches
against the injection schedule.

Three fault families:

  * ``inject_faults(FaultPlan)`` — a ``GradientTransformation`` that
    poisons the gradient tree with NaN/Inf at the exact steps listed in
    the plan.  Chain it BEFORE ``guard_updates`` so the guard sees the
    poisoned gradients the way a real overflow would arrive.
  * ``truncate_file`` / ``flip_bit`` / ``corrupt_latest_checkpoint`` —
    host-side checkpoint corruption, mimicking a kill mid-write
    (truncation) and silent media corruption (bit flip).
  * ``remesh_after_loss`` — the device-loss driver: drops ``lost``
    devices from the current topology and returns the
    ``distributed.elastic`` plan the survivors should restart under.

``tools/chaos.py`` wraps the gradient injector into a CLI smoke run
that emits ``kind="fault"`` telemetry JSONL for the CI artifact.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic NaN/Inf gradient burst schedule.

    Steps are 1-based (the injector's own counter, incremented before
    the check — step 1 is the first update), matching the train loop's
    reported step numbers.
    """

    nan_steps: Tuple[int, ...] = ()
    inf_steps: Tuple[int, ...] = ()

    @property
    def fault_steps(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.nan_steps) | set(self.inf_steps)))


def inject_faults(plan: FaultPlan):
    """Gradient transformation that poisons grads at scheduled steps.

    State is a single int32 step counter; the poisoning decision is
    ``jnp.isin(step, schedule)`` so it stays a traced elementwise select
    (no recompiles, no host sync).  NaN wins when a step is in both
    lists.  With an empty plan this is an exact pass-through.
    """
    from repro.core.types import GradientTransformation

    nan_steps = jnp.asarray(plan.nan_steps or (-1,), jnp.int32)
    inf_steps = jnp.asarray(plan.inf_steps or (-1,), jnp.int32)

    def init(params):
        del params
        return jnp.zeros((), jnp.int32)

    def update(grads, state, params=None):
        del params
        step = state + 1
        hit_nan = jnp.any(step == nan_steps)
        hit_inf = jnp.any(step == inf_steps)

        def poison(g):
            g = jnp.where(hit_inf, jnp.full_like(g, jnp.inf), g)
            return jnp.where(hit_nan, jnp.full_like(g, jnp.nan), g)

        return jax.tree.map(poison, grads), step

    def spec(state, param_specs):
        del param_specs
        return P()

    return GradientTransformation(init, update, spec)


# ---------------------------------------------------------------------------
# Checkpoint corruption (host side)
# ---------------------------------------------------------------------------

def truncate_file(path: str, keep_bytes: int) -> None:
    """Cut ``path`` down to its first ``keep_bytes`` bytes (kill mid-write)."""
    with open(path, "r+b") as f:
        f.truncate(max(0, keep_bytes))


def flip_bit(path: str, byte_offset: int, bit: int = 0) -> None:
    """Flip one bit in place (silent media corruption)."""
    with open(path, "r+b") as f:
        f.seek(byte_offset)
        b = f.read(1)
        if not b:
            raise ValueError(f"{path}: offset {byte_offset} past EOF")
        f.seek(byte_offset)
        f.write(bytes([b[0] ^ (1 << bit)]))


def corrupt_latest_checkpoint(directory: str, kind: str = "truncate") -> str:
    """Damage the newest committed checkpoint's largest leaf file.

    kind="truncate": cut the file in half (detected by the cheap
    structural size check, so even ``latest_step()`` skips it).
    kind="bitflip": flip one payload bit (sizes stay right — only the
    deep sha256 verify in ``restore()`` can catch it).
    kind="manifest": truncate manifest.json itself.
    Returns the path of the file that was damaged.
    """
    from repro.checkpoint.serialization import list_checkpoints

    committed = list_checkpoints(directory)
    if not committed:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    step_dir = str(committed[-1])
    if kind == "manifest":
        target = os.path.join(step_dir, "manifest.json")
        truncate_file(target, os.path.getsize(target) // 2)
        return target
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    files = [os.path.join(step_dir, leaf["file"])
             for leaf in manifest["leaves"]]
    target = max(files, key=os.path.getsize)
    if kind == "truncate":
        truncate_file(target, os.path.getsize(target) // 2)
    elif kind == "bitflip":
        # flip inside the payload, past the .npy header
        flip_bit(target, os.path.getsize(target) - 1, bit=3)
    else:
        raise ValueError(f"unknown corruption kind: {kind!r}")
    return target


# ---------------------------------------------------------------------------
# Device loss
# ---------------------------------------------------------------------------

def remesh_after_loss(lost: int, target_model: int = 16,
                      available_devices: Optional[int] = None):
    """Mesh plan for the survivors after losing ``lost`` devices.

    Simulated device loss: the chaos harness shrinks the visible device
    count and asks ``distributed.elastic`` for the mesh the restarted
    job should build, then restores the checkpoint under it (placement
    happens at load — PR-3 resharding restore does the heavy lifting).
    """
    from repro.distributed.elastic import plan_remesh

    n = (available_devices if available_devices is not None
         else len(jax.devices()))
    survivors = n - lost
    if survivors < 1:
        raise ValueError(f"lost {lost} of {n} devices — nothing left")
    return plan_remesh(survivors, target_model=target_model)
