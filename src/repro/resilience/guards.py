"""In-jit numerical health guards with graceful degradation.

Two enforcement levels, both running INSIDE the jitted train step so a
fault never needs a host round-trip to be contained:

  * :func:`guard_updates` — the chain-level **skip-step** wrapper.  It
    checks every gradient leaf AND every final update leaf for
    non-finite values; on a trip the updates are zeroed and the whole
    inner optimizer state reverts, so params and every EMA are exactly
    what they were before the poisoned step (weight decay included —
    that is why the wrapper sits OUTSIDE the chain, not inside the
    preconditioner).  Only the :class:`GuardedState` counters advance.

  * ``scale_by_adapprox`` xi guards — per-factored-leaf degradation
    driven by :class:`GuardState` (carried in ``AdapproxState.guards``
    when ``AdapproxConfig.guards`` is set): a leaf whose approximation
    error xi blows past ``GuardConfig.xi_trip`` gets a FORCED full
    S-RSI refresh on the next step (overriding the fold cadence), and
    after ``max_demotions`` consecutive trips the leaf falls back to
    the exact dense second moment (per-leaf ``lax.cond`` dispatch; the
    dense buffer is seeded from the factored reconstruction
    ``max(Q Uᵀ, 0)`` at demotion time, so the EMA continues without a
    cold restart).

This module keeps NO module-level ``repro`` imports (the core package
imports it during its own init); the one ``repro.core.types`` dependency
is resolved lazily inside :func:`guard_updates`.

Everything is default-off: ``AdapproxConfig.guards is None`` and an
unwrapped chain are bit-identical to the pre-resilience optimizer
(pinned in tests/test_compose.py / tests/test_chaos.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Numerical-guard policy (hashable: rides frozen optimizer configs).

    skip_nonfinite: zero the step and revert the optimizer state when any
        gradient or final-update leaf is non-finite (guard_updates).
    xi_trip: per-leaf xi threshold; above it the leaf's factorization is
        considered blown and a full S-RSI refresh is forced next step.
    max_demotions: consecutive xi trips before the leaf is demoted to the
        exact dense second moment.  0 disables demotion (and the dense
        shadow buffers it needs); forced refreshes still apply.
    """

    skip_nonfinite: bool = True
    xi_trip: float = 0.75
    max_demotions: int = 0


# ---------------------------------------------------------------------------
# Per-Adapprox-instance xi-guard state (lives in AdapproxState.guards)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GuardState:
    """Per-factored-leaf degradation state for one Adapprox instance.

    trips:         (n_factored,) int32 — CONSECUTIVE xi-trip count per
                   leaf (resets to 0 on any calm step).
    force_refresh: (n_factored,) int32 — 1 when the leaf's next step must
                   run a full S-RSI refresh regardless of the cadence.
    demoted:       (n_factored,) int32 — 1 once the leaf runs the exact
                   dense second moment (sticky for the rest of the run).
    trip_total:    int32 scalar — cumulative trip count (telemetry).
    demotions:     int32 scalar — cumulative demotion count (telemetry).
    dense_v:       tuple of (param-shaped) f32 dense second-moment
                   buffers, one per factored leaf, allocated only when
                   ``GuardConfig.max_demotions > 0`` (else empty).
    """

    trips: jnp.ndarray
    force_refresh: jnp.ndarray
    demoted: jnp.ndarray
    trip_total: jnp.ndarray
    demotions: jnp.ndarray
    dense_v: tuple = ()


def init_guard_state(factored_shapes, max_demotions: int) -> GuardState:
    """Fresh guard state for ``len(factored_shapes)`` factored leaves."""
    n = len(factored_shapes)
    dense_v = ()
    if max_demotions > 0:
        dense_v = tuple(jnp.zeros(s, jnp.float32) for s in factored_shapes)
    return GuardState(
        trips=jnp.zeros((n,), jnp.int32),
        force_refresh=jnp.zeros((n,), jnp.int32),
        demoted=jnp.zeros((n,), jnp.int32),
        trip_total=jnp.zeros((), jnp.int32),
        demotions=jnp.zeros((), jnp.int32),
        dense_v=dense_v,
    )


def guard_spec(gstate: GuardState, factored_pspecs) -> GuardState:
    """Sharding spec: counters are replicated scalars / tiny vectors; the
    dense shadow buffers shard exactly like the params they mirror."""
    return GuardState(
        trips=P(), force_refresh=P(), demoted=P(),
        trip_total=P(), demotions=P(),
        dense_v=tuple(factored_pspecs[:len(gstate.dense_v)]),
    )


# ---------------------------------------------------------------------------
# Chain-level skip-step wrapper
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GuardedState:
    """State of :func:`guard_updates`.

    inner:     the wrapped transformation's state (reverted wholesale on
               a skipped step).
    steps:     int32 scalar — steps the guard has seen (its own counter:
               the inner step counter does NOT advance on skips).
    skipped:   int32 scalar — cumulative skip-step count.
    last_skip: int32 scalar — ``steps`` value of the most recent skip
               (0 = never skipped).
    """

    inner: Any
    steps: jnp.ndarray
    skipped: jnp.ndarray
    last_skip: jnp.ndarray


def tree_all_finite(tree) -> jnp.ndarray:
    """Scalar bool: every array leaf of ``tree`` is entirely finite.
    Non-float leaves (int counters, PRNG keys) are finite by definition.
    An empty tree is finite."""
    checks = []
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            checks.append(jnp.all(jnp.isfinite(leaf)))
    if not checks:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(checks))


def guard_updates(inner, cfg: GuardConfig = GuardConfig()):
    """Wrap a whole optimizer chain with the non-finite skip-step guard.

    On a healthy step the wrapper is pass-through (the select lowers to a
    no-op on identical operands).  On a tripped step the returned updates
    are zeros — ``apply_updates`` leaves params untouched — and the inner
    state reverts to its pre-step value, so first/second-moment EMAs,
    step counters and PRNG folding all behave as if the poisoned step
    never ran; only the skip counters advance.  Works on any
    ``GradientTransformation`` (chains, partitions, arbitrary nesting)
    and forwards the ``state_sharding_spec`` protocol.
    """
    from repro.core.types import (GradientTransformation,
                                  state_sharding_spec as _resolve_spec)

    def init(params):
        # one zeros() PER field: sharing a single array across leaves
        # makes donation reject the state ("donate the same buffer twice")
        def z():
            return jnp.zeros((), jnp.int32)
        return GuardedState(inner=inner.init(params), steps=z(),
                            skipped=z(), last_skip=z())

    def update(grads, state: GuardedState, params):
        new_upd, new_inner = inner.update(grads, state.inner, params)
        steps = state.steps + 1
        if not cfg.skip_nonfinite:
            return new_upd, GuardedState(inner=new_inner, steps=steps,
                                         skipped=state.skipped,
                                         last_skip=state.last_skip)
        ok = jnp.logical_and(tree_all_finite(grads),
                             tree_all_finite(new_upd))
        upd = jax.tree.map(
            lambda u: None if u is None else jnp.where(ok, u,
                                                       jnp.zeros_like(u)),
            new_upd, is_leaf=lambda x: x is None)
        kept = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                            new_inner, state.inner)
        return upd, GuardedState(
            inner=kept, steps=steps,
            skipped=state.skipped + jnp.where(ok, 0, 1).astype(jnp.int32),
            last_skip=jnp.where(ok, state.last_skip, steps))

    def spec(state: GuardedState, param_specs):
        return GuardedState(
            inner=_resolve_spec(inner, state.inner, param_specs),
            steps=P(), skipped=P(), last_skip=P())

    from repro.core.types import GradientTransformation
    return GradientTransformation(init, update, spec)
