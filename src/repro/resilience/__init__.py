"""repro.resilience — fault tolerance for the Adapprox training stack.

Low-rank second moments add failure modes dense Adam does not have: a
diverging warm-started S-RSI, a stale fold between refreshes, or a
saturated sketch table can corrupt the preconditioner long before the
loss spikes.  This package is the containment layer — everything is
config-gated and default-off, so the default chain stays bitwise
identical to the unguarded optimizer.  Three pieces:

  In-jit health guards (guards.py; ``OptimizerConfig.guards``)
      ``GuardConfig`` + two enforcement levels, both inside the jitted
      step (no host round-trip on the decision path):

      * **skip-step** — ``guard_updates(transform, cfg)`` wraps the
        WHOLE optimizer chain (weight decay included): when any gradient
        or final-update leaf is non-finite, the step's updates are
        zeroed and the entire inner state reverts — params and every EMA
        are untouched, only the ``GuardedState`` skip counters advance.
      * **graceful degradation** — ``scale_by_adapprox`` watches each
        factored leaf's xi; a blow-up past ``xi_trip`` forces an
        immediate full S-RSI refresh for that leaf on the next step
        (overriding the ``refresh_every`` fold cadence), and after
        ``max_demotions`` CONSECUTIVE trips the leaf is demoted to the
        exact dense second moment (a per-leaf ``lax.cond`` dispatch,
        seeded from the factored reconstruction at demotion time).
        Demotion needs a dense shadow buffer per factored leaf, so it
        only allocates when ``max_demotions > 0``.

      Trips, demotions and skip counters surface as ``kind="fault"``
      telemetry events (repro.telemetry), and the closed-loop refresh
      controller treats them as anomalies: cadence RELAXATION pauses
      during fault bursts (tightening stays armed).

  Hardened checkpoint I/O (repro.checkpoint)
      Atomic tmp + fsync + ``os.replace`` saves with the commit marker
      written BEFORE the rename, per-file sha256 checksums in the
      manifest, retry-with-exponential-backoff around save/restore I/O,
      and ``restore()`` / ``latest_step()`` that verify integrity and
      fall back to the last GOOD checkpoint instead of crashing on a
      truncated or bit-flipped one.

  Deterministic fault injection (chaos.py + tools/chaos.py)
      ``FaultPlan`` / ``inject_faults`` poison gradients with NaN/Inf at
      exact steps as a gradient transformation (pure function of the
      step counter — reruns are bit-identical), plus host-side
      checkpoint corruption helpers and the device-loss remesh driver.
      ``tests/test_chaos.py`` is the acceptance harness; ``python
      tools/chaos.py`` is the CI smoke that emits the fault-event JSONL
      artifact.
"""
from repro.resilience.chaos import (FaultPlan, corrupt_latest_checkpoint,
                                    flip_bit, inject_faults,
                                    remesh_after_loss, truncate_file)
from repro.resilience.guards import (GuardConfig, GuardedState, GuardState,
                                     guard_spec, guard_updates,
                                     init_guard_state, tree_all_finite)
