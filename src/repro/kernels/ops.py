"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: padding to block multiples, dtype handling, platform
dispatch (TPU -> compiled Pallas; CPU -> interpret mode for tests, or the
pure-jnp reference for speed), and batching via vmap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lowrank_update import lowrank_update_pallas
from repro.kernels.srsi_matmul import sq_matmul_pallas

# Mode: "auto" (pallas on TPU, ref elsewhere), "pallas" (force, interpret on
# CPU — used by kernel tests), "ref" (force reference).
_MODE = "auto"


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in ("auto", "pallas", "ref")
    _MODE = mode


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    platform = jax.default_backend()
    if _MODE == "ref":
        return False, False
    if _MODE == "pallas":
        return True, platform != "tpu"
    return platform == "tpu", False


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_block(dim: int, target: int = 256, align: int = 8) -> int:
    """Largest block <= target that keeps padding waste < ~2x for tiny dims."""
    if dim >= target:
        return target
    # round tiny dims up to the alignment quantum
    return max(align, ((dim + align - 1) // align) * align)


def lowrank_update(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                   b2: float, eps: float,
                   with_frob: bool = False):
    """Fused V-reconstruct + elementwise update (see ref.lowrank_update).

    Accepts arbitrary leading batch dims on (q, u, g) jointly.
    """
    use, interp = _use_pallas()

    def one(q2, u2, g2):
        if not use:
            out, fro = ref.lowrank_update(q2, u2, g2, b2, eps)
            return out, fro
        m, n = g2.shape
        bm, bn = _pick_block(m), _pick_block(n)
        # r padded to a lane multiple so the MXU tile is aligned.
        qp = _pad_to(_pad_to(q2.astype(jnp.float32), bm, 0), 128, 1)
        up = _pad_to(_pad_to(u2.astype(jnp.float32), bn, 0), 128, 1)
        gp = _pad_to(_pad_to(g2, bm, 0), bn, 1)
        out, fro = lowrank_update_pallas(qp, up, gp,
                                         jnp.asarray(b2), jnp.asarray(eps),
                                         bm=bm, bn=bn, interpret=interp)
        return out[:m, :n], fro

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    out, fro = fn(q, u, g)
    return (out, fro) if with_frob else out


def sq_matmul(g: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(G*G) @ X with G^2 fused (see ref.sq_matmul)."""
    use, interp = _use_pallas()

    def one(g2, x2):
        if not use:
            return ref.sq_matmul(g2, x2)
        m, n = g2.shape
        s = x2.shape[1]
        bm, bn = _pick_block(m), _pick_block(n)
        gp = _pad_to(_pad_to(g2, bm, 0), bn, 1)
        xp = _pad_to(_pad_to(x2.astype(jnp.float32), bn, 0), 128, 1)
        y = sq_matmul_pallas(gp, xp, bm=bm, bn=bn, interpret=interp)
        return y[:m, :s]

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    return fn(g, x)


def sq_matmul_t(g: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(G*G)^T @ Y — implemented as sq_matmul on the transpose (the Pallas
    grid then streams G^T tiles; layout cost is folded into the same
    kernel)."""
    def one(g2, y2):
        return sq_matmul(g2.T, y2)

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    return fn(g, y)


def one_sided_fold(u: jnp.ndarray, q: jnp.ndarray, g: jnp.ndarray,
                   b2: float,
                   col_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Rank-projected factor fold ``mask * (b2*U + (1-b2) (G^2)^T Q)`` —
    the between-refresh update of Adapprox's amortized S-RSI.  The hot
    (G^2)^T Q product goes through the fused ``sq_matmul_t`` Pallas kernel
    dispatch (G^2 never materialised, batching included); the rank-r EMA +
    mask broadcast over any leading batch dims.  ``col_mask`` (r,) is
    shared across the batch.
    """
    y = sq_matmul_t(g, q)
    folded = b2 * u.astype(jnp.float32) + (1.0 - b2) * y
    if col_mask is not None:
        folded = folded * col_mask[None, :]
    return folded


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 512,
                    bk: int = 512) -> jnp.ndarray:
    """Flash attention for model-layout tensors.

    q: (B, Sq, H, dh), k/v: (B, Sk, KV, dh) with H % KV == 0 (GQA groups
    broadcast).  Pads dh to 128 lanes and folds (B, H) into the kernel
    grid.  On non-TPU backends runs the kernel in interpret mode ("pallas"
    test mode) or falls back to the reference (auto).
    """
    use, interp = _use_pallas()
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    groups = h // kv
    kx = jnp.repeat(k, groups, axis=2)
    vx = jnp.repeat(v, groups, axis=2)

    if not use:
        # reference path via plain softmax attention
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       kx.astype(jnp.float32)) / jnp.sqrt(float(dh))
        if causal:
            sk = kx.shape[1]
            mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
            s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), vx)

    dh_pad = (-dh) % 128
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    kp = jnp.pad(kx, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    vp = jnp.pad(vx, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    # (B, S, H, dh) -> (B*H, S, dh)
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, t.shape[1],
                                                   dh + dh_pad)
    bq_eff = min(bq, sq)
    bk_eff = min(bk, kx.shape[1])
    out = flash_attention_pallas(fold(qp), fold(kp), fold(vp),
                                 causal=causal, bq=bq_eff, bk=bk_eff,
                                 interpret=interp,
                                 scale=1.0 / (dh ** 0.5))
    out = out.reshape(b, h, sq, dh + dh_pad)[:, :, :, :dh]
    return jnp.moveaxis(out, 1, 2)
