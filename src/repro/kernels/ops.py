"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: padding to block multiples, dtype handling, platform
dispatch (TPU -> compiled Pallas; CPU -> interpret mode for tests, or the
pure-jnp reference for speed), and batching via vmap.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_update import (fused_apply_pallas,
                                        fused_apply_shared_pallas,
                                        fused_precond_guided_pallas,
                                        fused_precond_pallas)
from repro.kernels.lowrank_update import lowrank_update_pallas
from repro.kernels.sketch_update import sketch_update_pallas
from repro.kernels.srsi_matmul import sq_matmul_pallas

# Mode: "auto" (pallas on TPU, ref elsewhere), "pallas" (force, interpret on
# CPU — used by kernel tests and the CI pallas-interpret job via the
# REPRO_KERNEL_MODE env var), "ref" (force reference).
_MODE = os.environ.get("REPRO_KERNEL_MODE", "auto")
if _MODE not in ("auto", "pallas", "ref"):
    raise ValueError(
        f"REPRO_KERNEL_MODE={_MODE!r} (expected auto|pallas|ref)")


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in ("auto", "pallas", "ref")
    _MODE = mode


def resolved_mode() -> str:
    """The mode actually in effect: "pallas" | "interpret" | "ref".
    Benchmarks record this so TPU and CPU runs are distinguishable."""
    use, interp = _use_pallas()
    if not use:
        return "ref"
    return "interpret" if interp else "pallas"


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    platform = jax.default_backend()
    if _MODE == "ref":
        return False, False
    if _MODE == "pallas":
        return True, platform != "tpu"
    return platform == "tpu", False


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_block(dim: int, target: int = 256, align: int = 8) -> int:
    """Largest block <= target that keeps padding waste < ~2x for tiny dims."""
    if dim >= target:
        return target
    # round tiny dims up to the alignment quantum
    return max(align, ((dim + align - 1) // align) * align)


def lowrank_update(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                   b2: float, eps: float,
                   with_frob: bool = False):
    """Fused V-reconstruct + elementwise update (see ref.lowrank_update).

    Accepts arbitrary leading batch dims on (q, u, g) jointly.
    """
    use, interp = _use_pallas()

    def one(q2, u2, g2):
        if not use:
            out, fro = ref.lowrank_update(q2, u2, g2, b2, eps)
            return out, fro
        m, n = g2.shape
        bm, bn = _pick_block(m), _pick_block(n)
        # r padded to a lane multiple so the MXU tile is aligned.
        qp = _pad_to(_pad_to(q2.astype(jnp.float32), bm, 0), 128, 1)
        up = _pad_to(_pad_to(u2.astype(jnp.float32), bn, 0), 128, 1)
        gp = _pad_to(_pad_to(g2, bm, 0), bn, 1)
        out, fro = lowrank_update_pallas(qp, up, gp,
                                         jnp.asarray(b2), jnp.asarray(eps),
                                         bm=bm, bn=bn, interpret=interp)
        return out[:m, :n], fro

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    out, fro = fn(q, u, g)
    return (out, fro) if with_frob else out


def fused_precond(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                  b2: float, eps: float,
                  m1: jnp.ndarray | None = None,
                  with_vfro: bool = True):
    """Pass 1 of the fused two-pass update pipeline (see ref.fused_precond):
    raw update direction + whole-matrix reductions in one read of G, with V
    reconstructed tile-wise and never stored.  Pass ``m1`` to additionally
    get the guidance partials streamed in the same pass.

    Accepts arbitrary leading batch dims on (q, u, g, m1) jointly.
    Returns (u_hat, vfro, usq, m1dot, m1sq); the last two are None when
    ``m1`` is None.  ``with_vfro=False`` returns None for vfro on the ref
    path (the reduction is skipped — fold steps never consume it); the
    Pallas kernels always emit the per-tile partial since it rides the
    update loop for free, and the wrapper simply drops it.
    """
    use, interp = _use_pallas()

    def pads(q2, u2, g2, bm, bn):
        qp = _pad_to(_pad_to(q2.astype(jnp.float32), bm, 0), 128, 1)
        up = _pad_to(_pad_to(u2.astype(jnp.float32), bn, 0), 128, 1)
        gp = _pad_to(_pad_to(g2, bm, 0), bn, 1)
        return qp, up, gp

    if m1 is None:
        def one(q2, u2, g2):
            if not use:
                out, vfro, usq, _, _ = ref.fused_precond(
                    q2, u2, g2, b2, eps, with_vfro=with_vfro)
                return out, vfro, usq
            m_, n_ = g2.shape
            bm, bn = _pick_block(m_), _pick_block(n_)
            qp, up, gp = pads(q2, u2, g2, bm, bn)
            out, vfro, usq = fused_precond_pallas(
                qp, up, gp, jnp.asarray(b2), jnp.asarray(eps),
                bm=bm, bn=bn, interpret=interp)
            # the kernel always emits the per-tile partial (it rides the
            # update loop for free); drop it here so the return contract
            # matches the ref path backend-independently
            return out[:m_, :n_], vfro if with_vfro else None, usq

        fn = one
        for _ in range(g.ndim - 2):
            fn = jax.vmap(fn)
        out, vfro, usq = fn(q, u, g)
        return out, vfro, usq, None, None

    def one(q2, u2, g2, m12):
        if not use:
            return ref.fused_precond(q2, u2, g2, b2, eps, m1=m12,
                                     with_vfro=with_vfro)
        m_, n_ = g2.shape
        bm, bn = _pick_block(m_), _pick_block(n_)
        qp, up, gp = pads(q2, u2, g2, bm, bn)
        mp = _pad_to(_pad_to(m12.astype(jnp.float32), bm, 0), bn, 1)
        out, vfro, usq, m1dot, m1sq = fused_precond_guided_pallas(
            qp, up, gp, mp, jnp.asarray(b2), jnp.asarray(eps),
            bm=bm, bn=bn, interpret=interp)
        return (out[:m_, :n_], vfro if with_vfro else None, usq,
                m1dot, m1sq)

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, u, g, m1)


def fused_apply(u_hat: jnp.ndarray, m1: jnp.ndarray | None,
                denom: jnp.ndarray, b1: float,
                out_scale: jnp.ndarray, store_scale: jnp.ndarray,
                shared_out: bool = False):
    """Pass 2 of the fused pipeline (see ref.fused_apply): clip + first-
    moment EMA + guidance scales in one read-modify-write; on the Pallas
    path ``m1`` is donated to its output (updated in place).

    ``u_hat``/``m1``: (*batch, m, n); ``denom``/``out_scale``/
    ``store_scale``: (*batch,) scalars from the host combine.  With ``m1``
    None (b1 = 0) the EMA collapses to a single scaled copy, which is one
    fused elementwise op on every backend — no kernel needed.
    ``shared_out=True`` (valid when out_scale == store_scale, i.e.
    guidance "off" or "stored") returns the SAME array as m_out and
    m1_new — exactly the unfused aliasing — saving one (m, n) HBM write
    on the kernel path.  Returns (m_out, m1_new); ``m1_new`` is None when
    ``m1`` is None.
    """
    use, interp = _use_pallas()

    if m1 is None:
        dn = jnp.asarray(denom).reshape(jnp.shape(denom) + (1, 1))
        os_ = jnp.asarray(out_scale).reshape(jnp.shape(out_scale) + (1, 1))
        return (u_hat / dn) * os_, None

    def one(u2, m12, d, os_, ss):
        if not use:
            out, m1n = ref.fused_apply(u2, m12, d, b1, os_, ss)
            return (m1n, m1n) if shared_out else (out, m1n)
        m_, n_ = u2.shape
        bm, bn = _pick_block(m_), _pick_block(n_)
        up = _pad_to(_pad_to(u2.astype(jnp.float32), bm, 0), bn, 1)
        mp = _pad_to(_pad_to(m12.astype(jnp.float32), bm, 0), bn, 1)
        scalars = jnp.stack([d.astype(jnp.float32),
                             jnp.asarray(b1, jnp.float32),
                             jnp.asarray(1.0 - b1, jnp.float32),
                             os_.astype(jnp.float32),
                             ss.astype(jnp.float32)])
        if shared_out:
            m1n = fused_apply_shared_pallas(up, mp, scalars, bm=bm, bn=bn,
                                            interpret=interp)
            m1n = m1n[:m_, :n_]
            return m1n, m1n
        out, m1n = fused_apply_pallas(up, mp, scalars, bm=bm, bn=bn,
                                      interpret=interp)
        return out[:m_, :n_], m1n[:m_, :n_]

    fn = one
    for _ in range(u_hat.ndim - 2):
        fn = jax.vmap(fn)
    return fn(u_hat, m1, jnp.asarray(denom), jnp.asarray(out_scale),
              jnp.asarray(store_scale))


def sq_matmul(g: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(G*G) @ X with G^2 fused (see ref.sq_matmul)."""
    use, interp = _use_pallas()

    def one(g2, x2):
        if not use:
            return ref.sq_matmul(g2, x2)
        m, n = g2.shape
        s = x2.shape[1]
        bm, bn = _pick_block(m), _pick_block(n)
        gp = _pad_to(_pad_to(g2, bm, 0), bn, 1)
        xp = _pad_to(_pad_to(x2.astype(jnp.float32), bn, 0), 128, 1)
        y = sq_matmul_pallas(gp, xp, bm=bm, bn=bn, interpret=interp)
        return y[:m, :s]

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    return fn(g, x)


def sq_matmul_t(g: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(G*G)^T @ Y — implemented as sq_matmul on the transpose (the Pallas
    grid then streams G^T tiles; layout cost is folded into the same
    kernel)."""
    def one(g2, y2):
        return sq_matmul(g2.T, y2)

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    return fn(g, y)


def one_sided_fold(u: jnp.ndarray, q: jnp.ndarray, g: jnp.ndarray,
                   b2: float,
                   col_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Rank-projected factor fold ``mask * (b2*U + (1-b2) (G^2)^T Q)`` —
    the between-refresh update of Adapprox's amortized S-RSI.  The hot
    (G^2)^T Q product goes through the fused ``sq_matmul_t`` Pallas kernel
    dispatch (G^2 never materialised, batching included); the rank-r EMA +
    mask broadcast over any leading batch dims.  ``col_mask`` (r,) is
    shared across the batch.
    """
    y = sq_matmul_t(g, q)
    folded = b2 * u.astype(jnp.float32) + (1.0 - b2) * y
    if col_mask is not None:
        folded = folded * col_mask[None, :]
    return folded


def sketch_update(table: jnp.ndarray, g: jnp.ndarray, idx: jnp.ndarray,
                  b2: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused count-min EMA scatter + min-over-depth query (see
    ref.sketch_update).  table: (depth, width, d) f32, g: (rows, d) any
    float, idx: (depth, rows) int32.  Returns (table_new, vhat).

    Padding contract: rows pad with zero gradient and bucket 0 (no mass
    scattered, query sliced away); the bucket axis pads to a lane multiple
    (padded buckets are never indexed); the inner axis pads to the block
    and is sliced back.
    """
    use, interp = _use_pallas()
    if not use:
        return ref.sketch_update(table, g, idx, b2)
    depth, width, d = table.shape
    rows = g.shape[0]
    br = _pick_block(rows, target=256, align=8)
    # shrink the inner block when the resident (depth, width, bd) table
    # pair would blow the VMEM budget (see sketch_update.py docstring)
    bd_target = 128 if depth * width > 4096 else 256
    bd = _pick_block(d, target=bd_target, align=128)
    tab = _pad_to(_pad_to(table.astype(jnp.float32), 128, 1), bd, 2)
    gp = _pad_to(_pad_to(g, br, 0), bd, 1)
    ip = _pad_to(idx, br, 1)
    new, vhat = sketch_update_pallas(tab, gp, ip, jnp.asarray(b2),
                                     br=br, bd=bd, interpret=interp)
    return new[:, :width, :d], vhat[:rows, :d]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 512,
                    bk: int = 512) -> jnp.ndarray:
    """Flash attention for model-layout tensors.

    q: (B, Sq, H, dh), k/v: (B, Sk, KV, dh) with H % KV == 0 (GQA groups
    broadcast).  Pads dh to 128 lanes and folds (B, H) into the kernel
    grid.  On non-TPU backends runs the kernel in interpret mode ("pallas"
    test mode) or falls back to the reference (auto).
    """
    use, interp = _use_pallas()
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    groups = h // kv
    kx = jnp.repeat(k, groups, axis=2)
    vx = jnp.repeat(v, groups, axis=2)

    if not use:
        # reference path via plain softmax attention
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       kx.astype(jnp.float32)) / jnp.sqrt(float(dh))
        if causal:
            sk = kx.shape[1]
            mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
            s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), vx)

    dh_pad = (-dh) % 128
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    kp = jnp.pad(kx, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    vp = jnp.pad(vx, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    # (B, S, H, dh) -> (B*H, S, dh)
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, t.shape[1],
                                                   dh + dh_pad)
    bq_eff = min(bq, sq)
    bk_eff = min(bk, kx.shape[1])
    out = flash_attention_pallas(fold(qp), fold(kp), fold(vp),
                                 causal=causal, bq=bq_eff, bk=bk_eff,
                                 interpret=interp,
                                 scale=1.0 / (dh ** 0.5))
    out = out.reshape(b, h, sq, dh + dh_pad)[:, :, :, :dh]
    return jnp.moveaxis(out, 1, 2)
