"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: padding to block multiples, dtype handling, platform
dispatch (TPU -> compiled Pallas; CPU -> interpret mode for tests, or the
pure-jnp reference for speed), and batching via vmap.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_update import (fused_apply_pallas,
                                        fused_apply_shared_pallas,
                                        fused_precond_pallas)
from repro.kernels.lowrank_update import lowrank_update_pallas
from repro.kernels.sketch_update import sketch_update_pallas
from repro.kernels.srsi_matmul import sq_matmul_pallas

# Mode: "auto" (pallas on TPU, ref elsewhere), "pallas" (force, interpret on
# CPU — used by kernel tests and the CI pallas-interpret job via the
# REPRO_KERNEL_MODE env var), "ref" (force reference).
_MODE = os.environ.get("REPRO_KERNEL_MODE", "auto")
if _MODE not in ("auto", "pallas", "ref"):
    raise ValueError(
        f"REPRO_KERNEL_MODE={_MODE!r} (expected auto|pallas|ref)")

# Mixed-shape bucketing (pallas dispatch only; the ref path never pads, so
# the default chain's arithmetic is untouched): raw dims are rounded up a
# coarse ladder before the block size is chosen, so a many-leaf stack of
# near-miss shapes compiles to a handful of kernel instances instead of
# one per (shape, r_store) signature.  Zero padding + the kernels' exact
# partial reductions make the rounding bit-neutral (tests/test_kernels.py
# pins bucketed == unbucketed bitwise).  REPRO_KERNEL_BUCKETS=off or
# set_bucketing(False) restores exact-shape dispatch.
_BUCKETED = os.environ.get("REPRO_KERNEL_BUCKETS", "on").lower() \
    not in ("0", "off", "false")


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in ("auto", "pallas", "ref")
    _MODE = mode


def set_bucketing(on: bool) -> None:
    global _BUCKETED
    _BUCKETED = bool(on)


# Trace-time census of pallas dispatch signatures: every kernel launch
# records (kernel, padded operand shapes, block plan).  Distinct keys are
# exactly the jit cache keys of the underlying pallas wrappers, i.e. the
# number of kernel instances XLA compiles — tests assert a ragged
# many-leaf stack stays at a handful of instances under bucketing.
_INSTANCES: dict = {}


def _note_instance(kernel: str, shapes: tuple, blocks: tuple) -> None:
    key = (kernel, shapes, blocks)
    _INSTANCES[key] = _INSTANCES.get(key, 0) + 1


def kernel_instances() -> dict:
    return dict(_INSTANCES)


def reset_kernel_instances() -> None:
    _INSTANCES.clear()


def resolved_mode() -> str:
    """The mode actually in effect: "pallas" | "interpret" | "ref".
    Benchmarks record this so TPU and CPU runs are distinguishable."""
    use, interp = _use_pallas()
    if not use:
        return "ref"
    return "interpret" if interp else "pallas"


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    platform = jax.default_backend()
    if _MODE == "ref":
        return False, False
    if _MODE == "pallas":
        return True, platform != "tpu"
    return platform == "tpu", False


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_block(dim: int, target: int = 256, align: int = 8) -> int:
    """Largest block <= target that keeps padding waste < ~2x for tiny dims."""
    if dim >= target:
        return target
    # round tiny dims up to the alignment quantum
    return max(align, ((dim + align - 1) // align) * align)


def _bucket_dim(dim: int) -> int:
    """Round a raw dim up the bucket ladder: fine steps where leaves are
    small and shapes diverse, coarse where padding waste is relatively
    cheap.  dims > 256 already land on 256-multiples via _pad_to(block),
    so the ladder's work is consolidating the sub-256 long tail."""
    mult = 64 if dim <= 512 else (256 if dim <= 2048 else 512)
    return ((dim + mult - 1) // mult) * mult


def _tile_plan(dim: int, target: int = 256, align: int = 8) -> int:
    """Block size for one axis of a pallas dispatch.  With bucketing on
    (default) the dim is first rounded up the bucket ladder, so the
    subsequent ``_pad_to(x, block)`` lands mixed raw shapes on a small
    set of padded signatures — e.g. 100 -> 128, 130 -> 192, 320 -> 512 —
    instead of one 8-aligned signature per raw dim."""
    d = _bucket_dim(dim) if _BUCKETED else dim
    return _pick_block(d, target, align)


def _q_block_rows() -> int:
    """core/quantized.py's codec block height (lazy import: the codec is
    only needed on the int8 path and core imports this module)."""
    from repro.core.quantized import BLOCK_ROWS
    return BLOCK_ROWS


def lowrank_update(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                   b2: float, eps: float,
                   with_frob: bool = False):
    """Fused V-reconstruct + elementwise update (see ref.lowrank_update).

    Accepts arbitrary leading batch dims on (q, u, g) jointly.
    """
    use, interp = _use_pallas()

    def one(q2, u2, g2):
        if not use:
            out, fro = ref.lowrank_update(q2, u2, g2, b2, eps)
            return out, fro
        m, n = g2.shape
        bm, bn = _tile_plan(m), _tile_plan(n)
        # r padded to a lane multiple so the MXU tile is aligned.
        qp = _pad_to(_pad_to(q2.astype(jnp.float32), bm, 0), 128, 1)
        up = _pad_to(_pad_to(u2.astype(jnp.float32), bn, 0), 128, 1)
        gp = _pad_to(_pad_to(g2, bm, 0), bn, 1)
        _note_instance("lowrank_update", (qp.shape, up.shape, gp.shape),
                       (bm, bn))
        out, fro = lowrank_update_pallas(qp, up, gp,
                                         jnp.asarray(b2), jnp.asarray(eps),
                                         bm=bm, bn=bn, interpret=interp)
        return out[:m, :n], fro

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    out, fro = fn(q, u, g)
    return (out, fro) if with_frob else out


def fused_precond(q, u, g: jnp.ndarray,
                  b2: float, eps: float,
                  m1: jnp.ndarray | None = None,
                  with_vfro: bool = True,
                  with_fold: bool = False):
    """Pass 1 of the fused two-pass update pipeline (see ref.fused_precond):
    raw update direction + whole-matrix reductions in one read of G, with V
    reconstructed tile-wise and never stored.  Pass ``m1`` to additionally
    get the guidance partials streamed in the same pass.

    ``q`` / ``u`` are (…, m|n, r) f32 arrays OR ``QuantizedMatrix`` triples
    (core/quantized.py): on the kernel path the int8 payload is dequantized
    per tile in VMEM (block height == the forced bm = bn = BLOCK_ROWS) so
    the factors never materialize in fp32 HBM; on the ref path they are
    dequantized up front with the exact same formula, so both backends see
    bit-identical factor values.

    ``with_fold=True`` additionally returns the amortized-refresh fold
    projection ``yfold = (G^2)^T Q`` (…, n, r), emitted from the same tile
    loop that reads G for u_hat (per-row-block partials, host-summed like
    vfro/usq) — on fold steps this kills the standalone ``sq_matmul_t``
    pass over G.

    Accepts arbitrary leading batch dims on (q, u, g, m1) jointly.
    Returns (u_hat, vfro, usq, m1dot, m1sq, yfold); m1dot/m1sq are None
    when ``m1`` is None, yfold is None unless ``with_fold``.
    ``with_vfro=False`` returns None for vfro on the ref path (the
    reduction is skipped — fold steps never consume it); the Pallas
    kernels always emit the per-tile partial since it rides the update
    loop for free, and the wrapper simply drops it.
    """
    use, interp = _use_pallas()
    quantized = hasattr(q, "q8")

    def one(q2, u2, g2, m12):
        if not use:
            if quantized:
                from repro.core.quantized import dequantize
                q2f, u2f = dequantize(q2), dequantize(u2)
            else:
                q2f, u2f = q2, u2
            out, vfro, usq, m1dot, m1sq, y = ref.fused_precond(
                q2f, u2f, g2, b2, eps, m1=m12, with_vfro=with_vfro,
                with_fold=with_fold)
            return out, vfro, usq, m1dot, m1sq, y
        m_, n_ = g2.shape
        if quantized:
            # the codec's block height IS the tile plan: one (scale, zero)
            # row per (bm, r) tile of int8 payload, so dequant fuses into
            # the tile load.  scale/zero row counts already equal the
            # padded grid (quantize pads ragged blocks internally).
            bm = bn = _q_block_rows()
            r_t = q2.q8.shape[-1]
            qp = (_pad_to(_pad_to(q2.q8, bm, 0), 128, 1),
                  _pad_to(q2.scale, 128, 1), _pad_to(q2.zero, 128, 1))
            up = (_pad_to(_pad_to(u2.q8, bn, 0), 128, 1),
                  _pad_to(u2.scale, 128, 1), _pad_to(u2.zero, 128, 1))
            mt, nt = m_, n_
            shapes = (qp[0].shape, up[0].shape)
        else:
            bm, bn = _tile_plan(m_), _tile_plan(n_)
            r_t = q2.shape[-1]
            qp = _pad_to(_pad_to(q2.astype(jnp.float32), bm, 0), 128, 1)
            up = _pad_to(_pad_to(u2.astype(jnp.float32), bn, 0), 128, 1)
            mt = nt = None
            shapes = (qp.shape, up.shape)
        gp = _pad_to(_pad_to(g2, bm, 0), bn, 1)
        mp = (None if m12 is None
              else _pad_to(_pad_to(m12.astype(jnp.float32), bm, 0), bn, 1))
        _note_instance("fused_precond", shapes + (gp.shape,),
                       (bm, bn, m12 is not None, with_fold, quantized))
        out, vfro, usq, m1dot, m1sq, y = fused_precond_pallas(
            qp, up, gp, mp, jnp.asarray(b2), jnp.asarray(eps),
            bm=bm, bn=bn, with_fold=with_fold, m_true=mt, n_true=nt,
            interpret=interp)
        # the kernel always emits the vfro per-tile partial (it rides the
        # update loop for free); drop it here so the return contract
        # matches the ref path backend-independently
        return (out[:m_, :n_], vfro if with_vfro else None, usq,
                m1dot, m1sq, None if y is None else y[:n_, :r_t])

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, u, g, m1)


def fused_apply(u_hat: jnp.ndarray, m1: jnp.ndarray | None,
                denom: jnp.ndarray, b1: float,
                out_scale: jnp.ndarray, store_scale: jnp.ndarray,
                shared_out: bool = False):
    """Pass 2 of the fused pipeline (see ref.fused_apply): clip + first-
    moment EMA + guidance scales in one read-modify-write; on the Pallas
    path ``m1`` is donated to its output (updated in place).

    ``u_hat``/``m1``: (*batch, m, n); ``denom``/``out_scale``/
    ``store_scale``: (*batch,) scalars from the host combine.  With ``m1``
    None (b1 = 0) the EMA collapses to a single scaled copy, which is one
    fused elementwise op on every backend — no kernel needed.
    ``shared_out=True`` (valid when out_scale == store_scale, i.e.
    guidance "off" or "stored") returns the SAME array as m_out and
    m1_new — exactly the unfused aliasing — saving one (m, n) HBM write
    on the kernel path.  Returns (m_out, m1_new); ``m1_new`` is None when
    ``m1`` is None.
    """
    use, interp = _use_pallas()

    if m1 is None:
        dn = jnp.asarray(denom).reshape(jnp.shape(denom) + (1, 1))
        os_ = jnp.asarray(out_scale).reshape(jnp.shape(out_scale) + (1, 1))
        return (u_hat / dn) * os_, None

    def one(u2, m12, d, os_, ss):
        if not use:
            out, m1n = ref.fused_apply(u2, m12, d, b1, os_, ss)
            return (m1n, m1n) if shared_out else (out, m1n)
        m_, n_ = u2.shape
        bm, bn = _tile_plan(m_), _tile_plan(n_)
        up = _pad_to(_pad_to(u2.astype(jnp.float32), bm, 0), bn, 1)
        mp = _pad_to(_pad_to(m12.astype(jnp.float32), bm, 0), bn, 1)
        _note_instance("fused_apply", (up.shape,), (bm, bn, shared_out))
        scalars = jnp.stack([d.astype(jnp.float32),
                             jnp.asarray(b1, jnp.float32),
                             jnp.asarray(1.0 - b1, jnp.float32),
                             os_.astype(jnp.float32),
                             ss.astype(jnp.float32)])
        if shared_out:
            m1n = fused_apply_shared_pallas(up, mp, scalars, bm=bm, bn=bn,
                                            interpret=interp)
            m1n = m1n[:m_, :n_]
            return m1n, m1n
        out, m1n = fused_apply_pallas(up, mp, scalars, bm=bm, bn=bn,
                                      interpret=interp)
        return out[:m_, :n_], m1n[:m_, :n_]

    fn = one
    for _ in range(u_hat.ndim - 2):
        fn = jax.vmap(fn)
    return fn(u_hat, m1, jnp.asarray(denom), jnp.asarray(out_scale),
              jnp.asarray(store_scale))


def sq_matmul(g: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(G*G) @ X with G^2 fused (see ref.sq_matmul)."""
    use, interp = _use_pallas()

    def one(g2, x2):
        if not use:
            return ref.sq_matmul(g2, x2)
        m, n = g2.shape
        s = x2.shape[1]
        bm, bn = _tile_plan(m), _tile_plan(n)
        gp = _pad_to(_pad_to(g2, bm, 0), bn, 1)
        xp = _pad_to(_pad_to(x2.astype(jnp.float32), bn, 0), 128, 1)
        _note_instance("sq_matmul", (gp.shape, xp.shape), (bm, bn))
        y = sq_matmul_pallas(gp, xp, bm=bm, bn=bn, interpret=interp)
        return y[:m, :s]

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    return fn(g, x)


def sq_matmul_t(g: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(G*G)^T @ Y — implemented as sq_matmul on the transpose.  NB: XLA
    materialises G^T in HBM before the custom call (a transpose copy is
    NOT folded into the kernel's tile streaming), so a standalone call
    costs ~3mn words of traffic on top of the matmul's reads — the reason
    fold steps route through ``fused_precond(..., with_fold=True)``, which
    emits the same product from pass 1's already-resident G tiles.  The
    roofline model (benchmarks/roofline.py) charges this stage honestly."""
    def one(g2, y2):
        return sq_matmul(g2.T, y2)

    fn = one
    for _ in range(g.ndim - 2):
        fn = jax.vmap(fn)
    return fn(g, y)


def one_sided_fold(u: jnp.ndarray, q: jnp.ndarray, g: jnp.ndarray,
                   b2: float,
                   col_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Rank-projected factor fold ``mask * (b2*U + (1-b2) (G^2)^T Q)`` —
    the between-refresh update of Adapprox's amortized S-RSI.  The hot
    (G^2)^T Q product goes through the fused ``sq_matmul_t`` Pallas kernel
    dispatch (G^2 never materialised, batching included); the rank-r EMA +
    mask broadcast over any leading batch dims.  ``col_mask`` (r,) is
    shared across the batch.
    """
    y = sq_matmul_t(g, q)
    folded = b2 * u.astype(jnp.float32) + (1.0 - b2) * y
    if col_mask is not None:
        folded = folded * col_mask[None, :]
    return folded


def sketch_update(table: jnp.ndarray, g: jnp.ndarray, idx: jnp.ndarray,
                  b2: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused count-min EMA scatter + min-over-depth query (see
    ref.sketch_update).  table: (depth, width, d) f32, g: (rows, d) any
    float, idx: (depth, rows) int32.  Returns (table_new, vhat).

    Padding contract: rows pad with zero gradient and bucket 0 (no mass
    scattered, query sliced away); the bucket axis pads to a lane multiple
    (padded buckets are never indexed); the inner axis pads to the block
    and is sliced back.
    """
    use, interp = _use_pallas()
    if not use:
        return ref.sketch_update(table, g, idx, b2)
    depth, width, d = table.shape
    rows = g.shape[0]
    br = _pick_block(rows, target=256, align=8)
    # shrink the inner block when the resident (depth, width, bd) table
    # pair would blow the VMEM budget (see sketch_update.py docstring)
    bd_target = 128 if depth * width > 4096 else 256
    bd = _pick_block(d, target=bd_target, align=128)
    tab = _pad_to(_pad_to(table.astype(jnp.float32), 128, 1), bd, 2)
    gp = _pad_to(_pad_to(g, br, 0), bd, 1)
    ip = _pad_to(idx, br, 1)
    new, vhat = sketch_update_pallas(tab, gp, ip, jnp.asarray(b2),
                                     br=br, bd=bd, interpret=interp)
    return new[:, :width, :d], vhat[:rows, :d]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 512,
                    bk: int = 512) -> jnp.ndarray:
    """Flash attention for model-layout tensors.

    q: (B, Sq, H, dh), k/v: (B, Sk, KV, dh) with H % KV == 0 (GQA groups
    broadcast).  Pads dh to 128 lanes and folds (B, H) into the kernel
    grid.  On non-TPU backends runs the kernel in interpret mode ("pallas"
    test mode) or falls back to the reference (auto).
    """
    use, interp = _use_pallas()
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    groups = h // kv
    kx = jnp.repeat(k, groups, axis=2)
    vx = jnp.repeat(v, groups, axis=2)

    if not use:
        # reference path via plain softmax attention
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       kx.astype(jnp.float32)) / jnp.sqrt(float(dh))
        if causal:
            sk = kx.shape[1]
            mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
            s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), vx)

    dh_pad = (-dh) % 128
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    kp = jnp.pad(kx, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    vp = jnp.pad(vx, ((0, 0), (0, 0), (0, 0), (0, dh_pad)))
    # (B, S, H, dh) -> (B*H, S, dh)
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, t.shape[1],
                                                   dh + dh_pad)
    bq_eff = min(bq, sq)
    bk_eff = min(bk, kx.shape[1])
    out = flash_attention_pallas(fold(qp), fold(kp), fold(vp),
                                 causal=causal, bq=bq_eff, bk=bk_eff,
                                 interpret=interp,
                                 scale=1.0 / (dh ** 0.5))
    out = out.reshape(b, h, sq, dh + dh_pad)[:, :, :, :dh]
    return jnp.moveaxis(out, 1, 2)
