"""Pallas TPU kernels: the fused two-pass Adapprox update pipeline.

The elementwise tail of the optimizer — reconstruct V, divide, RMS-clip,
first-moment EMA, cosine guidance — is memory-bound, and the plain jnp
path makes ~7 full (m, n) HBM passes per factored leaf.  These kernels cut
it to ~3:

  pass 1 (``fused_precond_pallas``): per (bm, bn) tile, reconstruct
      V = b2 * max(Q @ U^T, 0) + (1 - b2) * G^2 in VMEM, write the raw
      update direction u_hat = G / (sqrt(V) + eps) ONCE, and emit per-tile
      partial reductions alongside it: sum(V^2) (adaptive rank / implicit
      S-RSI), sum(u_hat^2) (RMS clip) and, when guidance is on,
      dot(m1, u_hat) + sum(m1^2).  The (gm, gn) partial grids are summed on
      the host — O(tiles) scalars, negligible traffic.

      Two optional tile-load extensions close the remaining HBM gaps:

      * ``with_fold=True`` additionally emits the amortized-refresh fold
        projection ``(G^2)^T Q`` as a third per-tile partial: each (i, j)
        tile contributes ``(G_tile^2)^T Q_tile`` (bn, r) to row i of a
        (gm, n, r) partial tensor, host-summed over i on the same
        partial-reduction path as vfro/usq.  G is already resident in the
        tile registers for u_hat, so on fold steps the separate
        ``sq_matmul_t`` pass over G — read G, materialise G^T, read it
        again — disappears (see ops.one_sided_fold / roofline.py).

      * quantized factors: pass Q / U as ``(q8, scale, zero)`` triples
        (core/quantized.py layout, block height == bm == bn) and the tile
        load applies ``deq = (q8 + 127) * scale + zero`` in VMEM — the
        int8 factors never round-trip through fp32 HBM.  Rows past the
        true (m, n) are statically masked to 0 so padded tiles keep every
        partial reduction exact (an affine codec dequantizes padding to
        ``zero``, not 0, without the mask).

  pass 2 (``fused_apply_pallas``): one read-modify-write applying the
      host-combined scalars: u_c = u_hat / denom (RMS clip),
      acc = b1 * m1 + (1 - b1) * u_c (update-EMA first moment),
      m_out = acc * out_scale, m1_new = acc * store_scale (guidance).
      ``m1`` is aliased to ``m1_new`` via ``input_output_aliases`` so the
      first moment is updated in place — no extra HBM allocation.

Traffic per factored leaf (f32 words, b1 > 0, guidance off, skinny
factor reads shared by both sides): unfused = reconstruct (read G, write
V) + divide (read G, V; write u_hat) + rms reduce (read u_hat) + clip
(rmw u_hat) + EMA (read u_c, m1; write m1) ~ 11 m*n; fused = pass 1
(read G, write u_hat) + pass 2 (read u_hat, m1; write m1 == m_out)
~ 5 m*n.  On fold steps the PR-4 pipeline additionally paid ~3 m*n for
the standalone (G^2)^T Q (read G, write G^T, read G^T); ``with_fold``
replaces that with 2 * gm * n * r partial words — >= 1.3x fewer fold-step
bytes at r <= bm / 2, 1.6x at small r.  See
benchmarks/roofline.py::optimizer_update_traffic for the full per-stage
model and tests/test_fused.py for the pinned ratios.

VMEM tiling matches lowrank_update.py: blocks (bm, r) of Q, (bn, r) of U,
(bm, bn) of G / m1 with r padded to the 128-lane quantum by ops.py;
bm = bn = 256 keeps the footprint ~2 MiB, well inside the ~16 MiB budget
(and equals core/quantized.py's BLOCK_ROWS, so a quantized tile needs
exactly one scale/zero row).  Scalars ride in a single small ANY-space
vector, indexed inside the body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _deq_tile(q8_ref, scale_ref, zero_ref, base_row: jnp.ndarray,
              true_rows: int):
    """In-register dequant of one factor tile: the EXACT
    core/quantized.dequantize formula, plus a static row mask so rows past
    the matrix's true extent read as 0 (keeping padded-tile partials and
    padded output rows exactly zero, as on the f32 path)."""
    vals = ((q8_ref[...].astype(jnp.float32) + 127.0) * scale_ref[...]
            + zero_ref[...])
    rows = base_row + jax.lax.broadcasted_iota(jnp.int32, vals.shape, 0)
    return jnp.where(rows < true_rows, vals, 0.0)


def _make_precond_kernel(guided: bool, with_fold: bool, quantized: bool,
                         m_true: int, n_true: int, bm: int, bn: int):
    """Build the pass-1 kernel body for one (guided, fold, quantized)
    variant — one code path instead of eight hand-written bodies."""

    def kernel(*refs):
        it = iter(refs)
        if quantized:
            q = _deq_tile(next(it), next(it), next(it),
                          pl.program_id(0) * bm, m_true)
            u = _deq_tile(next(it), next(it), next(it),
                          pl.program_id(1) * bn, n_true)
        else:
            q = next(it)[...].astype(jnp.float32)      # (bm, r)
            u = next(it)[...].astype(jnp.float32)      # (bn, r)
        g = next(it)[...].astype(jnp.float32)          # (bm, bn)
        m1 = next(it)[...].astype(jnp.float32) if guided else None
        s_ref = next(it)
        out_ref, vfro_ref, usq_ref = next(it), next(it), next(it)
        m1dot_ref, m1sq_ref = (next(it), next(it)) if guided else (None,
                                                                   None)
        fold_ref = next(it) if with_fold else None

        b2 = s_ref[0]
        eps = s_ref[1]
        low = jax.lax.dot_general(q, u, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        v = b2 * jnp.maximum(low, 0.0) + (1.0 - b2) * g * g
        out = g / (jnp.sqrt(v) + eps)
        out_ref[...] = out
        vfro_ref[0, 0] = jnp.sum(v * v)
        usq_ref[0, 0] = jnp.sum(out * out)
        if guided:
            m1dot_ref[0, 0] = jnp.sum(m1 * out)
            m1sq_ref[0, 0] = jnp.sum(m1 * m1)
        if with_fold:
            # (G_tile^2)^T Q_tile: contract the bm rows already resident
            # for u_hat — the fold projection rides the update loop.
            fold_ref[0, :, :] = jax.lax.dot_general(
                g * g, q, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

    return kernel


@functools.partial(jax.jit, static_argnames=("bm", "bn", "with_fold",
                                             "m_true", "n_true",
                                             "interpret"))
def fused_precond_pallas(q, u, g: jnp.ndarray, m1, b2, eps,
                         bm: int = 256, bn: int = 256,
                         with_fold: bool = False,
                         m_true: int | None = None,
                         n_true: int | None = None,
                         interpret: bool = False):
    """Pass 1 for every variant.  q: (m, r) f32 OR an int8
    ``(q8 (m, r), scale (gm, r), zero (gm, r))`` triple; u likewise over
    (n, r) / gn; g: (m, n); m1: (m, n) f32 or None (guidance off).
    m % bm == 0, n % bn == 0, r % 128 == 0 (ops.py pads; zero padding —
    plus the in-kernel row mask on the quantized path — leaves every
    reduction untouched).  ``m_true`` / ``n_true``: the unpadded extents,
    required when quantized.  Returns
    ``(u_hat (m, n) f32, vfro (), usq (), m1dot, m1sq, yfold)`` with the
    per-tile partial grids already summed; m1dot/m1sq are None without
    m1, yfold ((n, r) f32 = (G^2)^T Q) is None unless ``with_fold``.
    """
    quantized = isinstance(q, tuple)
    guided = m1 is not None
    m, r = (q[0] if quantized else q).shape
    n = (u[0] if quantized else u).shape[0]
    gm, gn = m // bm, n // bn
    scalars = jnp.stack([jnp.asarray(b2, jnp.float32),
                         jnp.asarray(eps, jnp.float32)])

    inputs, in_specs = [], []
    if quantized:
        inputs += [q[0], q[1], q[2], u[0], u[1], u[2]]
        in_specs += [
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((1, r), lambda i, j: (i, 0)),
            pl.BlockSpec((1, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((1, r), lambda i, j: (j, 0)),
            pl.BlockSpec((1, r), lambda i, j: (j, 0)),
        ]
    else:
        inputs += [q, u]
        in_specs += [
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ]
    inputs.append(g)
    in_specs.append(pl.BlockSpec((bm, bn), lambda i, j: (i, j)))
    if guided:
        inputs.append(m1)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j: (i, j)))
    inputs.append(scalars)
    in_specs.append(pl.BlockSpec(memory_space=pl.ANY))

    tile = jax.ShapeDtypeStruct((gm, gn), jnp.float32)
    tile_spec = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    out_specs = [pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                 tile_spec, tile_spec]
    out_shape = [jax.ShapeDtypeStruct((m, n), jnp.float32), tile, tile]
    if guided:
        out_specs += [tile_spec, tile_spec]
        out_shape += [tile, tile]
    if with_fold:
        out_specs.append(pl.BlockSpec((1, bn, r), lambda i, j: (i, j, 0)))
        out_shape.append(jax.ShapeDtypeStruct((gm, n, r), jnp.float32))

    kernel = _make_precond_kernel(guided, with_fold, quantized,
                                  m_true if m_true is not None else m,
                                  n_true if n_true is not None else n,
                                  bm, bn)
    res = pl.pallas_call(kernel, grid=(gm, gn), in_specs=in_specs,
                         out_specs=out_specs, out_shape=out_shape,
                         interpret=interpret)(*inputs)
    res = list(res)
    out = res.pop(0)
    vfro = jnp.sum(res.pop(0))
    usq = jnp.sum(res.pop(0))
    m1dot = jnp.sum(res.pop(0)) if guided else None
    m1sq = jnp.sum(res.pop(0)) if guided else None
    yfold = jnp.sum(res.pop(0), axis=0) if with_fold else None
    return out, vfro, usq, m1dot, m1sq, yfold


def _apply_kernel(u_ref, m1_ref, s_ref, out_ref, m1_new_ref):
    # s_ref: (5,) = [denom, b1, 1 - b1, out_scale, store_scale].  (1 - b1)
    # is precomputed by the wrapper in python-f64-then-round — the same
    # coefficient the jnp paths use — rather than re-derived in f32 here.
    u = u_ref[...].astype(jnp.float32)
    m1 = m1_ref[...].astype(jnp.float32)
    u_c = u / s_ref[0]
    acc = s_ref[1] * m1 + s_ref[2] * u_c
    out_ref[...] = acc * s_ref[3]
    m1_new_ref[...] = acc * s_ref[4]


def _apply_shared_kernel(u_ref, m1_ref, s_ref, m1_new_ref):
    # Shared-output variant: when out_scale == store_scale (guidance "off"
    # or "stored") the step direction IS the new first moment, exactly as
    # in the unfused path — write it once and let the caller alias.
    u = u_ref[...].astype(jnp.float32)
    m1 = m1_ref[...].astype(jnp.float32)
    u_c = u / s_ref[0]
    acc = s_ref[1] * m1 + s_ref[2] * u_c
    m1_new_ref[...] = acc * s_ref[4]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_apply_shared_pallas(u_hat: jnp.ndarray, m1: jnp.ndarray,
                              scalars: jnp.ndarray,
                              bm: int = 256, bn: int = 256,
                              interpret: bool = False):
    """Single-output :func:`fused_apply_pallas` for out_scale ==
    store_scale: returns m1_new (= m_out), saving one full (m, n) HBM
    write.  ``m1`` is aliased to the output."""
    m, n = u_hat.shape
    gm, gn = m // bm, n // bn
    return pl.pallas_call(
        _apply_shared_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pl.ANY),       # scalars (5,)
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        input_output_aliases={1: 0},                 # m1 -> m1_new
        interpret=interpret,
    )(u_hat, m1, scalars)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_apply_pallas(u_hat: jnp.ndarray, m1: jnp.ndarray,
                       scalars: jnp.ndarray,
                       bm: int = 256, bn: int = 256,
                       interpret: bool = False):
    """u_hat/m1: (m, n) f32, scalars: (5,) f32 = [denom, b1, 1 - b1,
    out_scale, store_scale].  m % bm == 0, n % bn == 0 (ops.py pads).  ``m1`` is
    aliased to the ``m1_new`` output (updated in place — the EMA buffer
    never exists twice in HBM).  Returns (m_out, m1_new), both (m, n) f32.
    """
    m, n = u_hat.shape
    gm, gn = m // bm, n // bn
    return pl.pallas_call(
        _apply_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pl.ANY),       # scalars (4,)
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        input_output_aliases={1: 1},                 # m1 -> m1_new
        interpret=interpret,
    )(u_hat, m1, scalars)
