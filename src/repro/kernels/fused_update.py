"""Pallas TPU kernels: the fused two-pass Adapprox update pipeline.

The elementwise tail of the optimizer — reconstruct V, divide, RMS-clip,
first-moment EMA, cosine guidance — is memory-bound, and the plain jnp
path makes ~7 full (m, n) HBM passes per factored leaf.  These kernels cut
it to ~3:

  pass 1 (``fused_precond_pallas``): per (bm, bn) tile, reconstruct
      V = b2 * max(Q @ U^T, 0) + (1 - b2) * G^2 in VMEM, write the raw
      update direction u_hat = G / (sqrt(V) + eps) ONCE, and emit per-tile
      partial reductions alongside it: sum(V^2) (adaptive rank / implicit
      S-RSI), sum(u_hat^2) (RMS clip) and, when guidance is on,
      dot(m1, u_hat) + sum(m1^2).  The (gm, gn) partial grids are summed on
      the host — O(tiles) scalars, negligible traffic.

  pass 2 (``fused_apply_pallas``): one read-modify-write applying the
      host-combined scalars: u_c = u_hat / denom (RMS clip),
      acc = b1 * m1 + (1 - b1) * u_c (update-EMA first moment),
      m_out = acc * out_scale, m1_new = acc * store_scale (guidance).
      ``m1`` is aliased to ``m1_new`` via ``input_output_aliases`` so the
      first moment is updated in place — no extra HBM allocation.

Traffic per factored leaf (f32 words, b1 > 0, guidance off, skinny
factor reads shared by both sides): unfused = reconstruct (read G, write
V) + divide (read G, V; write u_hat) + rms reduce (read u_hat) + clip
(rmw u_hat) + EMA (read u_c, m1; write m1) ~ 11 m*n; fused = pass 1
(read G, write u_hat) + pass 2 (read u_hat, m1; write m1 == m_out)
~ 5 m*n — 2.1-2.5x fewer bytes across modes; see
benchmarks/roofline.py::optimizer_update_traffic for the full per-stage
model and tests/test_fused.py for the pinned >= 2x ratio.

VMEM tiling matches lowrank_update.py: blocks (bm, r) of Q, (bn, r) of U,
(bm, bn) of G / m1 with r padded to the 128-lane quantum by ops.py;
bm = bn = 256 keeps the footprint ~2 MiB, well inside the ~16 MiB budget.
Scalars ride in a single small ANY-space vector, indexed inside the body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _precond_tile(q_ref, u_ref, g_ref, s_ref):
    """Shared pass-1 tile math -> (u_hat_tile, v_tile)."""
    q = q_ref[...].astype(jnp.float32)          # (bm, r)
    u = u_ref[...].astype(jnp.float32)          # (bn, r)
    g = g_ref[...].astype(jnp.float32)          # (bm, bn)
    b2 = s_ref[0]
    eps = s_ref[1]
    low = jax.lax.dot_general(q, u, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    v = b2 * jnp.maximum(low, 0.0) + (1.0 - b2) * g * g
    return g / (jnp.sqrt(v) + eps), v


def _precond_kernel(q_ref, u_ref, g_ref, s_ref,
                    out_ref, vfro_ref, usq_ref):
    out, v = _precond_tile(q_ref, u_ref, g_ref, s_ref)
    out_ref[...] = out
    vfro_ref[0, 0] = jnp.sum(v * v)
    usq_ref[0, 0] = jnp.sum(out * out)


def _precond_guided_kernel(q_ref, u_ref, g_ref, m1_ref, s_ref,
                           out_ref, vfro_ref, usq_ref, m1dot_ref, m1sq_ref):
    out, v = _precond_tile(q_ref, u_ref, g_ref, s_ref)
    m1 = m1_ref[...].astype(jnp.float32)
    out_ref[...] = out
    vfro_ref[0, 0] = jnp.sum(v * v)
    usq_ref[0, 0] = jnp.sum(out * out)
    m1dot_ref[0, 0] = jnp.sum(m1 * out)
    m1sq_ref[0, 0] = jnp.sum(m1 * m1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_precond_pallas(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                         b2: jnp.ndarray, eps: jnp.ndarray,
                         bm: int = 256, bn: int = 256,
                         interpret: bool = False):
    """q: (m, r) f32, u: (n, r) f32, g: (m, n).  m % bm == 0, n % bn == 0,
    r % 128 == 0 (ops.py pads; zero padding leaves every reduction
    untouched).  Returns (u_hat (m, n) f32, vfro (), usq ()) with the
    per-tile partial grids already summed."""
    m, r = q.shape
    n = u.shape[0]
    gm, gn = m // bm, n // bn
    scalars = jnp.stack([jnp.asarray(b2, jnp.float32),
                         jnp.asarray(eps, jnp.float32)])
    tile = jax.ShapeDtypeStruct((gm, gn), jnp.float32)
    out, vfro, usq = pl.pallas_call(
        _precond_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pl.ANY),       # scalars (2,)
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            tile, tile,
        ],
        interpret=interpret,
    )(q, u, g, scalars)
    return out, jnp.sum(vfro), jnp.sum(usq)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_precond_guided_pallas(q: jnp.ndarray, u: jnp.ndarray,
                                g: jnp.ndarray, m1: jnp.ndarray,
                                b2: jnp.ndarray, eps: jnp.ndarray,
                                bm: int = 256, bn: int = 256,
                                interpret: bool = False):
    """Guidance variant of :func:`fused_precond_pallas`: also streams the
    stored first moment through the tile and emits dot(m1, u_hat) and
    sum(m1^2) partials.  Returns (u_hat, vfro, usq, m1dot, m1sq)."""
    m, r = q.shape
    n = u.shape[0]
    gm, gn = m // bm, n // bn
    scalars = jnp.stack([jnp.asarray(b2, jnp.float32),
                         jnp.asarray(eps, jnp.float32)])
    tile = jax.ShapeDtypeStruct((gm, gn), jnp.float32)
    out, vfro, usq, m1dot, m1sq = pl.pallas_call(
        _precond_guided_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pl.ANY),       # scalars (2,)
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            tile, tile, tile, tile,
        ],
        interpret=interpret,
    )(q, u, g, m1, scalars)
    return (out, jnp.sum(vfro), jnp.sum(usq),
            jnp.sum(m1dot), jnp.sum(m1sq))


def _apply_kernel(u_ref, m1_ref, s_ref, out_ref, m1_new_ref):
    # s_ref: (5,) = [denom, b1, 1 - b1, out_scale, store_scale].  (1 - b1)
    # is precomputed by the wrapper in python-f64-then-round — the same
    # coefficient the jnp paths use — rather than re-derived in f32 here.
    u = u_ref[...].astype(jnp.float32)
    m1 = m1_ref[...].astype(jnp.float32)
    u_c = u / s_ref[0]
    acc = s_ref[1] * m1 + s_ref[2] * u_c
    out_ref[...] = acc * s_ref[3]
    m1_new_ref[...] = acc * s_ref[4]


def _apply_shared_kernel(u_ref, m1_ref, s_ref, m1_new_ref):
    # Shared-output variant: when out_scale == store_scale (guidance "off"
    # or "stored") the step direction IS the new first moment, exactly as
    # in the unfused path — write it once and let the caller alias.
    u = u_ref[...].astype(jnp.float32)
    m1 = m1_ref[...].astype(jnp.float32)
    u_c = u / s_ref[0]
    acc = s_ref[1] * m1 + s_ref[2] * u_c
    m1_new_ref[...] = acc * s_ref[4]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_apply_shared_pallas(u_hat: jnp.ndarray, m1: jnp.ndarray,
                              scalars: jnp.ndarray,
                              bm: int = 256, bn: int = 256,
                              interpret: bool = False):
    """Single-output :func:`fused_apply_pallas` for out_scale ==
    store_scale: returns m1_new (= m_out), saving one full (m, n) HBM
    write.  ``m1`` is aliased to the output."""
    m, n = u_hat.shape
    gm, gn = m // bm, n // bn
    return pl.pallas_call(
        _apply_shared_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pl.ANY),       # scalars (5,)
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        input_output_aliases={1: 0},                 # m1 -> m1_new
        interpret=interpret,
    )(u_hat, m1, scalars)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_apply_pallas(u_hat: jnp.ndarray, m1: jnp.ndarray,
                       scalars: jnp.ndarray,
                       bm: int = 256, bn: int = 256,
                       interpret: bool = False):
    """u_hat/m1: (m, n) f32, scalars: (5,) f32 = [denom, b1, 1 - b1,
    out_scale, store_scale].  m % bm == 0, n % bn == 0 (ops.py pads).  ``m1`` is
    aliased to the ``m1_new`` output (updated in place — the EMA buffer
    never exists twice in HBM).  Returns (m_out, m1_new), both (m, n) f32.
    """
    m, n = u_hat.shape
    gm, gn = m // bm, n // bn
    return pl.pallas_call(
        _apply_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pl.ANY),       # scalars (4,)
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        input_output_aliases={1: 1},                 # m1 -> m1_new
        interpret=interpret,
    )(u_hat, m1, scalars)
