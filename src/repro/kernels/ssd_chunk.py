"""Pallas TPU kernel: fused Mamba2/SSD intra-chunk block.

Per (batch, chunk) program, computes in one VMEM residency:

    S      = C X ... specifically  CB = C @ (dt*B)^T          (q, q)
    L      = tril(exp(cums_i - cums_j))                        (q, q)
    Y_intra= (CB * L) @ X                                      (q, p)
    Y_inter= exp(cums) * (C @ h_in)                            (q, p)
    Y      = Y_intra + Y_inter + d_skip * X

which is the matmul-heavy heart of the SSD algorithm (models/mamba2.py).
The jnp path materialises the (nc, q, q, H) decay and CB tensors in HBM —
at 32k context that is ~4 GB per layer; here they live only as (q, q)
VMEM tiles per head.

The inter-chunk state recurrence (tiny: nc sequential steps over
(H, N, P) states) stays in jnp `lax.scan` — it is latency-, not
throughput-bound, and supplies `h_in` per chunk as a kernel input.

Grid: (batch * n_chunks, heads).  Blocks per program:
x (q, p), b/c (q, n), cums/dt (q,), h_in (n, p) — with q = 256, p = 64,
n = 128: VMEM ~ (256*64*3 + 256*128*2 + 256*256) * 4 B ~ 0.7 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, b_ref, c_ref, dt_ref, cums_ref, hin_ref, dskip_ref,
            y_ref):
    x = x_ref[0, 0, :, :].astype(jnp.float32)        # (q, p)
    b = b_ref[0, 0, :, :].astype(jnp.float32)        # (q, n)
    c = c_ref[0, 0, :, :].astype(jnp.float32)        # (q, n)
    dt = dt_ref[0, 0, :].astype(jnp.float32)         # (q,)
    cums = cums_ref[0, 0, :].astype(jnp.float32)     # (q,)
    h_in = hin_ref[0, 0, :, :].astype(jnp.float32)   # (n, p)
    dskip = dskip_ref[:]                             # (1,)

    q = x.shape[0]
    bx = b * dt[:, None]
    cb = jax.lax.dot_general(c, bx, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, q)
    diff = cums[:, None] - cums[None, :]
    iot_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iot_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    ldec = jnp.where(iot_j <= iot_i, jnp.exp(diff), 0.0)
    y_intra = jax.lax.dot_general(cb * ldec, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = jnp.exp(cums)[:, None] * jax.lax.dot_general(
        c, h_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0, :, :] = (y_intra + y_inter
                         + dskip[0] * x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(x, b, c, dt, cums, h_in, d_skip,
                     interpret: bool = False):
    """x: (BC, H, q, p); b, c: (BC, H, q, n); dt, cums: (BC, H, q);
    h_in: (BC, H, n, p); d_skip: (H,) — BC = batch * n_chunks flattened.
    Returns y: (BC, H, q, p)."""
    bc, h, q, p = x.shape
    n = b.shape[-1]
    return pl.pallas_call(
        _kernel,
        grid=(bc, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, h, q, p), jnp.float32),
        interpret=interpret,
    )(x, b, c, dt, cums, h_in, d_skip)
