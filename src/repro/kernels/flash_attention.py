"""Pallas TPU flash attention (causal/full, GQA) — forward kernel.

The §Perf analysis (EXPERIMENTS.md) shows the dominant HBM traffic of every
train cell is the attention score tensors crossing fusion boundaries; this
kernel keeps the (q_block, k_block) scores in VMEM with the standard
online-softmax recurrence, so per-head HBM traffic drops from O(S²) to
O(S·dh).

Grid: (batch*kv_heads*groups, Sq/BQ) — one program per (head, q-block);
the kv loop runs *inside* the kernel over Sk/BK so the running (m, l, acc)
never leave VMEM.  Blocks: q (BQ, dh), k/v (BK, dh) with BQ = BK = 512 by
default: VMEM ≈ (BQ + 2·BK)·dh·4 + BQ·BK·4 ≈ 2.3 MiB at dh = 128 — double
-buffering head-room in 16 MiB VMEM.  dh is padded to the 128-lane quantum
by the wrapper.

The backward pass uses the jnp chunked path (attention.py) via
``jax.custom_vjp`` — recompute-based, matching what the dry-run lowers;
a fused backward kernel is the natural next step on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import pallas_load

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, sk: int,
            causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, dh)
    dh = q.shape[-1]

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, dh), jnp.float32)

    n_kv = sk // bk
    if causal:
        # blocks strictly above the diagonal contribute nothing
        last = jnp.minimum(((qi + 1) * bq + bk - 1) // bk, n_kv)
    else:
        last = n_kv

    def body(ki, carry):
        m, l, acc = carry
        k = pallas_load(k_ref, (0, pl.dslice(ki * bk, bk), slice(None))
                        ).astype(jnp.float32)         # (bk, dh)
        v = pallas_load(v_ref, (0, pl.dslice(ki * bk, bk), slice(None))
                        ).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, last, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret",
                                    "scale"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True, bq: int = 512, bk: int = 512,
                           interpret: bool = False,
                           scale: "float | None" = None) -> jnp.ndarray:
    """q: (H, Sq, dh), k/v: (H, Sk, dh) — heads pre-broadcast (GQA groups
    expanded by the wrapper).  Sq % bq == 0, Sk % bk == 0, dh % 128 == 0
    (wrapper pads; pass ``scale`` = 1/sqrt(true_dh) when padded)."""
    h, sq, dh = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    kernel = functools.partial(_kernel, bq=bq, bk=bk, sk=sk, causal=causal,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((1, sk, dh), lambda hh, qi: (hh, 0, 0)),
            pl.BlockSpec((1, sk, dh), lambda hh, qi: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda hh, qi: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)

