"""Pallas TPU kernel: fused square-and-matmul for the implicit S-RSI operator.

    Y = (G * G) @ X          (m, n) x (n, s) -> (m, s)

``G**2`` is formed tile-by-tile in VMEM and fed straight to the MXU —
it never exists in HBM.  In the implicit second-moment operator

    V @ X = b2 * Q (U^T X) + (1 - b2) * (G*G) @ X

the low-rank half is a skinny matmul XLA handles well; this kernel covers
the dense half, which dominates (O(m n s) flops, O(m n) bytes).

Grid: (m/bm, n/bn) with accumulation over the contraction axis j (TPU grids
iterate sequentially, so the output block indexed by i alone is revisited
across j — initialised at j == 0, accumulated afterwards).  ``s`` (the
sketch width k + p) stays whole: it is <= a few hundred, so an (bm, s) f32
accumulator tile fits VMEM alongside the (bm, bn) G tile and (bn, s) X tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, x_ref, y_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    g = g_ref[...].astype(jnp.float32)          # (bm, bn)
    x = x_ref[...].astype(jnp.float32)          # (bn, s)
    y_ref[...] += jax.lax.dot_general(
        g * g, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def sq_matmul_pallas(g: jnp.ndarray, x: jnp.ndarray, bm: int = 256,
                     bn: int = 256, interpret: bool = False) -> jnp.ndarray:
    """g: (m, n), x: (n, s); m % bm == 0, n % bn == 0 (ops.py pads)."""
    m, n = g.shape
    s = x.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, s), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, s), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.float32),
        interpret=interpret,
    )(g, x)
