"""Pallas TPU kernel: fused count-min sketch EMA update + per-row query.

Computes, in ONE pass over the gradient and without a dense (rows, d)
second moment in HBM:

    S_t[j, b, :] = b2 * S_{t-1}[j, b, :]
                   + (1 - b2) * sum_{i : idx[j, i] = b} G[i, :]^2
    vhat[i, :]   = min_j S_t[j, idx[j, i], :]

Scatter and gather are both expressed as one-hot matmuls so the MXU does
the bucketing: per depth j, ``one_hot(idx[j])`` is (br, w) and the scatter
contribution is ``one_hot^T @ G^2`` -> (w, bd), while the query is
``one_hot @ S_t[j]`` -> (br, bd).  The gather matmul is exact in f32 (each
output row sums a single non-zero term); the scatter matmul sums colliding
rows in a different order than ``jax.ops.segment_sum``, so kernel-vs-oracle
parity is tolerance-level, like the other kernels in this package.

Grid (nd, 2, nr): d-blocks outermost, then phase, then row-blocks.  For a
fixed d-block the output table block (depth, w, bd) keeps the SAME index
across every (phase, row) step, so it stays resident in VMEM — phase 0
initialises it to ``b2 * S_{t-1}`` at the first row-block, accumulates the
scatter over row-blocks, and phase 1 reads the completed table back for
the min-over-depth gather (TPU grids run sequentially, so phase 0 finishes
before phase 1 starts).  The vhat block is fully overwritten in phase 1,
so its phase-0 placeholder write never matters.

VMEM: 2 * depth*w*bd (table in/out) + br*bd (G) + br*w (one-hot) f32.  At
the default depth = 4, w = 2048, bd = 128, br = 256 that is ~10.3 MiB —
inside the ~16 MiB budget; ops.py shrinks bd first when the table is
wider.  Padding contract (ops.py): padded rows carry zero gradient and
bucket 0, so they scatter no mass; padded buckets are never queried.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, g_ref, table_ref, b2_ref, new_ref, vhat_ref):
    phase = pl.program_id(1)
    i = pl.program_id(2)
    depth, w = table_ref.shape[0], table_ref.shape[1]
    br = g_ref.shape[0]
    b2 = b2_ref[0]
    idx = idx_ref[...]                                       # (depth, br)
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (br, w), 1)

    @pl.when(jnp.logical_and(phase == 0, i == 0))
    def _init():
        new_ref[...] = b2 * table_ref[...]

    @pl.when(phase == 0)
    def _scatter():
        g = g_ref[...].astype(jnp.float32)
        gsq = g * g
        for j in range(depth):                               # static unroll
            one_hot = (idx[j].reshape(br, 1) == iota_w).astype(jnp.float32)
            contrib = jax.lax.dot_general(
                one_hot, gsq, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)          # (w, bd)
            new_ref[j, :, :] = new_ref[j, :, :] + (1.0 - b2) * contrib
        vhat_ref[...] = jnp.zeros(vhat_ref.shape, jnp.float32)

    @pl.when(phase == 1)
    def _gather():
        acc = None
        for j in range(depth):
            one_hot = (idx[j].reshape(br, 1) == iota_w).astype(jnp.float32)
            got = jax.lax.dot_general(
                one_hot, new_ref[j, :, :], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)          # (br, bd)
            acc = got if acc is None else jnp.minimum(acc, got)
        vhat_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("br", "bd", "interpret"))
def sketch_update_pallas(table: jnp.ndarray, g: jnp.ndarray,
                         idx: jnp.ndarray, b2: jnp.ndarray,
                         br: int = 256, bd: int = 128,
                         interpret: bool = False):
    """table: (depth, w, d) f32, g: (rows, d), idx: (depth, rows) int32.
    rows % br == 0, d % bd == 0, w a lane multiple (ops.py pads).
    Returns (S_t (depth, w, d) f32, vhat (rows, d) f32)."""
    depth, w, d = table.shape
    rows = g.shape[0]
    nr, nd = rows // br, d // bd

    new, vhat = pl.pallas_call(
        _kernel,
        grid=(nd, 2, nr),
        in_specs=[
            pl.BlockSpec((depth, br), lambda dd, p, i: (0, i)),
            pl.BlockSpec((br, bd), lambda dd, p, i: (i, dd)),
            pl.BlockSpec((depth, w, bd), lambda dd, p, i: (0, 0, dd)),
            pl.BlockSpec(memory_space=pl.ANY),   # b2 scalar (1,)
        ],
        out_specs=[
            pl.BlockSpec((depth, w, bd), lambda dd, p, i: (0, 0, dd)),
            pl.BlockSpec((br, bd), lambda dd, p, i: (i, dd)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((depth, w, d), jnp.float32),
            jax.ShapeDtypeStruct((rows, d), jnp.float32),
        ],
        interpret=interpret,
    )(idx, g, table, jnp.reshape(b2.astype(jnp.float32), (1,)))
    return new, vhat
