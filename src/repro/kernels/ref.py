"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert_allclose against these, and ``ops.py`` falls back to them on
platforms without Pallas TPU lowering.
"""
from __future__ import annotations

import jax.numpy as jnp


def lowrank_update(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                   b2: float, eps: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Adapprox elementwise update.

        V    = b2 * max(Q @ U^T, 0) + (1 - b2) * G^2
        out  = G / (sqrt(V) + eps)
        vfro = ||V||_F^2                      (needed by adaptive rank)

    q: (m, r) f32, u: (n, r) f32, g: (m, n) any float.
    Returns (out: (m, n) f32, vfro: () f32).
    """
    g32 = g.astype(jnp.float32)
    v = (b2 * jnp.maximum(q.astype(jnp.float32) @ u.astype(jnp.float32).T, 0.0)
         + (1.0 - b2) * g32 * g32)
    out = g32 / (jnp.sqrt(v) + eps)
    return out, jnp.sum(v * v)


def sq_matmul(g: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Y = (G * G) @ X without materialising G^2.

    g: (m, n), x: (n, s) -> (m, s) f32.  The hot matvec of the implicit
    second-moment operator in S-RSI.
    """
    g32 = g.astype(jnp.float32)
    return (g32 * g32) @ x.astype(jnp.float32)


def sq_matmul_t(g: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Z = (G * G)^T @ Y.   g: (m, n), y: (m, s) -> (n, s) f32."""
    g32 = g.astype(jnp.float32)
    return (g32 * g32).T @ y.astype(jnp.float32)


def one_sided_fold(u: jnp.ndarray, q: jnp.ndarray, g: jnp.ndarray,
                   b2: float,
                   col_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Amortized-refresh factor fold (Adapprox ``refresh_every`` mode):

        U_t = mask * (b2 * U_{t-1} + (1 - b2) * (G^2)^T @ Q)

    i.e. the rank-projected EMA of the second moment under a FROZEN left
    basis Q.  Exact identity: with U = V^T Q this is V_t^T Q for
    V_t = b2 V_{t-1} + (1-b2) G^2 projected onto span(Q), so the stored
    pair (Q, U_t) keeps representing the implicit operator between full
    S-RSI refreshes.  u: (n, r), q: (m, r), g: (m, n) -> (n, r) f32.
    """
    u32 = u.astype(jnp.float32)
    folded = b2 * u32 + (1.0 - b2) * sq_matmul_t(g, q.astype(jnp.float32))
    if col_mask is not None:
        folded = folded * col_mask[None, :]
    return folded
