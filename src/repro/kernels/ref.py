"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert_allclose against these, and ``ops.py`` falls back to them on
platforms without Pallas TPU lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lowrank_update(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                   b2: float, eps: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Adapprox elementwise update.

        V    = b2 * max(Q @ U^T, 0) + (1 - b2) * G^2
        out  = G / (sqrt(V) + eps)
        vfro = ||V||_F^2                      (needed by adaptive rank)

    q: (m, r) f32, u: (n, r) f32, g: (m, n) any float.
    Returns (out: (m, n) f32, vfro: () f32).
    """
    g32 = g.astype(jnp.float32)
    v = (b2 * jnp.maximum(q.astype(jnp.float32) @ u.astype(jnp.float32).T, 0.0)
         + (1.0 - b2) * g32 * g32)
    out = g32 / (jnp.sqrt(v) + eps)
    return out, jnp.sum(v * v)


def fused_precond(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                  b2: float, eps: float, m1: jnp.ndarray | None = None,
                  with_vfro: bool = True, with_fold: bool = False):
    """Pass 1 of the fused two-pass update pipeline.

    Reconstructs V tile-wise (never stored), emits the raw update direction
    and every whole-matrix reduction the elementwise tail needs, so the
    clip / first-moment / guidance scalars can be combined on-host without
    re-reading the (m, n) operands:

        V     = b2 * max(Q @ U^T, 0) + (1 - b2) * G^2
        u_hat = G / (sqrt(V) + eps)              (UNclipped)
        vfro  = ||V||_F^2                        (adaptive rank / implicit
                                                  S-RSI frob_sq)
        usq   = sum(u_hat^2)                     (RMS clip)
        m1dot = sum(m1 * u_hat)   [m1 given]     (cosine guidance)
        m1sq  = sum(m1^2)         [m1 given]     (cosine guidance)
        yfold = (G^2)^T @ Q       [with_fold]    (amortized-refresh fold)

    q: (m, r) f32, u: (n, r) f32, g: (m, n), m1: (m, n) f32 | None.
    Returns (u_hat, vfro, usq, m1dot, m1sq, yfold); m1dot/m1sq are None
    when ``m1`` is None (guidance off or b1 = 0).  ``with_vfro=False``
    skips the ||V||_F^2 reduction and returns None for it — the
    optimizer's fold steps never consume it, and skipping saves a full
    pass over V's values on backends where the reduction doesn't ride the
    update loop.  ``with_fold=True`` additionally emits the fold
    projection ``(G^2)^T Q`` (n, r) — on the kernel path it rides pass 1's
    read of G, killing the standalone sq_matmul_t pass on fold steps; here
    it is the same ``sq_matmul_t`` expression the unfused fold uses, so
    consuming it keeps the fused == unfused bitwise contract.
    """
    g32 = g.astype(jnp.float32)
    # (1 - b2) must be computed in f32 (not python f64 then rounded) to stay
    # bitwise-identical to ImplicitV.materialize, which subtracts an f32 b2.
    b2f = jnp.asarray(b2, jnp.float32)
    v = (b2f * jnp.maximum(q.astype(jnp.float32) @ u.astype(jnp.float32).T,
                           0.0)
         + (1.0 - b2f) * g32 * g32)
    out = g32 / (jnp.sqrt(v) + eps)
    vfro = jnp.sum(v * v) if with_vfro else None
    usq = jnp.sum(jnp.square(out))
    yfold = sq_matmul_t(g32, q.astype(jnp.float32)) if with_fold else None
    if m1 is None:
        return out, vfro, usq, None, None, yfold
    m1f = m1.astype(jnp.float32)
    return (out, vfro, usq, jnp.sum(m1f * out), jnp.sum(jnp.square(m1f)),
            yfold)


def fused_apply(u_hat: jnp.ndarray, m1: jnp.ndarray | None,
                denom: jnp.ndarray, b1: float,
                out_scale: jnp.ndarray, store_scale: jnp.ndarray):
    """Pass 2 of the fused pipeline: one read-modify-write applying the RMS
    clip (division by the host-combined ``denom = max(1, rms/d)`` — division,
    not reciprocal-multiply, for bitwise parity with the unfused path), the
    update-EMA first moment, and the guidance scales:

        u_c    = u_hat / denom
        acc    = b1 * m1 + (1 - b1) * u_c
        m_out  = acc * out_scale
        m1_new = acc * store_scale

    ``out_scale``/``store_scale`` encode the guidance mode: (1, 1) = off,
    (s, 1) = "update", (s, s) = "stored".  With ``m1`` None (b1 = 0) the
    EMA collapses to ``m_out = u_c * out_scale`` and m1_new is None.
    Returns (m_out, m1_new).
    """
    u_c = u_hat / denom
    if m1 is None:
        return u_c * out_scale, None
    acc = b1 * m1 + (1.0 - b1) * u_c
    return acc * out_scale, acc * store_scale


def sq_matmul(g: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Y = (G * G) @ X without materialising G^2.

    g: (m, n), x: (n, s) -> (m, s) f32.  The hot matvec of the implicit
    second-moment operator in S-RSI.
    """
    g32 = g.astype(jnp.float32)
    return (g32 * g32) @ x.astype(jnp.float32)


def sq_matmul_t(g: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Z = (G * G)^T @ Y.   g: (m, n), y: (m, s) -> (n, s) f32."""
    g32 = g.astype(jnp.float32)
    return (g32 * g32).T @ y.astype(jnp.float32)


def sketch_update(table: jnp.ndarray, g: jnp.ndarray, idx: jnp.ndarray,
                  b2: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Count-min second-moment EMA update + per-row query, fused.

        S_t[j, b, :] = b2 * S_{t-1}[j, b, :]
                       + (1 - b2) * sum_{i : idx[j, i] = b} G[i, :]^2
        vhat[i, :]   = min_j S_t[j, idx[j, i], :]

    table: (depth, width, d) f32, g: (rows, d) any float,
    idx: (depth, rows) int32 hashed bucket per row per depth.
    Returns (S_t: (depth, width, d) f32, vhat: (rows, d) f32).

    The query never underestimates the exact per-row EMA: every bucket
    holds the row's own (non-negative) mass plus colliding rows', decayed
    uniformly, and min-over-depth preserves the bound.
    """
    g32 = g.astype(jnp.float32)
    gsq = g32 * g32
    # (1 - b2) in f32 for bitwise agreement with the rest of the package.
    b2f = jnp.asarray(b2, jnp.float32)
    width = table.shape[1]

    def per_depth(tab_j, idx_j):
        scat = jax.ops.segment_sum(gsq, idx_j, num_segments=width)
        return b2f * tab_j + (1.0 - b2f) * scat

    new = jax.vmap(per_depth)(table.astype(jnp.float32), idx)
    gathered = jax.vmap(lambda tab_j, idx_j: tab_j[idx_j])(new, idx)
    return new, jnp.min(gathered, axis=0)


def one_sided_fold(u: jnp.ndarray, q: jnp.ndarray, g: jnp.ndarray,
                   b2: float,
                   col_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Amortized-refresh factor fold (Adapprox ``refresh_every`` mode):

        U_t = mask * (b2 * U_{t-1} + (1 - b2) * (G^2)^T @ Q)

    i.e. the rank-projected EMA of the second moment under a FROZEN left
    basis Q.  Exact identity: with U = V^T Q this is V_t^T Q for
    V_t = b2 V_{t-1} + (1-b2) G^2 projected onto span(Q), so the stored
    pair (Q, U_t) keeps representing the implicit operator between full
    S-RSI refreshes.  u: (n, r), q: (m, r), g: (m, n) -> (n, r) f32.
    """
    u32 = u.astype(jnp.float32)
    folded = b2 * u32 + (1.0 - b2) * sq_matmul_t(g, q.astype(jnp.float32))
    if col_mask is not None:
        folded = folded * col_mask[None, :]
    return folded
