"""Pallas TPU kernel family for the perf-critical compute layers.

Every kernel is one member of a three-part contract:

  1. an **oracle** — a pure-jnp function defining the exact semantics
     (``ref.py`` for the optimizer kernels; the model zoo for attention /
     SSD).  Oracles are the ground truth for kernel tests AND the fast CPU
     execution path — they are written to be bitwise-compatible with the
     unfused optimizer arithmetic where the config contract requires it;
  2. a **Pallas kernel** — the TPU implementation in its own module,
     taking pre-padded block-aligned operands and raw scalars;
  3. a **dispatch wrapper** in ``ops.py`` — the only entry point callers
     use: it pads to block multiples, batches via vmap, and picks the
     backend per the mode ("auto" = compiled Pallas on TPU / oracle
     elsewhere; "pallas" = forced, interpret off-TPU — used by
     tests/test_kernels.py and the CI kernel job via REPRO_KERNEL_MODE;
     "ref" = forced oracle).

Family index (oracle <-> kernel module <-> ops wrapper):

  lowrank_update   ref.lowrank_update   <-> lowrank_update.py
      fused V-reconstruct + elementwise update (+ ||V||_F^2), the
      single-pass legacy path (``use_kernels`` without ``fused_update``)
  fused_precond    ref.fused_precond    <-> fused_update.py
      pass 1 of the two-pass fused pipeline: u_hat + per-tile partial
      reductions (sum V^2, sum u_hat^2, and with guidance dot(m1, u_hat),
      sum m1^2); V is never materialised in HBM.  Two optional riders on
      the same read of G:
        * ``with_fold=True`` (fold-fused pass 1) additionally emits the
          fold projection (G^2)^T Q as per-row-tile partials, summed on
          the host — on an amortized-refresh cadence, fold steps reuse
          pass 1's resident G tiles instead of paying the standalone
          ``sq_matmul_t`` pass (which reads a materialised G^T);
        * ``q`` / ``u`` may be :class:`repro.core.quantized.QuantizedMatrix`
          triples — the kernel dequantizes int8 factor tiles in VMEM
          (``_deq_tile``, the codec's exact affine formula + row masks),
          so fp32 factors never touch HBM on the update path
  fused_apply      ref.fused_apply      <-> fused_update.py
      pass 2: RMS clip + update-EMA first moment + guidance scales in one
      read-modify-write; m1 aliased in place (input_output_aliases);
      shared-output variant when the step direction IS the new moment
  sq_matmul(_t)    ref.sq_matmul(_t)    <-> srsi_matmul.py
      (G*G) @ X / (G*G)^T @ Y with the square fused — the S-RSI sketch
      matvecs of the implicit second-moment operator
  one_sided_fold   ref.one_sided_fold   <-> (composes sq_matmul_t)
      amortized-refresh factor fold U <- mask*(b2*U + (1-b2)(G^2)^T Q);
      standalone form — the fused pipeline gets the product from pass 1
  sketch_update    ref.sketch_update    <-> sketch_update.py
      fused count-min second-moment EMA scatter + min-over-depth query
      for the sketch state family (scale_by_sketch); one-hot matmuls do
      the bucketing on the MXU
  flash_attention  ops fallback softmax <-> flash_attention.py
      causal/GQA online-softmax attention forward
  ssd_chunk        models zoo reference <-> ssd_chunk.py
      Mamba2 SSD intra-chunk fusion

Dispatch-level machinery in ``ops.py`` (pallas paths only; the ref path
never pads, keeping the default chain's arithmetic untouched):

  * mixed-shape bucketing (default on; ``REPRO_KERNEL_BUCKETS=off`` or
    ``ops.set_bucketing(False)`` to disable): raw dims round up a coarse
    ladder before the block size is chosen, so near-miss leaf shapes land
    on a handful of padded kernel signatures instead of one compiled
    instance per (shape, r_store).  Bit-neutral on tensor outputs, f32
    roundoff on scalar tile reductions (tests/test_kernels.py);
  * dispatch census: ``ops.kernel_instances()`` counts distinct
    (kernel, padded shapes, block plan) signatures — exactly the jit
    cache keys — for tests and compile-time audits.

Byte-traffic claims for all of the above are modeled and floor-asserted
in benchmarks/roofline.py (``--quick`` runs in CI).

Use via ``repro.kernels.ops`` — never call kernel modules directly.
"""
from repro.kernels import ops
