"""Pallas TPU kernels for the perf-critical compute layers.

  lowrank_update — fused Adapprox V-reconstruct + elementwise update
  srsi_matmul    — fused (G*G) @ X sketch matmul
  flash_attention— causal/GQA online-softmax attention
  ssd_chunk      — Mamba2 SSD intra-chunk fusion

Use via repro.kernels.ops (wrappers with padding/batching/platform
dispatch); every kernel has a pure-jnp oracle in ref.py or the model zoo.
"""
from repro.kernels import ops
