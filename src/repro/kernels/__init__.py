"""Pallas TPU kernel family for the perf-critical compute layers.

Every kernel is one member of a three-part contract:

  1. an **oracle** — a pure-jnp function defining the exact semantics
     (``ref.py`` for the optimizer kernels; the model zoo for attention /
     SSD).  Oracles are the ground truth for kernel tests AND the fast CPU
     execution path — they are written to be bitwise-compatible with the
     unfused optimizer arithmetic where the config contract requires it;
  2. a **Pallas kernel** — the TPU implementation in its own module,
     taking pre-padded block-aligned operands and raw scalars;
  3. a **dispatch wrapper** in ``ops.py`` — the only entry point callers
     use: it pads to block multiples, batches via vmap, and picks the
     backend per the mode ("auto" = compiled Pallas on TPU / oracle
     elsewhere; "pallas" = forced, interpret off-TPU — used by
     tests/test_kernels.py and the CI kernel job via REPRO_KERNEL_MODE;
     "ref" = forced oracle).

Family index (oracle <-> kernel module <-> ops wrapper):

  lowrank_update   ref.lowrank_update   <-> lowrank_update.py
      fused V-reconstruct + elementwise update (+ ||V||_F^2), the
      single-pass legacy path (``use_kernels`` without ``fused_update``)
  fused_precond    ref.fused_precond    <-> fused_update.py
      pass 1 of the two-pass fused pipeline: u_hat + per-tile partial
      reductions (sum V^2, sum u_hat^2, and with guidance dot(m1, u_hat),
      sum m1^2); V is never materialised in HBM
  fused_apply      ref.fused_apply      <-> fused_update.py
      pass 2: RMS clip + update-EMA first moment + guidance scales in one
      read-modify-write; m1 aliased in place (input_output_aliases);
      shared-output variant when the step direction IS the new moment
  sq_matmul(_t)    ref.sq_matmul(_t)    <-> srsi_matmul.py
      (G*G) @ X / (G*G)^T @ Y with the square fused — the S-RSI sketch
      matvecs of the implicit second-moment operator
  one_sided_fold   ref.one_sided_fold   <-> (composes sq_matmul_t)
      amortized-refresh factor fold U <- mask*(b2*U + (1-b2)(G^2)^T Q)
  sketch_update    ref.sketch_update    <-> sketch_update.py
      fused count-min second-moment EMA scatter + min-over-depth query
      for the sketch state family (scale_by_sketch); one-hot matmuls do
      the bucketing on the MXU
  flash_attention  ops fallback softmax <-> flash_attention.py
      causal/GQA online-softmax attention forward
  ssd_chunk        models zoo reference <-> ssd_chunk.py
      Mamba2 SSD intra-chunk fusion

Use via ``repro.kernels.ops`` — never call kernel modules directly.
"""
from repro.kernels import ops
