"""Pallas TPU kernel: fused Adapprox elementwise update.

Computes, tile by tile and WITHOUT materialising V in HBM:

    V    = b2 * max(Q @ U^T, 0) + (1 - b2) * G^2        (per (bm, bn) tile)
    out  = G / (sqrt(V) + eps)
    vfro = sum(V^2)                                      (per-tile partials)

Memory-traffic analysis (the reason this kernel exists): the jnp path reads
G, writes V (m*n f32), reads V, writes out — 3x(m*n) f32 of HBM traffic plus
the factor reads.  The fused kernel reads G and the skinny factors once and
writes out once: ~2.4x less HBM traffic for the optimizer's elementwise
stage, which is memory-bound (arithmetic intensity ~r flops/byte on the
Q @ U^T tile, ~1 on the elementwise tail).

VMEM tiling: block (bm, r) of Q, (bn, r) of U, (bm, bn) of G live in VMEM;
the (bm, r) x (r, bn) product hits the MXU with r padded to a multiple of
128 by the wrapper in ops.py.  Default bm = bn = 256: VMEM footprint
~ 2*256*r_max*4 + 2*256*256*4 bytes ~= 1.5 MiB at r = 256 — comfortably
inside the ~16 MiB VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, u_ref, g_ref, b2_ref, eps_ref, out_ref, vfro_ref):
    q = q_ref[...].astype(jnp.float32)          # (bm, r)
    u = u_ref[...].astype(jnp.float32)          # (bn, r)
    g = g_ref[...].astype(jnp.float32)          # (bm, bn)
    b2 = b2_ref[0]
    eps = eps_ref[0]
    low = jax.lax.dot_general(q, u, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (bm, bn)
    v = b2 * jnp.maximum(low, 0.0) + (1.0 - b2) * g * g
    out_ref[...] = g / (jnp.sqrt(v) + eps)
    vfro_ref[0, 0] = jnp.sum(v * v)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def lowrank_update_pallas(q: jnp.ndarray, u: jnp.ndarray, g: jnp.ndarray,
                          b2: jnp.ndarray, eps: jnp.ndarray,
                          bm: int = 256, bn: int = 256,
                          interpret: bool = False):
    """q: (m, r) f32, u: (n, r) f32, g: (m, n).  m % bm == 0, n % bn == 0
    (ops.py pads).  Returns (out (m, n) f32, vfro () f32)."""
    m, r = q.shape
    n = u.shape[0]
    gm, gn = m // bm, n // bn

    out, vfro = pl.pallas_call(
        _kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pl.ANY),   # b2 scalar (1,)
            pl.BlockSpec(memory_space=pl.ANY),   # eps scalar (1,)
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ],
        interpret=interpret,
    )(q, u, g, jnp.reshape(b2.astype(jnp.float32), (1,)),
      jnp.reshape(eps.astype(jnp.float32), (1,)))
    return out, jnp.sum(vfro)
