"""Config system: model / optimizer / parallelism / run configs.

Everything is a frozen dataclass so configs hash (jit static args) and print
reproducibly.  Arch configs live in ``repro.configs.<id>`` and produce a
``ModelConfig``; launchers combine it with ``ParallelConfig`` +
``OptimizerConfig`` into a ``RunConfig``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    impl: str = "sort"            # "sort" (prod, EP-aware) | "einsum" (tiny)
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # P
    n_groups: int = 1
    chunk: int = 256              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    act: str = "swiglu"                     # swiglu | gelu | relu2
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"             # rope | learned | none
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    # hybrid (zamba2): a shared attention+MLP block applied every N ssm layers
    hybrid_attn_every: int = 0
    n_shared_blocks: int = 2
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # modality frontends are STUBS: input_specs() provides embeddings
    frontend: str = "none"                  # none | audio | vision
    frontend_tokens: int = 0                # vision patch tokens prepended
    dtype: str = "bfloat16"
    param_dtype: str = "float32"   # master params; 1T-scale uses bfloat16
    attn_impl: str = "auto"        # auto | chunked | naive (perf knob)
    parallel_strategy: str = "tp"  # tp (megatron TP x FSDP) | fsdp (ZeRO-3)
    scan_layers: bool = True
    remat: str = "full"                     # none | full | dots
    # which shape cells apply (see CELLS); long ctx only for sub-quadratic
    sub_quadratic: bool = False
    max_seq_len: int = 32_768

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), used for
        MODEL_FLOPS = 6*N*D in the roofline tables."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        n_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.act == "swiglu":
            n_mlp = 3 * d * f
        else:
            n_mlp = 2 * d * f
        if self.moe is not None:
            fe = self.moe.d_ff_expert
            n_mlp = self.moe.n_experts * 3 * d * fe + d * self.moe.n_experts
        n_ssm = 0
        if self.ssm is not None:
            di = self.ssm.expand * d
            conv_dim = di + 2 * self.ssm.n_groups * self.ssm.d_state
            nh = di // self.ssm.head_dim
            in_proj = d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state
                           + nh)
            n_ssm = in_proj + conv_dim * self.ssm.d_conv + di * d + di + 3 * nh
        if self.family == "ssm":
            per_layer = n_ssm + d
        elif self.family == "hybrid":
            per_layer = n_ssm + 2 * d
        else:
            per_layer = n_attn + n_mlp + 2 * d
        total = self.n_layers * per_layer + v * d
        if self.family == "hybrid":
            shared = n_attn + 3 * d * f + 2 * d
            total += self.n_shared_blocks * shared
        if self.enc_layers:
            enc_mlp = 2 * d * f
            total += self.enc_layers * (n_attn + enc_mlp + 2 * d)
            total += self.n_layers * n_attn          # decoder cross-attn
        if not self.tie_embeddings:
            total += v * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        fe = self.moe.d_ff_expert
        dense_moe = self.moe.n_experts * 3 * d * fe
        active_moe = self.moe.top_k * 3 * d * fe
        return self.param_count() - self.n_layers * (dense_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


CELLS = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def applicable_cells(model: ModelConfig) -> list[str]:
    """long_500k needs sub-quadratic attention: run for SSM/hybrid archs,
    skip (and record the skip) for pure full-attention archs."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if model.sub_quadratic:
        cells.append("long_500k")
    return cells


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """One parameter group inside :attr:`OptimizerConfig.groups`.

    ``select`` picks the leaves this group owns (first matching group in
    declaration order wins):

      * ``"embeddings"`` — >= 2-D leaves whose LEADING dim is at least
        ``OptimizerConfig.embedding_min_rows`` (vocab / embedding tables;
        list it before ``"factored"`` so the row-hashed sketch family
        takes them, first hit wins);
      * ``"factored"`` — >= 2-D leaves whose smaller trailing dim is at
        least ``OptimizerConfig.min_dim_factor`` (the same policy the
        factored second moments use);
      * ``"matrices"`` — every >= 2-D leaf;
      * ``"vectors"``  — < 2-D leaves (biases, norm scales, scalars);
      * ``"rest"``     — catch-all (every groups tuple must end in one).

    ``name`` is the optimizer family for the group (adapprox | adamw |
    adafactor | came | sketch); ``None`` inherits the parent config's
    ``name``.
    ``lr_scale`` is a per-group LR multiplier applied inside the group's
    ``scale_by_schedule`` stage (shared warmup/decay shape, scaled peak).
    """

    select: str = "rest"
    name: Optional[str] = None
    lr_scale: float = 1.0


def default_mixed_groups() -> tuple:
    """The production mixed partition, three state families: the count-min
    sketch on embedding tables (rows update sparsely and the spectrum is
    flat — the regime where a low-rank basis wastes memory and refresh
    FLOPs), the factored family (Adapprox by default) on matrices, and
    bias-corrected dense Adam on 1-D / small leaves (Adafactor-style
    blanket factorization costs accuracy on leaves it barely saves memory
    on).  Declaration order matters: ``"embeddings"`` is listed first so
    wide tables hit the sketch before ``"factored"`` can claim them; with
    the default ``embedding_min_rows`` threshold nothing below a real
    vocab-sized table routes there."""
    return (("embeddings", GroupSpec(select="embeddings", name="sketch")),
            ("factored", GroupSpec(select="factored")),
            ("dense", GroupSpec(select="rest", name="adamw")))


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Declarative optimizer spec — the single input to
    ``repro.core.build_optimizer``, which lowers it to a chain of
    ``scale_by_*`` transformation primitives.

    ``name`` selects the preconditioner family (adapprox | adamw |
    adafactor | came | sketch); the schedule block builds the LR schedule;
    the
    decay block controls decoupled weight decay and its parameter mask;
    the remaining groups are family-specific knobs (ignored by families
    that don't use them).

    ``groups`` (optional) partitions the parameters into labeled groups,
    each lowered to its own chain and routed through
    ``repro.core.partition``: a tuple of ``(label, GroupSpec)`` pairs,
    matched in order (first hit wins; the last group must be a ``"rest"``
    catch-all).  ``default_mixed_groups()`` is the production default —
    dense Adam on 1-D/small leaves, the parent family on matrices.
    """

    name: str = "adapprox"
    # LR schedule: "cosine" = linear warmup + cosine decay to min_lr
    # (repro.core.Schedule); "constant" = fixed lr.
    lr: float = 3e-4
    schedule: str = "cosine"        # cosine | constant
    warmup_steps: int = 1000
    total_steps: int = 100_000
    min_lr: float = 5e-5
    # shared moment/decay knobs
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    clip_d: float = 1.0             # RMS update clip (adapprox/adafactor/came)
    weight_decay: float = 0.1
    decay_mask: str = "all"         # all | no_1d (exempt biases/norms/scalars)
    # adapprox specifics
    rank_mode: str = "static"       # static | paper | exact
    k: int = 64                     # static rank / k_init (adaptive)
    k_max: int = 256
    xi_thresh: float = 0.01
    delta_s: int = 10
    oversample: int = 5
    n_iter: int = 5
    guidance: str = "off"
    implicit: bool = True
    use_kernels: bool = False
    # amortized-refresh perf knobs (defaults off => paper-faithful cadence):
    # refresh_every=T runs full S-RSI every T steps and folds G^2 into the
    # factors in between; warm_start seeds S-RSI from the stored U so
    # n_iter_warm (1-2) power iterations suffice; bucketed groups
    # same-shape leaves into one vmapped trace per bucket.
    refresh_every: int = 1
    warm_start: bool = False
    n_iter_warm: int = 1
    warm_drift_xi: float = 0.5
    bucketed: bool = False
    # fused_update runs the elementwise tail (V-reconstruct, RMS clip,
    # update-EMA first moment, guidance) as the two-pass fused pipeline
    # (kernels/fused_update.py); bit-exact vs the unfused path when
    # guidance="off", fp-tolerance otherwise.
    fused_update: bool = False
    # telemetry subsystem (repro.telemetry; both default-off => the state
    # pytree and the update arithmetic are unchanged vs pre-telemetry):
    # telemetry carries a fixed-shape per-step TelemetrySnapshot (per-leaf
    # xi / rank / clip activation + refresh-vs-fold counters) inside the
    # adapprox state; dynamic_refresh promotes refresh_every to a traced
    # int32 state scalar so the closed-loop controller can retune the
    # cadence at runtime with zero recompilation.
    telemetry: bool = False
    dynamic_refresh: bool = False
    # resilience guards (repro.resilience; default off => the built chain
    # and its state pytree are unchanged): guards wraps the WHOLE chain in
    # the non-finite skip-step guard and arms the per-leaf xi watchdog —
    # a leaf whose approximation error exceeds guard_xi_trip gets a forced
    # full S-RSI refresh on the next step, and after max_demotions
    # CONSECUTIVE trips it falls back to the exact dense second moment
    # (max_demotions=0 disables demotion and its dense shadow buffers).
    guards: bool = False
    guard_xi_trip: float = 0.75
    max_demotions: int = 0
    min_dim_factor: int = 128       # factor leaves with min(m, n) >= this
    factor_dtype: str = "float32"   # "int8": quantized factors
    # quantize_factors is the launcher-facing alias for
    # factor_dtype="int8" (core/quantized.py per-block codec): ~4x smaller
    # stored factors, and with fused_update the dequant fuses into the
    # pass-1 tile loads so the f32 factors never materialize in HBM.
    quantize_factors: bool = False
    seed: int = 0
    # sketch family (count-min second moment for embedding tables;
    # core/sketch.py): depth x width buckets per leaf, hashed over the
    # leading (row) axis.  embedding_min_rows doubles as the "embeddings"
    # GroupSpec selector threshold — >= 2-D leaves with at least this many
    # rows route to the sketch group in mixed chains.
    sketch_width: int = 2048
    sketch_depth: int = 4
    embedding_min_rows: int = 1024
    # adafactor specifics
    b2_schedule: bool = True        # b2_t = 1 - t^-0.8
    relative_step: bool = False
    # came specifics
    b3: float = 0.9999              # instability-statistic decay
    # parameter groups: (label, GroupSpec) pairs -> repro.core.partition
    groups: tuple = ()


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Host-side telemetry + closed-loop refresh control
    (``repro.telemetry``; see that package's docstring for the JSONL event
    schema).

    ``enabled`` turns on in-jit collection (``OptimizerConfig.telemetry``)
    and the per-step host fetch; ``dir`` adds the async JSONL sink
    (``None`` = collect + control without writing events);
    ``auto_refresh`` adds the feedback controller, which adapts
    ``refresh_every`` per parameter group from observed xi drift — it
    requires the cadence to be traced (``OptimizerConfig.dynamic_refresh``)
    so retuning never recompiles.  Controller policy: at every
    ``interval``-step boundary, the interval-mean xi per group is compared
    against a hysteresis band — ``>= xi_high`` divides the cadence by
    ``tighten_div`` (refresh more often; xi is regressing toward the
    warm-start drift guard), ``<= xi_low`` for ``relax_patience``
    consecutive intervals adds ``relax_add`` (refresh less often; the
    basis is tracking well), in between nothing moves.  Cadences are
    clamped to [t_min, t_max].
    """

    enabled: bool = False
    dir: Optional[str] = None            # JSONL sink directory (None = off)
    emit_every: int = 1                  # emit events every N steps
    rotate_bytes: int = 32 * 1024 * 1024
    auto_refresh: bool = False           # closed-loop cadence controller
    interval: int = 25                   # steps between cadence decisions
    t_min: int = 1
    t_max: int = 50
    xi_high: float = 0.25                # tighten when interval-mean xi >=
    xi_low: float = 0.10                 # relax when <= (with patience)
    relax_patience: int = 2
    tighten_div: int = 2
    relax_add: int = 1

    def __post_init__(self):
        if self.emit_every < 1:
            raise ValueError(f"emit_every must be >= 1, "
                             f"got {self.emit_every}")
        if self.rotate_bytes < 1:
            raise ValueError(f"rotate_bytes must be >= 1, "
                             f"got {self.rotate_bytes}")


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    # mesh axis sizes; pod=1 => single-pod (16, 16) production mesh
    pods: int = 1
    data: int = 16
    model: int = 16
    fsdp: bool = True               # shard params/opt-state over data axis
    microbatches: int = 1           # gradient accumulation
    remat: str = "full"
    moe_gather_axis: Optional[str] = "data"   # FSDP-gather expert weights


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    opt: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    parallel: ParallelConfig = dataclasses.field(
        default_factory=ParallelConfig)
    seed: int = 0
