"""Pipeline parallelism (GPipe schedule) over a mesh axis.

Layers are split into S stages, the stage dim sharded over ``axis``; each
tick every stage processes one microbatch and hands its activation to the
next stage with a ``ppermute``.  The bubble is the usual (S-1)/(M+S-1)
fraction.  Because ``ppermute`` is differentiable (its transpose is the
reverse permute), the whole pipeline is a plain jax function: ``jax.grad``
through ``pipeline_apply`` yields the reverse-schedule backward pass with
no extra machinery.

Intended use on the production mesh: stages over the ``pod`` axis (cross-
pod DCN carries only the (mb, seq, d_model) boundary activations instead
of full gradient all-reduces — the classic reason to pipeline across the
slow domain).  The unit test runs 4 stages on 4 host devices and checks
exact equivalence with sequential layer application, forward and grad.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map



def pipeline_apply(stage_fn: Callable, stage_params, x_micro,
                   mesh: Mesh, axis: str = "stage"):
    """Run ``stage_fn(params_stage, x) -> x`` as an S-stage GPipe pipeline.

    stage_params: pytree with leading (S, ...) dim, sharded over ``axis``.
    x_micro: (M, mb, ...) microbatched inputs (replicated).
    Returns (M, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]

    def body(params_local, micro):
        # params_local leaves: (1, ...) local stage slice
        params_local = jax.tree.map(lambda p: p[0], params_local)
        s_idx = jax.lax.axis_index(axis)
        m = micro.shape[0]
        ticks = m + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            outputs, prev = carry
            # stage 0 injects microbatch t (bubble ticks feed zeros)
            inject = jnp.where(t < m, 1, 0)
            x_in = jnp.where(s_idx == 0,
                             micro[jnp.clip(t, 0, m - 1)]
                             * inject.astype(micro.dtype),
                             prev)
            y = stage_fn(params_local, x_in)
            # last stage commits microbatch t - (S-1)
            out_idx = t - (n_stages - 1)
            outputs = jnp.where(
                (s_idx == n_stages - 1) & (out_idx >= 0),
                outputs.at[jnp.clip(out_idx, 0, m - 1)].set(y),
                outputs)
            prev = jax.lax.ppermute(y, axis, perm)
            return (outputs, prev), None

        outputs = jnp.zeros_like(micro)
        prev = jnp.zeros_like(micro[0])
        (outputs, _), _ = jax.lax.scan(tick, (outputs, prev),
                                       jnp.arange(ticks))
        # everyone returns; only the last stage's buffer is nonzero, so a
        # psum broadcasts it (small boundary tensor, one hop in practice)
        return jax.lax.psum(outputs, axis)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False)
    return fn(stage_params, x_micro)


def split_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-major."""
    def rs(p):
        l = p.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return p.reshape((n_stages, l // n_stages) + p.shape[1:])
    return jax.tree.map(rs, layer_params)


def stage_fn_from_layers(layer_fn: Callable) -> Callable:
    """layer_fn(params_layer, x) -> x  lifted to a stage (scan over the
    stage's layer slice)."""
    def stage(params_stage, x):
        def body(x, lp):
            return layer_fn(lp, x), None
        x, _ = jax.lax.scan(body, x, params_stage)
        return x
    return stage
