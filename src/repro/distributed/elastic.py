"""Elastic scaling: resume a checkpoint under a different mesh.

The serialization layer stores LOGICAL (global) arrays, so elasticity is:
  1. detect world size / topology at startup,
  2. build the new mesh + shardings,
  3. ``restore_pytree(..., shardings=new)`` — placement happens at load.

Data-stream elasticity is handled by the deterministic pipeline: batch t is
a pure function of (seed, step), so any host subset re-derives its slice
after re-partitioning (data/pipeline.py host_slice).

``plan_remesh`` is the policy piece: given a device count (possibly after
losing nodes) choose the nearest valid (pod, data, model) factorisation,
preferring to shrink the data axis (keeps TP intact so per-layer math and
factored-optimizer shapes are unchanged — only FSDP shard sizes move).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    pods: int
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.pods * self.data * self.model

    def axes(self) -> tuple:
        if self.pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")

    def shape(self) -> tuple:
        if self.pods > 1:
            return (self.pods, self.data, self.model)
        return (self.data, self.model)


def plan_remesh(available_devices: int, target_model: int = 16,
                max_pod_data: int = 16) -> MeshPlan:
    """Largest usable mesh with the given TP degree.

    Keeps `model` fixed (so parameter shard shapes are stable across the
    restart), re-factorises the rest into (pods, data).  Devices that do
    not fit the factorisation are left idle — the deterministic data
    pipeline re-balances over the surviving data shards.
    """
    if available_devices < target_model:
        # degrade TP as the last resort (power of two below the count)
        tm = 1
        while tm * 2 <= available_devices:
            tm *= 2
        target_model = tm
    usable = available_devices // target_model
    data = min(usable, max_pod_data)
    pods = usable // data
    return MeshPlan(pods=max(pods, 1), data=max(data, 1),
                    model=target_model)


def build_mesh(plan: MeshPlan):
    return jax.make_mesh(plan.shape(), plan.axes())


def elastic_restore(ckpt_manager, like, make_shardings, *,
                    available_devices: Optional[int] = None,
                    target_model: int = 16):
    """End-to-end elastic resume: plan mesh -> build shardings -> restore.

    make_shardings(mesh) -> sharding pytree matching ``like``.
    Returns (state, step, mesh).
    """
    n = available_devices or len(jax.devices())
    plan = plan_remesh(n, target_model=target_model)
    mesh = build_mesh(plan)
    shardings = make_shardings(mesh)
    state, step = ckpt_manager.restore(like, shardings)
    return state, step, mesh
