"""Logical-axis -> mesh-axis sharding rules, per shape-cell kind.

Parallelism map (production mesh (pod, data, model) = (2, 16, 16)):

  * pod   — pure data parallelism between pods (DCN domain: only gradient
            all-reduce crosses it).
  * data  — data parallelism + FSDP (params & optimizer states sharded over
            it; GSPMD all-gathers weights per layer under the scan).
  * model — tensor parallelism (heads / mlp / vocab / ssm-inner), expert
            parallelism (MoE), and the sequence axis of KV caches at decode
            (flash-decoding-style partial softmax).

Rule tables below map each *logical* axis name used by the model zoo to a
mesh axis per cell kind.  Optimizer-state shardings are derived from the
param specs through each transformation's ``state_sharding_spec`` protocol
hook (factored Q inherits the row spec, U the column spec — the factors of
a sharded matrix shard along the same axes; a ``partition`` of transforms
recurses per group through ``PartitionState``'s static labels); this module
knows nothing about any optimizer's state classes.

This module is the middle of the sharded training path::

    launch/train.py --mesh D,M [--fsdp] [--mixed-groups]
        -> launch.mesh (build the device mesh)
        -> param_pspecs / param_shardings          (this module)
        -> opt_state_shardings / train_shardings   (this module, via the
           state_sharding_spec protocol hook)
        -> train_loop.train(jit(step, in_shardings=..., out_shardings=...,
                            donate_argnums=...), batch_shardings)
        -> checkpoint/serialization.py (saves logical arrays + per-leaf
           spec metadata; restore re-places under ANY mesh's shardings —
           elastic re-scaling and single-host debugging use the same path)

Every ``*_pspecs`` function works from mesh *axis sizes* alone (pass a
``Mesh`` or a plain ``{axis: size}`` mapping), so memory accounting and
planning tools can reason about shardings without real (or virtual)
devices; the ``*_shardings`` variants bind the specs to a live ``Mesh``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core import types as T


def mesh_axis_sizes(mesh) -> dict:
    """``{axis: size}`` for a ``Mesh`` — or pass a mapping straight through
    (the spec-only entry points accept either)."""
    if isinstance(mesh, dict):
        return mesh
    return dict(mesh.shape)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh_axis_sizes(mesh))


# --------------------------------------------------------------------------
# Logical -> mesh rules
# --------------------------------------------------------------------------

def rules_for(cfg: ModelConfig, kind: str, mesh,
              fsdp: bool = True) -> dict:
    """kind: train | prefill | decode."""
    has_data = "data" in mesh_axis_sizes(mesh)
    fsdp_axis = "data" if (fsdp and has_data and kind == "train") else None
    # MoE expert stacks always keep FSDP storage (1T-param models don't fit
    # otherwise); dense weights drop it at decode (latency path).
    moe_fsdp = "data" if has_data else None

    rules = {
        # tensor-parallel dims
        "q_heads": "model", "kv_heads": "model", "mlp": "model",
        "vocab": "model", "experts_router": "model",
        "ssm_proj": "model", "ssm_inner": "model", "ssm_conv": "model",
        # FSDP dim of dense weights
        "embed": fsdp_axis,
        # MoE expert stacks: experts -> EP axis, d_model dim -> FSDP
        "experts": "model",
        "expert_mlp": None,
        # stacking dims never shard
        "layers": None, "shared": None,
    }
    return rules


def spec_from_axes(axes: tuple, rules: dict) -> P:
    parts = []
    for ax in axes:
        r = rules.get(ax) if ax is not None else None
        parts.append(r)
    return P(*parts)


def sanitize_spec(spec: P, shape: tuple, mesh) -> P:
    """Adjust mesh axes whose size does not divide the dim (jit argument
    shardings require exact divisibility).  Single axes fall back to
    replicated; tuple axes reduce to the largest-product contiguous
    subtuple that divides (e.g. batch 256 over (pod, data, model) = 512
    devices -> (data, model) = 256, replicated over the pod axis).  Axes
    the mesh does not have at all (e.g. ``model`` on a data-only FSDP
    mesh) are dropped the same way — the rule tables can stay
    mesh-agnostic."""
    sizes = mesh_axis_sizes(mesh)

    def axsize(axes):
        n = 1
        for a in axes:
            if a not in sizes:
                return 0               # unknown axis: never divides
            n *= sizes[a]
        return n

    parts = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            parts.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n_all = axsize(axes)
        if n_all and dim % n_all == 0:
            parts.append(ax)
            continue
        best, best_n = None, 1
        for i in range(len(axes)):
            for j in range(i + 1, len(axes) + 1):
                sub = axes[i:j]
                n = axsize(sub)
                if n and dim % n == 0 and n > best_n:
                    best, best_n = sub, n
        parts.append(best if best else None)
    return P(*parts)


def param_pspecs(model, mesh, kind: str, fsdp: bool = True):
    """Tree of PartitionSpec mirroring params (divisibility-sanitized).

    ``mesh`` may be a ``Mesh`` or a ``{axis: size}`` mapping — specs only
    depend on axis names and sizes, so planning/accounting tools can call
    this without any devices."""
    cfg = model.cfg
    if getattr(cfg, "parallel_strategy", "tp") == "fsdp":
        return _fsdp_param_pspecs(model, mesh)
    rules = rules_for(cfg, kind, mesh, fsdp)
    # expert-stack d_model dim keeps FSDP storage even outside train
    moe_rules = dict(rules)
    if "data" in mesh_axis_sizes(mesh):
        if kind == "decode":
            # weights-stationary EP-TP layout (moe_apply_ep_tp): experts
            # over model, FFN dim over data — zero weight movement/step
            moe_rules["embed"] = None
            moe_rules["expert_mlp"] = "data"
        else:
            moe_rules["embed"] = "data"

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    spec_tree = model.param_specs()

    def one(axes, leaf):
        table = moe_rules if "experts" in axes or "expert_mlp" in axes \
            else rules
        return sanitize_spec(spec_from_axes(axes, table), leaf.shape, mesh)

    flat_axes = jax.tree.leaves(spec_tree,
                                is_leaf=lambda x: isinstance(x, tuple))
    flat_leaves, treedef = jax.tree.flatten(params_struct)
    return jax.tree.unflatten(
        treedef, [one(a, l) for a, l in zip(flat_axes, flat_leaves)])


def _fsdp_param_pspecs(model, mesh):
    """Pure ZeRO-3: every >=2D leaf shards its -2 dim over ALL mesh axes
    (flattened); 1D leaves shard over the same when divisible.  No tensor
    parallelism — activations stay fully local, the per-layer weight
    all-gather is the only collective in the forward."""
    all_axes = tuple(mesh_axis_sizes(mesh).keys())
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def one(leaf):
        nd = len(leaf.shape)
        if nd >= 2:
            parts = [None] * nd
            parts[-2] = all_axes
            spec = P(*parts)
        elif nd == 1:
            spec = P(all_axes)
        else:
            spec = P()
        return sanitize_spec(spec, leaf.shape, mesh)

    return jax.tree.map(one, params_struct)


def param_shardings(model, mesh: Mesh, kind: str, fsdp: bool = True):
    """Tree of NamedSharding mirroring params: :func:`param_pspecs` bound
    to a live mesh."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(model, mesh, kind, fsdp),
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# Optimizer state shardings
# --------------------------------------------------------------------------

def opt_state_shardings(opt: T.GradientTransformation, state_struct,
                        pspecs_tree, mesh: Mesh):
    """Build the sharding pytree matching ``opt.init``'s state, from the
    param PartitionSpecs, via the transformation's ``state_sharding_spec``
    protocol hook (transformations without one get fully replicated
    state).  Works for any chain / partition of transformations — this
    module never inspects optimizer state classes."""
    spec_tree = T.state_sharding_spec(opt, state_struct, pspecs_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def train_shardings(model, opt: T.GradientTransformation, mesh: Mesh,
                    batch_struct: Optional[dict] = None, *,
                    kind: str = "train", fsdp: bool = True):
    """One-call derivation of the sharded training run's placement:
    returns ``(state_shardings, batch_shardings)`` where
    ``state_shardings`` is a ``TrainState``-shaped tree of NamedSharding
    (params by the rule tables, optimizer state through the
    ``state_sharding_spec`` protocol — including ``partition`` chains —
    and a replicated step counter) and ``batch_shardings`` places
    ``DataIterator`` batches over the data-parallel axes (``None`` when no
    ``batch_struct`` is given).  This is what ``launch/train.py`` feeds to
    ``train_loop.train``'s ``jax.jit(step, in_shardings=...,
    out_shardings=..., donate_argnums=...)``."""
    from repro.train.steps import TrainState  # lazy: avoid import cycle

    pspecs = param_pspecs(model, mesh, kind, fsdp)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    state_struct = jax.eval_shape(
        lambda p: TrainState.create(p, opt), params_struct)
    oshard = opt_state_shardings(opt, state_struct.opt_state, pspecs, mesh)
    state_shardings = TrainState(params=pshard, opt_state=oshard,
                                 step=NamedSharding(mesh, P()))
    bshard = (batch_shardings(model.cfg, kind, mesh, batch_struct)
              if batch_struct is not None else None)
    return state_shardings, bshard


# --------------------------------------------------------------------------
# Activations / batch / cache shardings
# --------------------------------------------------------------------------

def batch_shardings(cfg: ModelConfig, kind: str, mesh: Mesh,
                    batch_specs: dict):
    """tokens (B, S) -> B over dp; under the fsdp strategy the batch
    spreads over every mesh axis (no TP -> model axis is extra DP)."""
    if getattr(cfg, "parallel_strategy", "tp") == "fsdp":
        dp = tuple(mesh.shape.keys())
    else:
        dp = dp_axes(mesh)
    dpp = dp if dp else None
    seq = None   # chunked attention scans the seq dim; SP would force gathers

    out = {}
    for name, sds in batch_specs.items():
        if name == "tokens":
            spec = P(dpp, seq)
        else:  # embeds (B, F, D)
            spec = P(dpp, seq, None)
        out[name] = NamedSharding(mesh, sanitize_spec(spec, sds.shape, mesh))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_struct,
                    long_context: bool):
    """KV caches: batch over dp, sequence over model (flash-decoding).
    long_500k (B = 1): sequence over (data, model) — all 256 chips split
    the cache.  Mamba states: heads over model."""
    dp = dp_axes(mesh)
    dpp = dp if dp else None
    seq_ax = (tuple(dp) + ("model",)) if long_context else "model"
    b_ax = None if long_context else dpp

    def one(path, leaf):
        nd = len(leaf.shape)
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if name.endswith("pos"):
            return NamedSharding(mesh, P())
        if "mamba" in name and nd == 5:    # ssm state (L, B, H, P, N)
            spec = P(None, b_ax, "model", None, None)
        elif "mamba" in name and nd == 4:  # conv state (L, B, K, C)
            spec = P(None, b_ax, None, "model")
        elif "cross" in name and nd == 6:  # whisper (L, 2, B, S_enc, KV, dh)
            spec = P(None, None, b_ax, "model", None, None)
        elif nd == 5:                      # kv cache (L, B, S, KV, dh)
            spec = P(None, b_ax, seq_ax, None, None)
        else:
            spec = P()
        return NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_struct)


def make_act_constrainer(mesh: Optional[Mesh], kind: str,
                         long_context: bool = False,
                         all_axes_batch: bool = False):
    """Activation sharding constraints (batch over dp, sequence over model
    for prefill).  Without these, mixed gather/scatter shardings (embedding
    lookups) make GSPMD drop the batch sharding and replicate every scan
    carry — observed +25 GiB/device on qwen2-7b train before this hook."""
    if mesh is None:
        return lambda x, *_, **__: x
    dp = tuple(mesh.shape.keys()) if all_axes_batch else dp_axes(mesh)
    dpp = (dp if (dp and not long_context) else None)
    seq = None

    def constrain(x):
        if not hasattr(x, "ndim") or x.ndim not in (2, 3):
            return x
        spec = P(dpp, seq, None) if x.ndim == 3 else P(dpp, seq)
        spec = sanitize_spec(spec, x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def logits_sharding(mesh: Mesh, long_context: bool = False):
    dp = dp_axes(mesh)
    return NamedSharding(mesh, P(dp if (dp and not long_context) else None,
                                 None, "model"))
