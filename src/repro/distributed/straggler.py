"""Straggler detection & mitigation hooks.

On a real multi-pod job, stragglers show up as step-time outliers on
specific hosts.  The policy layer here is runnable anywhere (and unit
tested with synthetic timings); the actuation hooks are where a cluster
integration plugs in.

Detection: robust z-score (median / MAD) over a sliding window of per-step
(or per-host) durations.  Mitigation ladder:
  1. log + export metric (always),
  2. re-shuffle data assignment away from the slow host (cheap),
  3. request replacement + checkpoint-restart (the elastic path,
     distributed/elastic.py) when slowness persists.

Observability: pass a ``repro.telemetry.TelemetrySink`` and every flag /
escalation is emitted as a ``kind="straggler"`` event on the SAME stream
(and schema) the optimizer telemetry uses — one event log per run instead
of a private side channel.  The ``escalations`` list keeps working either
way (the elastic-restart policy layer consumes it).
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Callable, Deque, Optional


@dataclasses.dataclass
class StragglerConfig:
    window: int = 50              # sliding window of step times
    z_thresh: float = 4.0         # robust z-score to flag
    persist: int = 10             # consecutive flags before escalation
    min_steps: int = 20           # warmup before judging


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig(),
                 escalate: Optional[Callable[[str], None]] = None,
                 sink=None):
        self.cfg = cfg
        self.times: Deque[float] = collections.deque(maxlen=cfg.window)
        self.flags = 0
        self.n_steps = 0
        self.escalations: list[str] = []
        self._escalate = escalate or self.escalations.append
        self._sink = sink
        self._t0: Optional[float] = None

    def _emit(self, event: str, step_time: float, z: float):
        if self._sink is None:
            return
        self._sink.emit({
            "kind": "straggler", "event": event, "n_steps": self.n_steps,
            "step_time_s": float(step_time), "median_s": self.median,
            "z": float(z), "flags": self.flags})

    # -- timing helpers -----------------------------------------------------
    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.observe(dt)
        return dt

    # -- policy ---------------------------------------------------------------
    def observe(self, step_time: float) -> bool:
        """Feed one step duration; returns True if this step is flagged."""
        flagged = False
        z = 0.0
        if len(self.times) >= self.cfg.min_steps:
            med = statistics.median(self.times)
            mad = statistics.median(abs(t - med) for t in self.times) + 1e-9
            z = 0.6745 * (step_time - med) / mad
            flagged = z > self.cfg.z_thresh
        self.times.append(step_time)
        self.n_steps += 1
        if flagged:
            self.flags += 1
            self._emit("flagged", step_time, z)
            if self.flags >= self.cfg.persist:
                self._escalate(
                    f"straggler persisted {self.flags} steps "
                    f"(last={step_time:.3f}s median="
                    f"{statistics.median(self.times):.3f}s)")
                self._emit("escalated", step_time, z)
                self.flags = 0
        else:
            self.flags = 0
        return flagged

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0
