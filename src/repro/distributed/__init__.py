from repro.distributed import sharding
from repro.distributed.compression import (CompressionConfig,
                                           compress_gradients)
from repro.distributed.elastic import MeshPlan, build_mesh, plan_remesh
from repro.distributed.straggler import StragglerConfig, StragglerMonitor
from repro.distributed.pipeline import (pipeline_apply, split_stages,
                                        stage_fn_from_layers)
