"""Low-rank gradient compression for data-parallel all-reduce (beyond-paper).

The same randomized-subspace machinery Adapprox uses for optimizer *state*
also compresses optimizer *communication*: PowerSGD-style (Vogels et al.)
rank-r compression with error feedback, built on repro.core.srsi.

    g_hat = Q (Q^T g)         Q from one subspace iteration on (g + error)
    error <- g + error - g_hat            (error feedback keeps it unbiased
                                           in the long run)

Per-matrix DP all-reduce volume drops from O(mn) to O(r (m + n)) — on the
production mesh that is the pod-axis (DCN) traffic, the slowest link in the
system.  Convergence contract is validated in tests/test_compression.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import srsi as S
from repro.core.types import GradientTransformation


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    rank: int = 8
    min_dim: int = 128          # compress only matrices with min dim >= this
    n_iter: int = 1             # subspace iterations (PowerSGD uses 1)
    seed: int = 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    step: jnp.ndarray
    error: Any                  # pytree: error-feedback residuals (or None)


def _compressible(shape, min_dim):
    return len(shape) >= 2 and min(shape[-2], shape[-1]) >= min_dim


def compress_gradients(cfg: CompressionConfig) -> GradientTransformation:
    """A GradientTransformation that replaces each large-matrix gradient by
    its rank-r approximation (+ error feedback).  Chain it BEFORE the
    optimizer; in the sharded step the all-reduce then happens on the
    factors, not the dense gradient."""

    def init(params):
        err = jax.tree.map(
            lambda p: (jnp.zeros(p.shape, jnp.float32)
                       if _compressible(p.shape, cfg.min_dim) else None),
            params)
        return CompressionState(step=jnp.zeros((), jnp.int32), error=err)

    def update(grads, state: CompressionState, params):
        step = state.step + 1
        base = jax.random.PRNGKey(cfg.seed)
        key = jax.random.fold_in(base, step)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(state.error)

        out_g, out_e = [], []
        for i, (g, e) in enumerate(zip(flat_g, flat_e)):
            if e is None:
                out_g.append(g)
                out_e.append(None)
                continue
            g32 = g.astype(jnp.float32) + e

            def comp2d(mat, k):
                res = S.srsi_dense(mat, cfg.rank, 0, cfg.n_iter, k)
                return res.q @ res.u.T

            from repro.core import factored as F
            fn = comp2d
            bd = g32.ndim - 2
            for _ in range(bd):
                fn = jax.vmap(fn)
            keys = F.batched_keys(jax.random.fold_in(key, i),
                                  g32.shape[:-2])
            g_hat = fn(g32, keys)
            out_g.append(g_hat.astype(g.dtype))
            out_e.append(g32 - g_hat)

        return (jax.tree.unflatten(treedef, out_g),
                CompressionState(step=step,
                                 error=jax.tree.unflatten(treedef, out_e)))

    return GradientTransformation(init, update)
