"""Version-compat shims for the moving parts of the jax API."""
from __future__ import annotations

import inspect

import jax


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across jax versions.

    jax >= 0.7 exposes ``jax.shard_map(check_vma=...)``; 0.6 promoted it to
    the top level but still spells the kwarg ``check_rep``; older releases
    only have ``jax.experimental.shard_map.shard_map`` (also ``check_rep``).
    Dispatch on the actual signature, not mere presence of the attribute.
    """
    if hasattr(jax, "shard_map"):
        params = inspect.signature(jax.shard_map).parameters
        kw = {"check_vma" if "check_vma" in params else "check_rep":
              check_vma}
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def pallas_load(ref, idx: tuple):
    """``pl.load`` with integer indexers across jax versions.

    On jax 0.4.3x the interpret-mode state-discharge rule for ``load_p``
    assumes every non-``Slice`` indexer is an array (it probes ``.shape``),
    so a plain Python ``int`` in the index tuple raises
    ``AttributeError: 'int' object has no attribute 'shape'`` — but only
    when the kernel is *interpreted* (CPU tests), not when it is compiled
    for TPU.  Normalising each int ``i`` to the size-1 slice
    ``pl.dslice(i, 1)`` and squeezing the resulting unit axes afterwards is
    bit-identical on every version and lowers to the same DMA on TPU, so we
    do it unconditionally rather than sniffing the broken rule.
    """
    from jax.experimental import pallas as pl

    squeeze_axes = []
    norm = []
    for ax, s in enumerate(idx):
        if isinstance(s, int):
            norm.append(pl.dslice(s, 1))
            squeeze_axes.append(ax)
        else:
            norm.append(s)
    out = pl.load(ref, tuple(norm))
    if squeeze_axes:
        out = out.squeeze(axis=tuple(squeeze_axes))
    return out
