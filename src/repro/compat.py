"""Version-compat shims for the moving parts of the jax API."""
from __future__ import annotations

import inspect

import jax


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across jax versions.

    jax >= 0.7 exposes ``jax.shard_map(check_vma=...)``; 0.6 promoted it to
    the top level but still spells the kwarg ``check_rep``; older releases
    only have ``jax.experimental.shard_map.shard_map`` (also ``check_rep``).
    Dispatch on the actual signature, not mere presence of the attribute.
    """
    if hasattr(jax, "shard_map"):
        params = inspect.signature(jax.shard_map).parameters
        kw = {"check_vma" if "check_vma" in params else "check_rep":
              check_vma}
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
