"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: the sequence is split into chunks of length Q; the
intra-chunk part is a masked (C B^T) X batched matmul (MXU-friendly — this
is the whole point of SSD over Mamba1's elementwise scan) and the
inter-chunk part is a tiny state recurrence over ``S/Q`` steps carried by
``lax.scan``.  Decode is the O(1)-per-token state update.

State caches (the sub-quadratic long-context story):
    conv_state: (B, d_conv, conv_dim)    rolling input window
    ssm_state:  (B, H, P, N)             recurrent state
— constant in sequence length, which is why mamba2/zamba2 run the
``long_500k`` cell that pure-attention archs must skip.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def mamba_init(key, cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nh, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + nh
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], d, d_in_proj),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, s.d_conv))
                   * (1.0 / math.sqrt(s.d_conv))).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "out_proj": L.dense_init(ks[3], d_inner, d),
    }


def mamba_specs(cfg) -> dict:
    return {"in_proj": ("embed", "ssm_proj"), "conv_w": ("ssm_conv", None),
            "conv_b": ("ssm_conv",), "a_log": (None,), "d_skip": (None,),
            "dt_bias": (None,), "norm_w": ("ssm_inner",),
            "out_proj": ("ssm_inner", "embed")}


class MambaCache(NamedTuple):
    conv: jnp.ndarray     # (B, d_conv, conv_dim)
    ssm: jnp.ndarray      # (B, H, P, N) float32


def init_mamba_cache(batch: int, cfg, dtype) -> MambaCache:
    s = cfg.ssm
    d_inner, nh, conv_dim = _dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, s.d_conv, conv_dim), dtype),
        ssm=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32))


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, nh, conv_dim = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    return z, xbc, dt


def _split_xbc(cfg, xbc):
    s = cfg.ssm
    d_inner, _, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    return xs, b, c


def _causal_conv(xbc, w, b):
    """Depthwise causal conv. xbc: (B, S, C), w: (C, K)."""
    k = w.shape[1]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    # stack K shifted views: out[t] = sum_j w[:, j] * x[t - (K-1) + j]
    views = jnp.stack([pad[:, j:j + xbc.shape[1], :] for j in range(k)],
                      axis=-1)                       # (B, S, C, K)
    out = jnp.einsum("bsck,ck->bsc", views.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jax.nn.silu(out + b).astype(xbc.dtype)


def _expand_groups(x, n_heads, n_groups):
    """(B, S, G, N) -> (B, S, H, N) by repeating each group."""
    rep = n_heads // n_groups
    return jnp.repeat(x, rep, axis=2)


def ssd_chunked(xs, b, c, dt, a, chunk: int):
    """Chunked SSD.

    xs: (Bt, S, H, P); b, c: (Bt, S, H, N); dt: (Bt, S, H); a: (H,) < 0.
    Returns y: (Bt, S, H, P) and final state (Bt, H, P, N).
    """
    bt, s, h, p = xs.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} not a multiple of chunk {q}"
    nc = s // q

    def r(t, shape):
        return t.reshape((bt, nc, q) + shape)

    xs_c = r(xs, (h, p))
    b_c = r(b, (h, n))
    c_c = r(c, (h, n))
    da = (dt * a[None, None, :])                     # (Bt, S, H), <= 0
    da_c = r(da, (h,))                               # (Bt, nc, q, H)
    cums = jnp.cumsum(da_c, axis=2)                  # within-chunk cumsum

    # decay matrix L[i, j] = exp(cums[i] - cums[j]) for i >= j else 0
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (Bt,nc,q,q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    ldec = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)

    bx = b_c * dt[..., None].reshape(bt, nc, q, h, 1)  # dt-weighted B

    # intra-chunk: Y[i] = sum_{j<=i} L[i,j] (C_i . B_j) X_j
    cb = jnp.einsum("zcihn,zcjhn->zcijh", c_c.astype(jnp.float32),
                    bx.astype(jnp.float32))
    y_intra = jnp.einsum("zcijh,zcjhp->zcihp", cb * ldec,
                         xs_c.astype(jnp.float32))

    # per-chunk state contribution: S_c = sum_i exp(cums[-1]-cums[i]) Bx_i X_i
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)          # (Bt,nc,q,H)
    s_chunk = jnp.einsum("zcqh,zcqhn,zcqhp->zchnp",
                         decay_to_end, bx.astype(jnp.float32),
                         xs_c.astype(jnp.float32))
    chunk_decay = jnp.exp(cums[:, :, -1, :])                   # (Bt,nc,H)

    # inter-chunk recurrence over nc steps
    def scan_body(hstate, inp):
        s_c, dec = inp                       # (Bt,h,n,p), (Bt,h)
        out = hstate                         # state entering the chunk
        hstate = hstate * dec[:, :, None, None] + s_c
        return hstate, out

    s_seq = jnp.moveaxis(s_chunk, 1, 0)      # (nc, Bt, h, n, p)
    d_seq = jnp.moveaxis(chunk_decay, 1, 0)  # (nc, Bt, h)
    h0 = jnp.zeros((bt, h, n, p), jnp.float32)
    h_final, h_in = jax.lax.scan(scan_body, h0, (s_seq, d_seq))
    h_in = jnp.moveaxis(h_in, 0, 1)          # (Bt, nc, h, n, p)

    # inter-chunk output: Y_inter[i] = exp(cums[i]) * C_i . h_in
    y_inter = jnp.einsum("zcqh,zcqhn,zchnp->zcqhp",
                         jnp.exp(cums), c_c.astype(jnp.float32), h_in)

    y = (y_intra + y_inter).reshape(bt, s, h, p)
    # final state stored as (Bt, H, P, N)
    return y, jnp.moveaxis(h_final, -1, -2)


def mamba_apply(cfg, p, x, cache: MambaCache | None = None):
    """Full-sequence forward.  Returns (out, new_cache | None)."""
    s_cfg = cfg.ssm
    bt, s, d = x.shape
    d_inner, nh, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, b, c = _split_xbc(cfg, xbc_conv)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])        # (Bt,S,H)
    a = -jnp.exp(p["a_log"])                                   # (H,)

    xs = xs.reshape(bt, s, nh, s_cfg.head_dim)
    b = _expand_groups(b.reshape(bt, s, s_cfg.n_groups, s_cfg.d_state),
                       nh, s_cfg.n_groups)
    c = _expand_groups(c.reshape(bt, s, s_cfg.n_groups, s_cfg.d_state),
                       nh, s_cfg.n_groups)

    y, h_final = ssd_chunked(xs, b, c, dt, a, s_cfg.chunk)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bt, s, d_inner).astype(x.dtype)

    # gated RMSNorm then output projection
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)

    new_cache = None
    if cache is not None:
        tail = xbc[:, -s_cfg.d_conv:, :]
        pad = s_cfg.d_conv - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        new_cache = MambaCache(conv=tail.astype(cache.conv.dtype),
                               ssm=h_final)
    return out, new_cache


def mamba_decode(cfg, p, x, cache: MambaCache):
    """One-token step. x: (B, 1, D)."""
    s_cfg = cfg.ssm
    bt = x.shape[0]
    d_inner, nh, conv_dim = _dims(cfg)
    zxbcdt = x[:, 0, :] @ p["in_proj"].astype(x.dtype)         # (B, d_proj)
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    conv_buf = jnp.concatenate(
        [cache.conv[:, 1:, :], xbc[:, None, :].astype(cache.conv.dtype)],
        axis=1)                                                # (B, K, C)
    xbc_c = jnp.einsum("bkc,ck->bc", conv_buf.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
    xbc_c = jax.nn.silu(xbc_c + p["conv_b"]).astype(x.dtype)
    xs, b, c = _split_xbc(cfg, xbc_c)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a[None, :])                             # (B, H)

    xs = xs.reshape(bt, nh, s_cfg.head_dim).astype(jnp.float32)
    b = _expand_groups(b.reshape(bt, 1, s_cfg.n_groups, s_cfg.d_state),
                       nh, s_cfg.n_groups)[:, 0]
    c = _expand_groups(c.reshape(bt, 1, s_cfg.n_groups, s_cfg.d_state),
                       nh, s_cfg.n_groups)[:, 0]

    # h <- h * dec + dt * x (outer) B
    h = cache.ssm * dec[:, :, None, None] + (
        dt[:, :, None, None] * xs[:, :, :, None] * b[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", h, c)
    y = y + p["d_skip"][None, :, None] * xs
    y = y.reshape(bt, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(x.dtype))[:, None, :]
    return out, MambaCache(conv=conv_buf, ssm=h)
