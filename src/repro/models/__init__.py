"""Model zoo: dense/GQA transformer, MoE, Mamba2/SSD, Zamba2 hybrid,
Whisper enc-dec, and VLM backbone — all functional JAX with scan-stacked
layers and logical-axis param specs."""
from repro.models.model_zoo import build_model
