"""Decoder-only transformer LM (dense / MoE / VLM-backbone families).

Layers are scan-stacked: block params have a leading (L,) dim, the forward
is a single ``lax.scan`` whose body is optionally rematerialised.  This
keeps the HLO size O(1) in depth (compile-time at 95-layer scale) and gives
the optimizer stacked (L, m, n) leaves that the factored second moment
vmaps over.

VLM / audio frontends are STUBS by design (assignment): ``embeds`` —
precomputed patch/frame embeddings of width d_model — are concatenated in
front of the token embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


class TransformerLM:
    """Families: dense | moe | vlm (mistral backbone + stub frontend)."""

    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.is_moe = cfg.moe is not None
        # set by the launcher: activation sharding constraint hook
        self.constrain = lambda x: x
        # "train" | "decode": decode uses the weights-stationary EP-TP MoE
        self.moe_mode = "train"

    # -- params ------------------------------------------------------------
    def _init_block(self, key) -> dict:
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"norm1": L.make_norm_params(cfg, cfg.d_model),
             "attn": A.attn_init(k1, cfg, cfg.d_model),
             "norm2": L.make_norm_params(cfg, cfg.d_model)}
        if self.is_moe:
            p["moe"] = MOE.moe_init(k2, cfg, cfg.d_model)
        else:
            p["mlp"] = L.mlp_init(k3, cfg, cfg.d_model, cfg.d_ff)
        return p

    def _block_specs(self) -> dict:
        cfg = self.cfg
        s = {"norm1": L.norm_specs(cfg), "attn": A.attn_specs(cfg),
             "norm2": L.norm_specs(cfg)}
        if self.is_moe:
            s["moe"] = MOE.moe_specs(cfg)
        else:
            s["mlp"] = L.mlp_specs(cfg)
        return s

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kb, kh = jax.random.split(key, 3)
        bkeys = jax.random.split(kb, cfg.n_layers)
        blocks = jax.vmap(self._init_block)(bkeys)
        params = {"embed": L.embed_init(ke, cfg.vocab, cfg.d_model),
                  "blocks": blocks,
                  "final_norm": L.make_norm_params(cfg, cfg.d_model)}
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab,
                                             scale=0.02)
        if cfg.pos_embedding == "learned":
            params["pos_embed"] = L.embed_init(
                jax.random.fold_in(key, 7), cfg.max_seq_len, cfg.d_model)
        return params

    def param_specs(self) -> dict:
        cfg = self.cfg
        block = jax.tree.map(lambda axes: ("layers",) + tuple(axes),
                             self._block_specs(),
                             is_leaf=lambda x: isinstance(x, tuple))
        specs = {"embed": ("vocab", "embed"), "blocks": block,
                 "final_norm": L.norm_specs(cfg)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = ("embed", "vocab")
        if cfg.pos_embedding == "learned":
            specs["pos_embed"] = (None, "embed")
        return specs

    # -- blocks ------------------------------------------------------------
    def _moe_or_mlp(self, bp, h):
        cfg = self.cfg
        if not self.is_moe:
            return L.mlp_apply(cfg, bp["mlp"], h), jnp.zeros((), jnp.float32)
        if self.mesh is not None and cfg.moe.impl == "sort":
            if self.moe_mode == "decode":
                return MOE.moe_apply_ep_tp(cfg, bp["moe"], h, self.mesh)
            dp = tuple(a for a in ("pod", "data") if a in self.mesh.shape)
            gather = tuple(a for a in ("data",) if a in self.mesh.shape)
            return MOE.moe_apply_sharded(cfg, bp["moe"], h, self.mesh,
                                         dp_axes=dp, gather_axes=gather)
        return MOE.moe_apply_local(cfg, bp["moe"], h)

    def _block_train(self, x, bp):
        cfg = self.cfg
        h = L.apply_norm(cfg, bp["norm1"], x)
        x = x + A.attn_apply_full(cfg, bp["attn"], h, causal=True)
        x = self.constrain(x)
        h = L.apply_norm(cfg, bp["norm2"], x)
        y, aux = self._moe_or_mlp(bp, h)
        return self.constrain(x + y), aux

    # -- full-sequence forward ----------------------------------------------
    def forward(self, params, tokens, embeds: Optional[jnp.ndarray] = None):
        """tokens: (B, S_txt) int32; embeds: (B, F, D) stub-frontend output.
        Returns logits (B, S, V) where S = F + S_txt."""
        cfg = self.cfg
        dt = _dtype(cfg)
        x = params["embed"].astype(dt)[tokens]
        if embeds is not None:
            x = jnp.concatenate([embeds.astype(dt), x], axis=1)
        if cfg.pos_embedding == "learned":
            s = x.shape[1]
            x = x + params["pos_embed"].astype(dt)[None, :s, :]
        x = self.constrain(x)

        def body(carry, bp):
            x, aux = carry
            x, a = self._block_train(x, bp)
            return (x, aux + a), None

        body = _remat(cfg, body)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       params["blocks"])
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                bp = jax.tree.map(lambda p: p[i], params["blocks"])
                (x, aux), _ = body((x, aux), bp)

        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = self._lm_head(params, x)
        return logits, aux

    def _lm_head(self, params, x):
        cfg = self.cfg
        dt = x.dtype
        if cfg.tie_embeddings:
            return x @ params["embed"].astype(dt).T
        return x @ params["lm_head"].astype(dt)

    def loss(self, params, batch) -> tuple[jnp.ndarray, dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        embeds = batch.get("embeds")
        # big-vocab path: compute xent from hiddens in chunks so the
        # (B, S, V) f32 logits never materialise (see layers.py)
        if (not cfg.tie_embeddings and embeds is None
                and cfg.vocab * tokens.shape[1] >= 2 ** 26):
            x, aux = self._hidden(params, tokens)
            ce = L.fused_xent_from_hidden(x, params["lm_head"], tokens)
        else:
            logits, aux = self.forward(params, tokens, embeds)
            n_front = 0 if embeds is None else embeds.shape[1]
            txt_logits = logits[:, n_front:, :]
            ce = L.softmax_xent(txt_logits[:, :-1, :], tokens[:, 1:])
        total = ce + 0.01 * aux
        return total, {"loss": ce, "aux_loss": aux}

    def _hidden(self, params, tokens):
        """Forward up to the final norm (no LM head)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        x = self.constrain(params["embed"].astype(dt)[tokens])

        def body(carry, bp):
            x, aux = carry
            x, a = self._block_train(x, bp)
            return (x, aux + a), None

        body = _remat(cfg, body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        return L.apply_norm(cfg, params["final_norm"], x), aux

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        caches = [A.init_kv_cache(batch, cache_len, cfg, dt)
                  for _ in range(cfg.n_layers)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        return {"kv": stacked, "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, tokens, cache,
                embeds: Optional[jnp.ndarray] = None):
        cfg = self.cfg
        dt = _dtype(cfg)
        x = params["embed"].astype(dt)[tokens]
        if embeds is not None:
            x = jnp.concatenate([embeds.astype(dt), x], axis=1)
        if cfg.pos_embedding == "learned":
            x = x + params["pos_embed"].astype(dt)[None, :x.shape[1], :]
        x = self.constrain(x)

        def body(x, xs):
            bp, kv = xs
            h = L.apply_norm(cfg, bp["norm1"], x)
            a_out, kv = A.attn_prefill(cfg, bp["attn"], h, kv)
            x = self.constrain(x + a_out)
            h = L.apply_norm(cfg, bp["norm2"], x)
            y, _ = self._moe_or_mlp(bp, h)
            return self.constrain(x + y), kv

        body = _remat(cfg, body)
        x, kv = jax.lax.scan(body, x, (params["blocks"], cache["kv"]))
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = self._lm_head(params, x[:, -1:, :])
        return logits, {"kv": kv, "pos": jnp.asarray(x.shape[1], jnp.int32)}

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1). One autoregressive step at cache['pos']."""
        cfg = self.cfg
        dt = _dtype(cfg)
        pos = cache["pos"]
        x = params["embed"].astype(dt)[tokens]
        if cfg.pos_embedding == "learned":
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"].astype(dt), pos, 1, axis=0)[None]

        def body(x, xs):
            bp, kv = xs
            h = L.apply_norm(cfg, bp["norm1"], x)
            a_out, kv = A.attn_decode(cfg, bp["attn"], h, kv, pos)
            x = self.constrain(x + a_out)
            h = L.apply_norm(cfg, bp["norm2"], x)
            y, _ = self._moe_or_mlp(bp, h)
            return self.constrain(x + y), kv

        x, kv = jax.lax.scan(body, x, (params["blocks"], cache["kv"]))
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = self._lm_head(params, x)
        return logits, {"kv": kv, "pos": pos + 1}

    # -- paged serving (block-table KV cache; see serve/kv_cache.py) --------
    def init_paged_cache(self, num_blocks: int, block_size: int) -> dict:
        """Block pool shared by every slot: {"k","v"} of shape
        (L, num_blocks, block_size, KV, dh).  Block tables / positions are
        NOT part of the cache — the engine owns them host-side and passes
        them per call, so the pool pytree alone is donated/recycled."""
        cfg = self.cfg
        dt = _dtype(cfg)
        hd = cfg.resolved_head_dim
        shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    def prefill_paged(self, params, pool, tokens, block_table, p0, last_idx):
        """One prompt chunk for ONE slot.  tokens: (1, C) at logical
        positions p0..p0+C-1; block_table: (nbt,); last_idx: () int32
        index (within the chunk) of the last REAL prompt token — returns
        that position's logits (1, 1, V) so bucket-padded chunks still
        yield the correct first generated token."""
        cfg = self.cfg
        dt = _dtype(cfg)
        c = tokens.shape[1]
        x = params["embed"].astype(dt)[tokens]
        if cfg.pos_embedding == "learned":
            x = x + params["pos_embed"].astype(dt)[p0 + jnp.arange(c)][None]
        x = self.constrain(x)

        def body(x, xs):
            bp, (pk, pv) = xs
            h = L.apply_norm(cfg, bp["norm1"], x)
            a_out, pk, pv = A.attn_prefill_paged(cfg, bp["attn"], h, pk, pv,
                                                 block_table, p0)
            x = self.constrain(x + a_out)
            h = L.apply_norm(cfg, bp["norm2"], x)
            y, _ = self._moe_or_mlp(bp, h)
            return self.constrain(x + y), (pk, pv)

        x, kv = jax.lax.scan(body, x, (params["blocks"],
                                       (pool["k"], pool["v"])))
        x = L.apply_norm(cfg, params["final_norm"], x)
        xlast = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
        logits = self._lm_head(params, xlast)
        return logits, {"k": kv[0], "v": kv[1]}

    def decode_paged(self, params, pool, tokens, block_tables, positions):
        """One autoregressive step for ALL slots with PER-ROW positions.
        tokens: (B, 1); block_tables: (B, nbt); positions: (B,) — row i
        writes its token's k/v at positions[i] and attends to
        0..positions[i].  Idle rows point at the null block and are
        masked out host-side by the engine."""
        cfg = self.cfg
        dt = _dtype(cfg)
        x = params["embed"].astype(dt)[tokens]
        if cfg.pos_embedding == "learned":
            x = x + params["pos_embed"].astype(dt)[positions][:, None, :]

        def body(x, xs):
            bp, (pk, pv) = xs
            h = L.apply_norm(cfg, bp["norm1"], x)
            a_out, pk, pv = A.attn_decode_paged(cfg, bp["attn"], h, pk, pv,
                                                block_tables, positions)
            x = self.constrain(x + a_out)
            h = L.apply_norm(cfg, bp["norm2"], x)
            y, _ = self._moe_or_mlp(bp, h)
            return self.constrain(x + y), (pk, pv)

        x, kv = jax.lax.scan(body, x, (params["blocks"],
                                       (pool["k"], pool["v"])))
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = self._lm_head(params, x)
        return logits, {"k": kv[0], "v": kv[1]}
