"""Pure SSM language model (mamba2-370m): attention-free Mamba2 stack."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M


class SSMLM:
    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.constrain = lambda x: x

    def init(self, key):
        cfg = self.cfg
        ke, km, kh = jax.random.split(key, 3)
        mkeys = jax.random.split(km, cfg.n_layers)

        def init_layer(k):
            return {"norm": L.make_norm_params(cfg, cfg.d_model),
                    "mamba": M.mamba_init(k, cfg)}

        return {"embed": L.embed_init(ke, cfg.vocab, cfg.d_model),
                "layers": jax.vmap(init_layer)(mkeys),
                "final_norm": L.make_norm_params(cfg, cfg.d_model),
                "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab,
                                        scale=0.02)}

    def param_specs(self):
        cfg = self.cfg
        layer = {"norm": L.norm_specs(cfg), "mamba": M.mamba_specs(cfg)}
        return {
            "embed": ("vocab", "embed"),
            "layers": jax.tree.map(lambda a: ("layers",) + tuple(a), layer,
                                   is_leaf=lambda x: isinstance(x, tuple)),
            "final_norm": L.norm_specs(cfg),
            "lm_head": ("embed", "vocab"),
        }

    def _scan(self, params, x, caches):
        cfg = self.cfg

        def body(x, xs):
            lp, mc = xs
            h = L.apply_norm(cfg, lp["norm"], x)
            if mc is None:
                mo, _ = M.mamba_apply(cfg, lp["mamba"], h)
                new_mc = mc
            elif x.shape[1] > 1:
                mo, new_mc = M.mamba_apply(cfg, lp["mamba"], h, mc)
            else:
                mo, new_mc = M.mamba_decode(cfg, lp["mamba"], h, mc)
            return self.constrain(x + mo), new_mc

        if cfg.remat != "none" and caches is None:
            body = jax.checkpoint(body)
        return jax.lax.scan(body, x, (params["layers"], caches))

    def forward(self, params, tokens, embeds=None):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = self.constrain(params["embed"].astype(dt)[tokens])
        x, _ = self._scan(params, x, None)
        x = L.apply_norm(cfg, params["final_norm"], x)
        return x @ params["lm_head"].astype(dt), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        ce = L.softmax_xent(logits[:, :-1, :], batch["tokens"][:, 1:])
        return ce, {"loss": ce}

    def init_cache(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        mc = [M.init_mamba_cache(batch, cfg, dt) for _ in range(cfg.n_layers)]
        return {"mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *mc),
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, tokens, cache):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = self.constrain(params["embed"].astype(dt)[tokens])
        x, mc = self._scan(params, x, cache["mamba"])
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = x[:, -1:, :] @ params["lm_head"].astype(dt)
        return logits, {"mamba": mc,
                        "pos": jnp.asarray(tokens.shape[1], jnp.int32)}

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = self.constrain(params["embed"].astype(dt)[tokens])
        x, mc = self._scan(params, x, cache["mamba"])
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["lm_head"].astype(dt)
        return logits, {"mamba": mc, "pos": cache["pos"] + 1}
