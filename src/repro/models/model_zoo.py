"""Model registry: family -> implementation class.

``build_model(cfg, mesh)`` returns an object exposing the uniform API:
    init(key) -> params
    param_specs() -> logical-axis tree mirroring params
    forward(params, tokens, embeds=None) -> (logits, aux)
    loss(params, batch) -> (scalar, metrics)
    init_cache(batch, cache_len) -> cache
    prefill(params, tokens, cache[, embeds]) -> (logits, cache)
    decode_step(params, cache, tokens) -> (logits, cache)
"""
from __future__ import annotations

from repro.config import ModelConfig
from repro.models.ssm_lm import SSMLM
from repro.models.transformer import TransformerLM
from repro.models.whisper import EncDecLM
from repro.models.zamba2 import HybridLM

_FAMILIES = {
    "dense": TransformerLM,
    "moe": TransformerLM,
    "vlm": TransformerLM,
    "ssm": SSMLM,
    "hybrid": HybridLM,
    "encdec": EncDecLM,
}


def build_model(cfg: ModelConfig, mesh=None):
    try:
        cls = _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}; "
                         f"available: {sorted(_FAMILIES)}") from None
    model = cls(cfg, mesh=mesh)
    if cfg.param_dtype != "float32":
        import jax
        import jax.numpy as jnp
        dt = jnp.dtype(cfg.param_dtype)
        inner = model.init
        model.init = lambda key: jax.tree.map(
            lambda p: p.astype(dt), inner(key))
    return model
