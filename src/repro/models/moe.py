"""Mixture-of-Experts block: top-k routing with capacity + drop.

Two implementations sharing one core:

* ``dense`` — every expert on every token, exact weighted combine.  O(E)
  compute: only for tiny smoke configs and as the correctness oracle.
* ``sort``  — production path: tokens are sorted by expert id, packed into
  fixed-capacity per-expert buffers (static shapes), batched expert GEMMs,
  scatter-combine.  Inside ``moe_apply_sharded`` this runs per model-shard
  on the *local* expert slice with a psum combine over the model axis
  (expert parallelism with all-reduce combine — tokens never move between
  data shards, only activations are reduced over the EP axis, the same
  volume as a Megatron TP all-reduce).

Everything is jit/GSPMD-friendly: static capacities, no dynamic shapes, and
the scatter/gather ops differentiate (dropped tokens get zero gradient,
the standard capacity-drop semantics).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models import layers as L



def moe_init(key, cfg, d: int) -> dict:
    spec = cfg.moe
    ks = jax.random.split(key, 4)
    e, fe = spec.n_experts, spec.d_ff_expert
    s = 1.0 / math.sqrt(d)
    return {
        "router": L.dense_init(ks[0], d, e),
        "w_gate": (jax.random.normal(ks[1], (e, d, fe)) * s).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[2], (e, d, fe)) * s).astype(jnp.float32),
        "w_down": (jax.random.normal(ks[3], (e, fe, d))
                   * (1.0 / math.sqrt(fe))).astype(jnp.float32),
    }


def moe_specs(cfg) -> dict:
    return {"router": ("embed", "experts_router"),
            "w_gate": ("experts", "embed", "expert_mlp"),
            "w_up": ("experts", "embed", "expert_mlp"),
            "w_down": ("experts", "expert_mlp", "embed")}


def _route(cfg, router_w, xf):
    """xf: (T, D) -> (gates (T, k), idx (T, k), aux_loss scalar)."""
    spec = cfg.moe
    logits = xf.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, spec.top_k)
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = spec.n_experts
    me = jnp.mean(probs, axis=0)                                   # (E,)
    assign = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(assign, axis=0)
    aux = e * jnp.sum(me * fe)
    return gates, idx, aux


def _expert_mlp(cfg, p, h):
    """h: (E_l, C, D) -> (E_l, C, D) via per-expert SwiGLU."""
    dt = h.dtype
    gate = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(dt))
    act = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", act, p["w_down"].astype(dt))


def _moe_core_sort(cfg, p, xf, e0: int, e_local: int,
                   capacity: int) -> jnp.ndarray:
    """Sort-based dispatch for experts [e0, e0 + e_local). xf: (T, D)."""
    spec = cfg.moe
    t, d = xf.shape
    k = spec.top_k
    gates, idx, aux = _route(cfg, p["router"], xf)

    flat_e = idx.reshape(-1)                       # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), k)        # (T*k,)
    flat_g = gates.reshape(-1)

    local_e = flat_e - e0
    valid = (local_e >= 0) & (local_e < e_local)
    sort_key = jnp.where(valid, local_e, e_local)  # invalid -> sentinel seg
    order = jnp.argsort(sort_key)
    se = sort_key[order]
    stok = flat_tok[order]
    sg = flat_g[order]

    counts = jnp.zeros((e_local + 1,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[se]
    keep = (pos < capacity) & (se < e_local)
    slot = jnp.where(keep, se * capacity + pos, e_local * capacity)

    buf = jnp.zeros((e_local * capacity + 1, d), xf.dtype)
    buf = buf.at[slot].add(xf[stok])
    h = buf[:-1].reshape(e_local, capacity, d)
    out = _expert_mlp(cfg, p, h).reshape(e_local * capacity, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)

    contrib = out[slot] * (sg * keep.astype(jnp.float32))[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), xf.dtype).at[stok].add(contrib)
    return y, aux


def _capacity(t: int, cfg) -> int:
    spec = cfg.moe
    return max(1, int(math.ceil(t * spec.top_k / spec.n_experts
                                * spec.capacity_factor)))


def moe_apply_local(cfg, p, x) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-shard MoE (smoke tests; also correct—if slow—under GSPMD)."""
    spec = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    if spec.impl == "dense":
        gates, idx, aux = _route(cfg, p["router"], xf)
        outs = _expert_mlp(cfg, p, jnp.broadcast_to(
            xf[None], (spec.n_experts,) + xf.shape))      # (E, T, D)
        onehot = jax.nn.one_hot(idx, spec.n_experts,
                                dtype=jnp.float32)        # (T, k, E)
        w = jnp.einsum("tk,tke->te", gates, onehot)       # (T, E)
        y = jnp.einsum("te,etd->td", w.astype(outs.dtype), outs)
    else:
        y, aux = _moe_core_sort(cfg, p, xf, 0, spec.n_experts,
                                _capacity(b * s, cfg))
    return y.reshape(b, s, d), aux


def moe_apply_sharded(cfg, p, x, mesh, dp_axes: tuple = ("data",),
                      model_axis: str = "model",
                      gather_axes: tuple = ("data",)
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE over ``model_axis`` inside shard_map.

    x: (B, S, D) with B sharded over ``dp_axes``, replicated over model.
    Expert weights sharded (experts -> model_axis, d_model -> gather_axes):
    the d_model shard is FSDP storage — it is all-gathered *inside* the
    body, one layer at a time (transient ~E_local*D*F_e, which is what lets
    a 1T-param MoE (kimi-k2) fit 8 GB/chip of storage while keeping the
    per-layer working set bounded).

    Each model rank routes its local token block over ALL experts but
    computes only its expert slice; partial outputs psum over the model
    axis (EP-with-allreduce-combine: activation volume == a Megatron TP
    all-reduce, tokens never cross data shards).
    """
    spec = cfg.moe
    batch_axes = tuple(dp_axes)
    gather_axes = tuple(a for a in (gather_axes or ())
                        if a in mesh.shape and mesh.shape[a] > 1)
    wspec = P(model_axis, gather_axes if gather_axes else None, None)

    def body(xb, router, wg, wu, wd):
        if gather_axes:
            wg = jax.lax.all_gather(wg, gather_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, gather_axes, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, gather_axes, axis=2, tiled=True)
        pl_ = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        b, s, d = xb.shape
        xf = xb.reshape(b * s, d)
        e_local = wg.shape[0]
        rank = jax.lax.axis_index(model_axis)
        e0 = rank * e_local
        y, aux = _moe_core_sort(cfg, pl_, xf, e0, e_local,
                                _capacity(b * s, cfg))
        y = jax.lax.psum(y, model_axis)
        aux = jax.lax.psum(aux, model_axis) / jax.lax.psum(1, model_axis)
        return y.reshape(b, s, d), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None),
                  wspec, wspec, P(model_axis, None,
                                  gather_axes if gather_axes else None)),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_apply_ep_tp(cfg, p, x, mesh, model_axis: str = "model",
                    ff_axis: str = "data") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Weights-stationary MoE for DECODE: experts sharded over the model
    axis AND each expert's FFN dim sharded over the data axis — no weight
    movement at all.  The (tiny) decode activations are replicated to every
    rank instead; the combine is one psum over both axes (partial FFN sums
    over ``ff_axis`` + expert contributions over ``model_axis``).

    Per-layer collective volume ~ activation-sized (MBs) versus the
    FSDP-gather path's expert-weight gathers (~0.7 GB/layer for kimi-k2):
    the right trade exactly when tokens << weights, i.e. decode.
    """
    spec = cfg.moe
    has_ff = ff_axis in mesh.shape and mesh.shape[ff_axis] > 1
    wspec_up = P(model_axis, None, ff_axis if has_ff else None)
    wspec_dn = P(model_axis, ff_axis if has_ff else None, None)
    both = (ff_axis, model_axis) if has_ff else (model_axis,)

    def body(xb, router, wg, wu, wd):
        pl_ = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        b, s, d = xb.shape
        xf = xb.reshape(b * s, d)
        e_local = wg.shape[0]
        rank = jax.lax.axis_index(model_axis)
        e0 = rank * e_local
        y, aux = _moe_core_sort(cfg, pl_, xf, e0, e_local,
                                _capacity(b * s, cfg))
        y = jax.lax.psum(y, both)
        n = 1
        for a in both:
            n *= mesh.shape[a]
        aux = jax.lax.psum(aux, both) / n
        return y.reshape(b, s, d), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, None), P(None, None),
                  wspec_up, wspec_up, wspec_dn),
        out_specs=(P(None, None, None), P()),
        check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
