"""Shared neural-net building blocks (functional, no framework).

Parameters are nested dicts of arrays.  Alongside every ``init`` there is a
``*_specs`` tree with the same structure whose leaves are tuples of *logical
axis names* (strings or None); repro.distributed.sharding maps logical axes
to mesh axes per shape-cell.  Keeping weights 2D ``(in, out)`` (heads
flattened) matches how the Adapprox paper (and PyTorch) sees parameter
matrices, so the factored-optimizer policy applies to the same shapes the
paper measured.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Param creation
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None,
               dtype=jnp.float32) -> jnp.ndarray:
    scale = scale if scale is not None else (1.0 / math.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def make_norm_params(cfg, d: int) -> dict:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32),
                "b": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32)}


def norm_specs(cfg) -> dict:
    if cfg.norm == "layernorm":
        return {"w": ("embed",), "b": ("embed",)}
    return {"w": ("embed",)}


def apply_norm(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------

def _act(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    if name == "silu":
        return jax.nn.silu(x)
    raise ValueError(name)


def mlp_init(key, cfg, d: int, f: int) -> dict:
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        p = {"w_gate": dense_init(ks[0], d, f),
             "w_up": dense_init(ks[1], d, f),
             "w_down": dense_init(ks[2], f, d)}
    else:
        p = {"w_up": dense_init(ks[0], d, f),
             "w_down": dense_init(ks[1], f, d)}
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), jnp.float32)
        p["b_down"] = jnp.zeros((d,), jnp.float32)
    return p


def mlp_specs(cfg) -> dict:
    if cfg.act == "swiglu":
        s = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
             "w_down": ("mlp", "embed")}
    else:
        s = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if cfg.mlp_bias:
        s["b_up"] = ("mlp",)
        s["b_down"] = ("embed",)
    return s


def mlp_apply(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    if cfg.act == "swiglu":
        gate = x @ p["w_gate"].astype(dt)
        up = x @ p["w_up"].astype(dt)
        h = jax.nn.silu(gate) * up
    else:
        h = x @ p["w_up"].astype(dt)
        if cfg.mlp_bias:
            h = h + p["b_up"].astype(dt)
        h = _act(cfg.act, h)
    out = h @ p["w_down"].astype(dt)
    if cfg.mlp_bias and "b_down" in p:
        out = out + p["b_down"].astype(dt)
    return out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., :, None, :]              # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., ::2], x32[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, targets: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token-level cross entropy.  logits (..., V) f32-upcast."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def fused_xent_from_hidden(x: jnp.ndarray, head: jnp.ndarray,
                           targets: jnp.ndarray,
                           chunk: int = 512) -> jnp.ndarray:
    """Cross entropy computed from the pre-head hiddens in sequence
    chunks, with the per-chunk logits rematerialised in the backward —
    the (B, S, V) f32 logits tensor (GBs at 100k-vocab) never exists.

    x: (B, S, D); head: (D, V); targets: (B, S) — returns mean nll over
    the first S-1 positions (next-token objective).
    """
    b, s, d = x.shape
    s_eff = s - 1
    n_chunks = max(1, (s_eff + chunk - 1) // chunk)
    pad = n_chunks * chunk - s_eff
    xs = jnp.pad(x[:, :s_eff, :], ((0, 0), (0, pad), (0, 0)))
    ts = jnp.pad(targets[:, 1:s_eff + 1], ((0, 0), (0, pad)))
    msk = jnp.pad(jnp.ones((b, s_eff), jnp.float32), ((0, 0), (0, pad)))
    xs = xs.reshape(b, n_chunks, chunk, d)
    ts = ts.reshape(b, n_chunks, chunk)
    msk = msk.reshape(b, n_chunks, chunk)

    @jax.checkpoint
    def chunk_nll(xc, tc, mc):
        logits = (xc @ head.astype(xc.dtype)).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mc)

    def body(acc, i):
        return acc + chunk_nll(xs[:, i], ts[:, i], msk[:, i]), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(n_chunks))
    return total / (b * s_eff)
