"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, enc_seq, D) — the transformer
backbone (encoder self-attention stack + decoder with self & cross
attention) is fully implemented.

Serving: prefill encodes the audio embeddings, precomputes per-layer cross
K/V once, and runs the decoder prompt; decode_step is one token against
both caches.  There is no encoder "decode" — the decoder is the
autoregressive part (decode shape cells exercise it).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as A
from repro.models import layers as L


class EncDecLM:
    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.constrain = lambda x: x

    # -- params --------------------------------------------------------------
    def _init_enc_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"norm1": L.make_norm_params(cfg, cfg.d_model),
                "attn": A.attn_init(k1, cfg, cfg.d_model),
                "norm2": L.make_norm_params(cfg, cfg.d_model),
                "mlp": L.mlp_init(k2, cfg, cfg.d_model, cfg.d_ff)}

    def _init_dec_block(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {"norm1": L.make_norm_params(cfg, cfg.d_model),
                "self_attn": A.attn_init(k1, cfg, cfg.d_model),
                "norm_x": L.make_norm_params(cfg, cfg.d_model),
                "cross_attn": A.attn_init(k2, cfg, cfg.d_model),
                "norm2": L.make_norm_params(cfg, cfg.d_model),
                "mlp": L.mlp_init(k3, cfg, cfg.d_model, cfg.d_ff)}

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        ekeys = jax.random.split(ks[0], cfg.enc_layers)
        dkeys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": L.embed_init(ks[2], cfg.vocab, cfg.d_model),
            "enc_pos": L.embed_init(ks[3], cfg.enc_seq, cfg.d_model),
            "dec_pos": L.embed_init(ks[4], cfg.max_seq_len, cfg.d_model),
            "enc_blocks": jax.vmap(self._init_enc_block)(ekeys),
            "dec_blocks": jax.vmap(self._init_dec_block)(dkeys),
            "enc_norm": L.make_norm_params(cfg, cfg.d_model),
            "dec_norm": L.make_norm_params(cfg, cfg.d_model),
        }

    def param_specs(self):
        cfg = self.cfg
        enc = {"norm1": L.norm_specs(cfg), "attn": A.attn_specs(cfg),
               "norm2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}
        dec = {"norm1": L.norm_specs(cfg), "self_attn": A.attn_specs(cfg),
               "norm_x": L.norm_specs(cfg), "cross_attn": A.attn_specs(cfg),
               "norm2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}
        add = lambda: (lambda axes: ("layers",) + tuple(axes))
        is_tup = lambda x: isinstance(x, tuple)
        return {
            "embed": ("vocab", "embed"),
            "enc_pos": (None, "embed"),
            "dec_pos": (None, "embed"),
            "enc_blocks": jax.tree.map(add(), enc, is_leaf=is_tup),
            "dec_blocks": jax.tree.map(add(), dec, is_leaf=is_tup),
            "enc_norm": L.norm_specs(cfg),
            "dec_norm": L.norm_specs(cfg),
        }

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, enc_embeds):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        s = enc_embeds.shape[1]
        x = enc_embeds.astype(dt) + params["enc_pos"].astype(dt)[None, :s, :]

        def body(x, bp):
            h = L.apply_norm(cfg, bp["norm1"], x)
            x = x + A.attn_apply_full(cfg, bp["attn"], h, causal=False)
            h = L.apply_norm(cfg, bp["norm2"], x)
            return self.constrain(x + L.mlp_apply(cfg, bp["mlp"], h)), None

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.apply_norm(cfg, params["enc_norm"], x)

    # -- decoder (training / full teacher forcing) -------------------------------
    def forward(self, params, tokens, embeds):
        """embeds: (B, enc_seq, D) stub frontend output; tokens: (B, S)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        enc_out = self.encode(params, embeds)
        s = tokens.shape[1]
        x = params["embed"].astype(dt)[tokens] \
            + params["dec_pos"].astype(dt)[None, :s, :]

        def body(x, bp):
            h = L.apply_norm(cfg, bp["norm1"], x)
            x = x + A.attn_apply_full(cfg, bp["self_attn"], h, causal=True)
            h = L.apply_norm(cfg, bp["norm_x"], x)
            ek, ev = self._cross_kv(bp, enc_out)
            x = x + self._cross_attend(bp, h, ek, ev)
            h = L.apply_norm(cfg, bp["norm2"], x)
            return self.constrain(x + L.mlp_apply(cfg, bp["mlp"], h)), None

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        x = L.apply_norm(cfg, params["dec_norm"], x)
        return x @ params["embed"].astype(dt).T, jnp.zeros((), jnp.float32)

    def _cross_kv(self, bp, enc_out):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        b, s, _ = enc_out.shape
        p = bp["cross_attn"]
        dt = enc_out.dtype
        k = (enc_out @ p["wk"].astype(dt)).reshape(b, s, cfg.n_kv_heads, hd)
        v = (enc_out @ p["wv"].astype(dt)).reshape(b, s, cfg.n_kv_heads, hd)
        return k, v

    def _cross_attend(self, bp, h, ek, ev):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        b, s, _ = h.shape
        p = bp["cross_attn"]
        dt = h.dtype
        q = (h @ p["wq"].astype(dt)).reshape(b, s, cfg.n_heads, hd)
        mask = jnp.ones((1, s, ek.shape[1]), bool)
        out = A._sdpa(cfg, q, ek, ev, mask)
        return out @ p["wo"].astype(dt)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"], batch["embeds"])
        ce = L.softmax_xent(logits[:, :-1, :], batch["tokens"][:, 1:])
        return ce, {"loss": ce}

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        hd = cfg.resolved_head_dim
        kv = [A.init_kv_cache(batch, cache_len, cfg, dt)
              for _ in range(cfg.n_layers)]
        kv = jax.tree.map(lambda *xs: jnp.stack(xs), *kv)
        cross = jnp.zeros((cfg.n_layers, 2, batch, cfg.enc_seq,
                           cfg.n_kv_heads, hd), dt)
        return {"kv": kv, "cross": cross, "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, tokens, cache, embeds=None):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        enc_out = self.encode(params, embeds)
        s = tokens.shape[1]
        x = params["embed"].astype(dt)[tokens] \
            + params["dec_pos"].astype(dt)[None, :s, :]

        def body(x, xs):
            bp, kv = xs
            h = L.apply_norm(cfg, bp["norm1"], x)
            a_out, kv = A.attn_prefill(cfg, bp["self_attn"], h, kv)
            x = x + a_out
            h = L.apply_norm(cfg, bp["norm_x"], x)
            ek, ev = self._cross_kv(bp, enc_out)
            x = x + self._cross_attend(bp, h, ek, ev)
            h = L.apply_norm(cfg, bp["norm2"], x)
            return self.constrain(x + L.mlp_apply(cfg, bp["mlp"], h)), \
                (kv, jnp.stack([ek, ev]).astype(dt))

        x, (kv, cross) = jax.lax.scan(body, x, (params["dec_blocks"],
                                                cache["kv"]))
        x = L.apply_norm(cfg, params["dec_norm"], x)
        logits = x[:, -1:, :] @ params["embed"].astype(dt).T
        return logits, {"kv": kv, "cross": cross,
                        "pos": jnp.asarray(s, jnp.int32)}

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        pos = cache["pos"]
        x = params["embed"].astype(dt)[tokens] \
            + jax.lax.dynamic_slice_in_dim(params["dec_pos"].astype(dt),
                                           pos, 1, axis=0)[None]

        def body(x, xs):
            bp, kv, cross = xs
            h = L.apply_norm(cfg, bp["norm1"], x)
            a_out, kv = A.attn_decode(cfg, bp["self_attn"], h, kv, pos)
            x = x + a_out
            h = L.apply_norm(cfg, bp["norm_x"], x)
            x = x + self._cross_attend(bp, h, cross[0], cross[1])
            h = L.apply_norm(cfg, bp["norm2"], x)
            return x + L.mlp_apply(cfg, bp["mlp"], h), kv

        x, kv = jax.lax.scan(body, x, (params["dec_blocks"], cache["kv"],
                                       cache["cross"]))
        x = L.apply_norm(cfg, params["dec_norm"], x)
        logits = x @ params["embed"].astype(dt).T
        return logits, {"kv": kv, "cross": cache["cross"], "pos": pos + 1}
