"""Grouped-query attention with KV cache, causal/full masking, qk-norm,
QKV bias, and RoPE — weights kept 2D (see layers.py docstring).

Decode uses a static-shape ring of length ``cache_len`` with a position
mask — the production pattern (no dynamic shapes, O(cache_len) per token).
Sequence-sharded caches: the softmax here is written with plain reductions
so GSPMD can partition the S axis of the cache and insert the partial
max/sum collectives itself (flash-decoding style combine).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -1e30


def attn_init(key, cfg, d: int) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, cfg.n_heads * hd),
        "wk": L.dense_init(ks[1], d, cfg.n_kv_heads * hd),
        "wv": L.dense_init(ks[2], d, cfg.n_kv_heads * hd),
        "wo": L.dense_init(ks[3], cfg.n_heads * hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def attn_specs(cfg) -> dict:
    s = {"wq": ("embed", "q_heads"), "wk": ("embed", "kv_heads"),
         "wv": ("embed", "kv_heads"), "wo": ("q_heads", "embed")}
    if cfg.qkv_bias:
        s["bq"] = ("q_heads",)
        s["bk"] = ("kv_heads",)
        s["bv"] = ("kv_heads",)
    if cfg.qk_norm:
        s["q_norm"] = (None,)
        s["k_norm"] = (None,)
    return s


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_max, KV, dh)
    v: jnp.ndarray        # (B, S_max, KV, dh)


def init_kv_cache(batch: int, cache_len: int, cfg, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, cache_len, cfg.n_kv_heads, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def _project_qkv(cfg, p, x, positions):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(cfg, q, k, v, mask):
    """q: (B, Sq, H, dh), k/v: (B, Sk, KV, dh), mask: (B|1, Sq, Sk) bool."""
    hd = q.shape[-1]
    groups = cfg.n_heads // cfg.n_kv_heads
    b, sq = q.shape[:2]
    sk = k.shape[1]
    q = q.reshape(b, sq, cfg.n_kv_heads, groups, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(b, sq, cfg.n_heads * hd)


# Above this sequence length, full attention switches to the chunked
# (online-softmax / Rabe-Staats) path: the (Sq, Sk) score matrix is never
# materialised — peak attention memory drops from O(Sq*Sk) to
# O(q_chunk * k_chunk) per head group.  At 32k context the naive path's
# scores alone are ~17 GiB/device; chunked is ~0.1 GiB.
CHUNK_THRESHOLD = 4096
Q_CHUNK = 1024
K_CHUNK = 1024


def _sdpa_chunked(cfg, q, k, v, *, causal: bool,
                  q_chunk: int = Q_CHUNK, k_chunk: int = K_CHUNK):
    """Blockwise attention with a running (max, sum, acc) online softmax.

    q: (B, Sq, H, dh), k/v: (B, Sk, KV, dh).  Sq % q_chunk == 0 and
    Sk % k_chunk == 0 (shape cells are powers of two; smoke shapes take the
    naive path).  This is the jnp-level analogue of a flash-attention
    kernel: on TPU the Pallas version would tile the same loop into VMEM,
    the HLO here already has the right O(S) memory behaviour for the
    dry-run.
    """
    hd = q.shape[-1]
    groups = cfg.n_heads // cfg.n_kv_heads
    b, sq = q.shape[:2]
    sk = k.shape[1]
    kv = cfg.n_kv_heads
    assert sq % q_chunk == 0 and sk % k_chunk == 0, (sq, sk)
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = 1.0 / jnp.sqrt(float(hd))

    qc = q.reshape(b, nq, q_chunk, kv, groups, hd)
    kc = k.reshape(b, nk, k_chunk, kv, hd)
    vc = v.reshape(b, nk, k_chunk, kv, hd)

    @jax.checkpoint
    def q_step(_, qi):
        # rematerialised: the VJP of a plain scan would SAVE every
        # per-chunk probability block (= the full S^2 matrix again);
        # checkpointing recomputes them — flash-attention's backward.
        qblk = qc[:, qi].astype(jnp.float32) * scale   # (b, qc, kv, g, hd)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = kc[:, ki].astype(jnp.float32)       # (b, kc, kv, hd)
            vblk = vc[:, ki].astype(jnp.float32)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kblk)
            if causal:
                k_pos = ki * k_chunk + jnp.arange(k_chunk)
                msk = k_pos[None, :] <= q_pos[:, None]  # (qc, kc)
                s = jnp.where(msk[None, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, groups, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, groups, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, groups, q_chunk, hd), jnp.float32)
        if causal:
            # causal: kv chunks beyond the diagonal contribute nothing;
            # bound the inner scan at the diagonal block.
            n_kv = jnp.minimum(
                (qi * q_chunk + q_chunk + k_chunk - 1) // k_chunk, nk)
        else:
            n_kv = nk

        def bounded(carry, ki):
            def live(c):
                return kv_step(c, ki)[0]
            return jax.lax.cond(ki < n_kv, live, lambda c: c, carry), None

        (m, l, acc), _ = jax.lax.scan(bounded, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)    # (b,kv,g,qc,hd)
        out = jnp.moveaxis(out, 3, 1)                   # (b,qc,kv,g,hd)
        return None, out

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, b, q_chunk, kv, g, hd) -> (b, sq, H*hd)
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, sq, cfg.n_heads * hd)
    return outs.astype(v.dtype)


def sdpa_auto(cfg, q, k, v, *, causal: bool):
    """Dispatch: chunked for long sequences, naive otherwise; the
    ``attn_impl`` config knob forces either path (perf hillclimbing)."""
    sq, sk = q.shape[1], k.shape[1]
    impl = getattr(cfg, "attn_impl", "auto")
    divisible = sq % Q_CHUNK == 0 and sk % K_CHUNK == 0
    if impl == "chunked" and divisible:
        return _sdpa_chunked(cfg, q, k, v, causal=causal)
    if impl != "naive" and sq > CHUNK_THRESHOLD and divisible:
        return _sdpa_chunked(cfg, q, k, v, causal=causal)
    if causal:
        mask = (jnp.arange(sk)[None, None, :] <= jnp.arange(sq)[None, :, None])
    else:
        mask = jnp.ones((1, sq, sk), bool)
    return _sdpa(cfg, q, k, v, mask)


def attn_apply_full(cfg, p, x, *, causal: bool,
                    positions: Optional[jnp.ndarray] = None,
                    kv_override: Optional[tuple] = None) -> jnp.ndarray:
    """Full-sequence attention (train / prefill / encoder).

    kv_override: (k, v) for cross-attention (keys from the encoder)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(cfg, p, x, positions)
    if kv_override is not None:
        k, v = kv_override
    out = sdpa_auto(cfg, q, k, v, causal=causal)
    return out @ p["wo"].astype(x.dtype)


def attn_prefill(cfg, p, x, cache: KVCache) -> tuple[jnp.ndarray, KVCache]:
    """Causal attention over the prompt; fills cache[:, :S]."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = sdpa_auto(cfg, q, k, v, causal=True)
    new_cache = KVCache(
        k=jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                       (0, 0, 0, 0)),
        v=jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                       (0, 0, 0, 0)))
    return out @ p["wo"].astype(x.dtype), new_cache


def attn_decode(cfg, p, x, cache: KVCache,
                pos: jnp.ndarray) -> tuple[jnp.ndarray, KVCache]:
    """One-token step. x: (B, 1, D); pos: () int32 current position."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, pos, 0, 0))
    s_max = ck.shape[1]
    mask = (jnp.arange(s_max)[None, None, :] <= pos)
    out = _sdpa(cfg, q, ck, cv, mask)
    return out @ p["wo"].astype(x.dtype), KVCache(k=ck, v=cv)


# -- paged (block-table) KV cache ---------------------------------------------
# One layer's pool is (num_blocks, block_size, KV, dh); a sequence owns an
# ordered list of block ids (its block table) and a scalar position.  The
# attention read gathers the pool through the table into the LOGICAL dense
# layout (B, n_blocks_per_slot * block_size, KV, dh) and runs the exact
# same ``_sdpa`` reduction as the dense cache — positions at or beyond the
# per-row length are masked to NEG_INF, whose softmax weight underflows to
# exactly 0.0, so stale data in padded/recycled blocks can never leak into
# the output.  When the logical length equals ``cache_len`` this is
# BITWISE identical to ``attn_decode`` on a dense cache holding the same
# tokens (tests/test_serve.py pins it); the memory win is that the POOL is
# shared — slots only hold blocks their sequence actually reached, instead
# of reserving cache_len worst-case each.


def _paged_gather(pool: jnp.ndarray, block_tables: jnp.ndarray):
    """pool: (NB, bs, KV, dh); block_tables: (B, nbt) -> (B, nbt*bs, KV, dh)."""
    g = pool[block_tables]                       # (B, nbt, bs, KV, dh)
    b, nbt, bs = g.shape[:3]
    return g.reshape(b, nbt * bs, *g.shape[3:])


def attn_decode_paged(cfg, p, x, pk, pv, block_tables, positions):
    """One-token step against the block pool, per-row positions.

    x: (B, 1, D); pk/pv: (NB, bs, KV, dh) one layer's pool;
    block_tables: (B, nbt) int32; positions: (B,) int32 — row i's token
    lands at logical position positions[i] (physical block
    block_tables[i, positions[i] // bs], offset positions[i] % bs).
    Rows parked on the null block (table all zeros, position 0) scatter
    garbage into block 0, which only ever appears masked — see
    serve/kv_cache.py for why block 0 is reserved.
    """
    b = x.shape[0]
    bs = pk.shape[1]
    q, k, v = _project_qkv(cfg, p, x, positions[:, None])
    bids = jnp.take_along_axis(block_tables, (positions // bs)[:, None],
                               axis=1)[:, 0]                    # (B,)
    offs = positions % bs
    pk = pk.at[bids, offs].set(k[:, 0].astype(pk.dtype))
    pv = pv.at[bids, offs].set(v[:, 0].astype(pv.dtype))
    kall = _paged_gather(pk, block_tables)
    vall = _paged_gather(pv, block_tables)
    s = kall.shape[1]
    mask = jnp.arange(s)[None, None, :] <= positions[:, None, None]
    out = _sdpa(cfg, q, kall, vall, mask)
    return out @ p["wo"].astype(x.dtype), pk, pv


def attn_prefill_paged(cfg, p, x, pk, pv, block_table, p0):
    """Causal attention over ONE prompt chunk, writing through the block
    table.  x: (1, C, D) — chunk tokens at logical positions
    p0..p0+C-1; block_table: (nbt,) int32 for this one slot; p0: ()
    int32.  The chunk attends to everything already in the slot's blocks
    (earlier chunks) plus itself, causally.  Chunk padding past the real
    prompt length writes garbage k/v at positions the NEXT chunk (or
    decode) overwrites before they are ever unmasked, so bucketed chunk
    shapes stay compile-once without a pad mask.
    """
    _, c, _ = x.shape
    bs = pk.shape[1]
    tok_pos = p0 + jnp.arange(c)
    q, k, v = _project_qkv(cfg, p, x, tok_pos[None, :])
    bids = block_table[tok_pos // bs]                           # (C,)
    offs = tok_pos % bs
    pk = pk.at[bids, offs].set(k[0].astype(pk.dtype))
    pv = pv.at[bids, offs].set(v[0].astype(pv.dtype))
    kall = _paged_gather(pk, block_table[None, :])
    vall = _paged_gather(pv, block_table[None, :])
    s = kall.shape[1]
    mask = jnp.arange(s)[None, None, :] <= tok_pos[None, :, None]
    out = _sdpa(cfg, q, kall, vall, mask)
    return out @ p["wo"].astype(x.dtype), pk, pv
