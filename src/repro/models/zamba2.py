"""Zamba2-style hybrid: a Mamba2 backbone with SHARED attention+MLP blocks
applied every ``hybrid_attn_every`` layers (arXiv:2411.15242).

The shared blocks (n_shared_blocks of them, alternating) are stored once
and reused at every application point — Zamba2's parameter-sharing trick.
Simplification vs the released model (noted in DESIGN.md): the shared block
consumes the running hidden state directly rather than concat(hidden,
original embedding) + down-projection.

The layer scan stays uniform by branching on the layer index with
``lax.cond`` — the shared-attention branch costs nothing on non-attention
layers at run time, and the HLO contains each branch once.

Decode state = per-layer Mamba caches (O(1) in sequence length) + one KV
cache per shared-block application point — the attention part is why
long-context decode still carries an S-sized cache, but only at
``n_layers / hybrid_attn_every`` points instead of every layer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M


class HybridLM:
    def __init__(self, cfg: ModelConfig, mesh=None):
        assert cfg.hybrid_attn_every > 0
        assert cfg.n_layers % cfg.hybrid_attn_every == 0
        self.cfg = cfg
        self.mesh = mesh
        self.n_apps = cfg.n_layers // cfg.hybrid_attn_every
        self.constrain = lambda x: x

    # -- params --------------------------------------------------------------
    def _init_shared(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"norm1": L.make_norm_params(cfg, cfg.d_model),
                "attn": A.attn_init(k1, cfg, cfg.d_model),
                "norm2": L.make_norm_params(cfg, cfg.d_model),
                "mlp": L.mlp_init(k2, cfg, cfg.d_model, cfg.d_ff)}

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, km, ks, kh = jax.random.split(key, 4)
        mkeys = jax.random.split(km, cfg.n_layers)

        def init_layer(k):
            return {"norm": L.make_norm_params(cfg, cfg.d_model),
                    "mamba": M.mamba_init(k, cfg)}

        skeys = jax.random.split(ks, cfg.n_shared_blocks)
        return {
            "embed": L.embed_init(ke, cfg.vocab, cfg.d_model),
            "layers": jax.vmap(init_layer)(mkeys),
            "shared": jax.vmap(self._init_shared)(skeys),
            "final_norm": L.make_norm_params(cfg, cfg.d_model),
            "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, scale=0.02),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        layer = {"norm": L.norm_specs(cfg), "mamba": M.mamba_specs(cfg)}
        shared = {"norm1": L.norm_specs(cfg), "attn": A.attn_specs(cfg),
                  "norm2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}
        add = lambda name: (lambda axes: (name,) + tuple(axes))
        return {
            "embed": ("vocab", "embed"),
            "layers": jax.tree.map(add("layers"), layer,
                                   is_leaf=lambda x: isinstance(x, tuple)),
            "shared": jax.tree.map(add("shared"), shared,
                                   is_leaf=lambda x: isinstance(x, tuple)),
            "final_norm": L.norm_specs(cfg),
            "lm_head": ("embed", "vocab"),
        }

    # -- shared attention block -----------------------------------------------
    def _shared_block(self, sp, x, kv: Optional[A.KVCache], pos):
        cfg = self.cfg
        h = L.apply_norm(cfg, sp["norm1"], x)
        if kv is None:
            a_out = A.attn_apply_full(cfg, sp["attn"], h, causal=True)
            new_kv = None
        elif x.shape[1] > 1:      # prefill
            a_out, new_kv = A.attn_prefill(cfg, sp["attn"], h, kv)
        else:                     # decode
            a_out, new_kv = A.attn_decode(cfg, sp["attn"], h, kv, pos)
        x = x + a_out
        h = L.apply_norm(cfg, sp["norm2"], x)
        x = x + L.mlp_apply(cfg, sp["mlp"], h)
        return x, new_kv

    def _select_shared(self, params, app_idx):
        nb = self.cfg.n_shared_blocks
        return jax.tree.map(lambda p: p[app_idx % nb], params["shared"])

    # -- forward ---------------------------------------------------------------
    def _scan_layers(self, params, x, mamba_caches, kv_caches, pos):
        """Shared by train (caches None), prefill and decode."""
        cfg = self.cfg
        every = cfg.hybrid_attn_every
        idxs = jnp.arange(cfg.n_layers)

        def body(carry, xs):
            x, kvs = carry
            (lp, mcache), i = xs
            h = L.apply_norm(cfg, lp["norm"], x)
            if mcache is None:
                mo, _ = M.mamba_apply(cfg, lp["mamba"], h)
                new_mcache = mcache
            elif x.shape[1] > 1:
                mo, new_mcache = M.mamba_apply(cfg, lp["mamba"], h, mcache)
            else:
                mo, new_mcache = M.mamba_decode(cfg, lp["mamba"], h, mcache)
            x = self.constrain(x + mo)

            is_attn = (i % every) == (every - 1)
            app_idx = i // every

            def with_attn(x, kvs):
                sp = self._select_shared(params, app_idx)
                if kvs is None:
                    y, _ = self._shared_block(sp, x, None, pos)
                    return y, kvs
                kv = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, app_idx, 0, keepdims=False), kvs)
                y, new_kv = self._shared_block(sp, x, kv, pos)
                kvs = jax.tree.map(
                    lambda c, nk: jax.lax.dynamic_update_index_in_dim(
                        c, nk.astype(c.dtype), app_idx, 0), kvs, new_kv)
                return y, kvs

            x, kvs = jax.lax.cond(is_attn,
                                  lambda op: with_attn(*op),
                                  lambda op: op,
                                  (x, kvs))
            return (x, kvs), new_mcache

        if cfg.remat != "none" and mamba_caches is None:
            body = jax.checkpoint(body)
        (x, kv_caches), new_mcaches = jax.lax.scan(
            body, (x, kv_caches), ((params["layers"], mamba_caches), idxs))
        return x, new_mcaches, kv_caches

    def forward(self, params, tokens, embeds=None):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = self.constrain(params["embed"].astype(dt)[tokens])
        x, _, _ = self._scan_layers(params, x, None, None,
                                    jnp.zeros((), jnp.int32))
        x = L.apply_norm(cfg, params["final_norm"], x)
        return x @ params["lm_head"].astype(dt), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        ce = L.softmax_xent(logits[:, :-1, :], batch["tokens"][:, 1:])
        return ce, {"loss": ce}

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        mc = [M.init_mamba_cache(batch, cfg, dt)
              for _ in range(cfg.n_layers)]
        mc = jax.tree.map(lambda *xs: jnp.stack(xs), *mc)
        kv = [A.init_kv_cache(batch, cache_len, cfg, dt)
              for _ in range(self.n_apps)]
        kv = jax.tree.map(lambda *xs: jnp.stack(xs), *kv)
        return {"mamba": mc, "kv": kv, "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, tokens, cache):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = self.constrain(params["embed"].astype(dt)[tokens])
        x, mc, kv = self._scan_layers(params, x, cache["mamba"], cache["kv"],
                                      jnp.zeros((), jnp.int32))
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = x[:, -1:, :] @ params["lm_head"].astype(dt)
        return logits, {"mamba": mc, "kv": kv,
                        "pos": jnp.asarray(tokens.shape[1], jnp.int32)}

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = self.constrain(params["embed"].astype(dt)[tokens])
        x, mc, kv = self._scan_layers(params, x, cache["mamba"], cache["kv"],
                                      cache["pos"])
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["lm_head"].astype(dt)
        return logits, {"mamba": mc, "kv": kv, "pos": cache["pos"] + 1}
