"""Adapprox (Algorithm 3): Adam with a randomized-low-rank second moment.

Paper-faithful properties (validated in tests/test_adapprox.py):
  * no bias correction;
  * update clipping  u <- u / max(1, RMS(u)/d)  (Shazeer & Stern);
  * the first moment accumulates the *update* ``G/(sqrt(V)+eps)``, not the
    gradient;
  * decoupled weight decay (AdamW style);
  * the second moment lives only as factors (Q, U) between steps:
    ``V_t = b2 * Q_{t-1} U_{t-1}^T + (1 - b2) * G_t^2`` is rebuilt each step,
    used for the update, and re-factored with (adaptive-rank) S-RSI;
  * optional cosine-similarity guidance (Sec. 3.5).

Engineering modes (beyond-paper, all default-off => the default object IS the
faithful baseline):
  * ``implicit=True``: run S-RSI against the implicit operator so V is never
    materialised in HBM (the jnp fallback still forms one transient (m, n)
    f32 tile-set for the elementwise update; the Pallas kernel path removes
    even that).
  * ``use_kernels=True``: fused Pallas TPU kernels for the elementwise update
    and the sketch matmuls (kernels/).
  * ``rank.mode='exact'``: minimal-k selection instead of the paper's
    incremental probe.
  * ``warm_start=True`` (+ ``n_iter_warm``, ``warm_drift_xi``): seed S-RSI
    from the stored U so 1-2 power iterations replace the cold l = 5.
  * ``refresh_every=T``: full S-RSI every T steps; between refreshes the
    factors absorb gradients via the one-sided fold
    ``U <- b2*U + (1-b2)(G^2)^T Q`` under the frozen basis Q — the
    elementwise update remains exact w.r.t. the implicit operator.
  * ``bucketed=True``: same-shape factored leaves run as ONE vmapped
    trace per shape bucket instead of N sequential per-leaf traces.
  * ``fused_update=True``: the whole elementwise tail (V-reconstruct ->
    divide -> RMS clip -> update-EMA first moment -> guidance) runs as a
    two-pass pipeline: pass 1 emits the raw update direction plus every
    reduction the tail needs (V never stored); the clip/guidance scalars
    combine on-host; pass 2 applies them in one read-modify-write
    (kernels/fused_update.py on TPU, the ref oracles elsewhere).
    Bit-exact vs the unfused path for ``guidance="off"``; guidance modes
    agree to fp tolerance (reassociated reductions).

Composition: :func:`scale_by_adapprox` is the pure preconditioner — it maps
gradients to the (positive) update direction ``m_out`` and owns only the
factored/dense second moment, the update-EMA first moment, RMS clipping and
guidance.  :func:`adapprox` is the documented chain

    chain(scale_by_adapprox(cfg),
          add_decayed_weights(cfg.weight_decay),
          scale_by_schedule(cfg.lr),
          scale(-1.0))

which reproduces the monolithic seed implementation bit-for-bit (same
arithmetic, same order, same PRNG folding).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import factored as F
from repro.core import rank as R
from repro.core import srsi as S
from repro.core.transform import (add_decayed_weights, scale,
                                  scale_by_schedule)
from repro.core.types import GradientTransformation, chain
from repro.resilience.guards import (GuardConfig, GuardState, guard_spec,
                                     init_guard_state)
from repro.telemetry.snapshot import (TelemetrySnapshot, init_snapshot,
                                      snapshot_spec)


@dataclasses.dataclass(frozen=True)
class AdapproxConfig:
    lr: "float | Callable" = 1e-3          # float or schedule(step) -> lr
    b1: float = 0.9                        # 0.0 disables the first moment
    b2: float = 0.999
    eps: float = 1e-8
    clip_d: float = 1.0                    # RMS clip threshold d
    weight_decay: float = 0.0
    rank: R.RankConfig = dataclasses.field(default_factory=R.RankConfig)
    k_max_frac: float = 0.25               # k_max = frac * min(m, n)
    oversample: int = 5                    # p
    n_iter: int = 5                        # l (power iterations)
    min_dim_factor: int = 128              # factor only if min(m,n) >= this
    guidance: str = "off"                  # "off" | "update" | "stored"
    guidance_max_scale: float = 10.0       # safety clamp on 1/(1-theta+eps)
    implicit: bool = False                 # S-RSI on implicit operator
    use_kernels: bool = False              # Pallas fused update path
    factor_dtype: str = "float32"          # "int8": 4x smaller factors
    seed: int = 0
    # --- amortized-refresh perf knobs (all default-off => bit-exact vs the
    # paper-faithful baseline; see docs in scale_by_adapprox)
    refresh_every: int = 1                 # full S-RSI every T steps; between
                                           # refreshes fold G^2 into U under
                                           # the frozen basis Q (exact w.r.t.
                                           # the implicit operator)
    warm_start: bool = False               # seed S-RSI from the stored U
    n_iter_warm: int = 1                   # l when warm-started (1-2 suffice)
    warm_drift_xi: float = 0.5             # drift guard: cold-restart the
                                           # sketch when stored xi exceeds this
    bucketed: bool = False                 # group same-shape leaves into one
                                           # vmapped S-RSI + update per bucket
    fused_update: bool = False             # two-pass fused elementwise tail:
                                           # pass 1 emits u_hat + the clip /
                                           # guidance reductions with V never
                                           # stored; pass 2 applies clip +
                                           # first-moment EMA + guidance in
                                           # one read-modify-write (bit-exact
                                           # vs the unfused path for
                                           # guidance="off"; see
                                           # tests/test_fused.py)
    # --- telemetry subsystem (repro.telemetry; both default-off => the
    # state pytree and the update arithmetic are unchanged)
    telemetry: bool = False                # carry a fixed-shape
                                           # TelemetrySnapshot (per-leaf xi /
                                           # rank / clip activation,
                                           # refresh-vs-fold counters) in the
                                           # state; collection reuses values
                                           # the update already computes, so
                                           # updates stay BITWISE identical
                                           # to telemetry=False
    dynamic_refresh: bool = False          # carry refresh_every as a traced
                                           # int32 scalar in the state so the
                                           # closed-loop controller
                                           # (telemetry/controller.py) can
                                           # retune the cadence at runtime
                                           # with ZERO recompilation
    # --- resilience (repro.resilience; default None => state pytree and
    # arithmetic unchanged)
    guards: Optional[GuardConfig] = None   # per-leaf xi guards: a blow-up
                                           # past guards.xi_trip forces a
                                           # full S-RSI refresh next step;
                                           # after guards.max_demotions
                                           # CONSECUTIVE trips the leaf
                                           # falls back to the exact dense
                                           # second moment (per-leaf
                                           # lax.cond; needs a dense shadow
                                           # buffer, so demotion allocates
                                           # only when max_demotions > 0).
                                           # Forces the per-leaf path
                                           # (bucketed stacking would batch
                                           # the per-leaf demotion cond
                                           # into a select).


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdapproxState:
    step: jnp.ndarray                 # int32 scalar, counts from 0
    key: jax.Array                    # base PRNG key
    leaves: tuple                     # per-param FactoredLeaf | DenseLeaf,
                                      # in jax.tree.flatten(params) order
    telemetry: Optional[TelemetrySnapshot] = None
                                      # cfg.telemetry: per-step fixed-shape
                                      # snapshot (None => absent, the state
                                      # pytree is unchanged vs pre-telemetry)
    refresh_every: Optional[jnp.ndarray] = None
                                      # cfg.dynamic_refresh: the S-RSI
                                      # refresh cadence as a TRACED int32
                                      # scalar — the controller retunes it
                                      # without retriggering compilation
    guards: Optional[GuardState] = None
                                      # cfg.guards: per-factored-leaf trip /
                                      # forced-refresh / demotion state
                                      # (None => absent, pytree unchanged)


def _rms(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def _refresh_pred(step, refresh_t):
    """THE refresh-vs-fold predicate: full S-RSI at t = 1, 1+T, 1+2T, ...
    ``refresh_t`` may be a Python int or a traced int32 scalar
    (``dynamic_refresh``).  Single definition shared by the update branch
    dispatch and the telemetry counters, so they can never desynchronize."""
    return (step % refresh_t) == (1 % refresh_t)


def _fused_scalars(usq, m1dot, m1sq, size: int, cfg: AdapproxConfig,
                   guidance: bool):
    """Host-side combine of the pass-1 reductions into the three scalars
    pass 2 needs: ``(denom, out_scale, store_scale)``.

    ``denom = max(1, rms/d)`` reproduces the unfused clip bit-for-bit
    (``sqrt(usq/size + 1e-30)`` lowers to the same HLO as
    ``sqrt(mean(square(u)) + 1e-30)``).  The guidance scalars are recovered
    algebraically from the UNclipped pass-1 partials — with ``c = 1/denom``
    and ``acc = b1*m1 + (1-b1)*c*u_hat``:

        sum(u_c^2)   = usq / denom^2
        dot(u_c, m1) = m1dot / denom
        num          = b1*dot(u_c, m1) + (1-b1)*sum(u_c^2)
        sum(acc^2)   = b1^2*m1sq + 2*b1*(1-b1)*dot(u_c, m1)
                       + (1-b1)^2*sum(u_c^2)

    — the same quantities the unfused path reduces from the clipped
    arrays, reassociated, so guidance modes agree to fp tolerance (~1e-6
    rel) rather than bitwise; guidance="off" stays bitwise.
    """
    rms = jnp.sqrt(usq / size + 1e-30)
    denom = jnp.maximum(1.0, rms / cfg.clip_d)
    one = jnp.ones_like(denom)
    if not guidance:
        return denom, one, one
    su = usq / (denom * denom)
    du = m1dot / denom
    b1 = cfg.b1
    num = b1 * du + (1.0 - b1) * su
    accsq = (b1 * b1) * m1sq + 2.0 * b1 * (1.0 - b1) * du \
        + (1.0 - b1) ** 2 * su
    den = jnp.sqrt(su) * jnp.sqrt(accsq)
    theta = num / (den + 1e-30)
    gscale = jnp.clip(1.0 / (1.0 - theta + cfg.eps), 0.0,
                      cfg.guidance_max_scale)
    if cfg.guidance == "stored":
        return denom, gscale, gscale     # Eq. (18): the stored m1 is scaled
    return denom, gscale, one            # "update": step direction only


# Lazy module handles: repro.kernels.ops / repro.core.quantized are only
# needed on the kernel / int8 paths, and importing them per traced update
# call (the old inline ``from repro.kernels import ops``) put an import-lock
# acquisition + sys.modules lookup inside the hot per-leaf Python loop.
_KERNEL_OPS = None
_QUANTIZED = None


def _kernel_ops():
    global _KERNEL_OPS
    if _KERNEL_OPS is None:
        from repro.kernels import ops
        _KERNEL_OPS = ops
    return _KERNEL_OPS


def _quantized():
    global _QUANTIZED
    if _QUANTIZED is None:
        from repro.core import quantized
        _QUANTIZED = quantized
    return _QUANTIZED


def _leaf_r_store(shape: tuple[int, ...], cfg: AdapproxConfig) -> int:
    """Stored factor width for a (…, m, n) leaf."""
    m, n = shape[-2], shape[-1]
    if cfg.rank.mode == "static":
        r = min(cfg.rank.k_init, min(m, n))
    else:
        r = R.resolve_k_max(shape, cfg.rank, cfg.k_max_frac)
    return max(1, r)


def _leaf_oversample(shape: tuple[int, ...], r_store: int,
                     cfg: AdapproxConfig) -> int:
    """Paper constraint (k + p) <= min(m, n)."""
    m, n = shape[-2], shape[-1]
    return max(0, min(cfg.oversample, min(m, n) - r_store))


def _init_leaf(p: jnp.ndarray, cfg: AdapproxConfig):
    m1 = jnp.zeros(p.shape, jnp.float32) if cfg.b1 > 0 else None
    if F.should_factor(p.shape, cfg.min_dim_factor):
        bd = F.batch_dims(p.shape)
        m, n = p.shape[-2], p.shape[-1]
        r = _leaf_r_store(p.shape, cfg)
        k0 = cfg.rank.k_init if cfg.rank.mode != "static" else r
        q0 = jnp.zeros(bd + (m, r), jnp.float32)
        u0 = jnp.zeros(bd + (n, r), jnp.float32)
        if cfg.factor_dtype == "int8":
            QZ = _quantized()
            q0, u0 = QZ.quantize(q0), QZ.quantize(u0)
        return F.FactoredLeaf(
            q=q0,
            u=u0,
            k=jnp.full(bd, min(k0, r), jnp.int32),
            xi=jnp.zeros(bd, jnp.float32),
            m1=m1,
        )
    return F.DenseLeaf(v=jnp.zeros(p.shape, jnp.float32), m1=m1)


# ---------------------------------------------------------------------------
# Per-matrix (2D) factored update
# ---------------------------------------------------------------------------

def _factored_update_2d(g, q, u, k, xi_prev, m1, key, step,
                        cfg: AdapproxConfig,
                        r_store: int, p_eff: int, k_max_leaf: int,
                        refresh_t=None, force_refresh=None):
    """``refresh_t``: the refresh cadence as a traced int32 scalar
    (``cfg.dynamic_refresh``) or ``None`` (the compile-time
    ``cfg.refresh_every`` applies).  Returns one extra trailing output vs
    the pre-telemetry signature — ``clip_active`` (f32 scalar, 1.0 when
    the RMS clip engaged) — which is free to compute and dead-code
    eliminated when the caller drops it (telemetry off).

    ``force_refresh``: optional traced int32 scalar (the xi guard's
    per-leaf flag, ``cfg.guards``) OR-ed into the refresh predicate — a
    tripped leaf re-factorizes immediately instead of waiting out the
    fold cadence.  It rides in via closure like ``step``, so it stays an
    unbatched scalar under vmap and the cond remains a real branch."""
    g32 = g.astype(jnp.float32)
    dynamic = cfg.dynamic_refresh and refresh_t is not None
    r_every = refresh_t if dynamic else cfg.refresh_every
    # Lazy int8: with fused_update + factor_dtype="int8" the caller passes
    # the stored QuantizedMatrix triples straight through — pass 1
    # dequantizes per tile in VMEM and the f32 factors never materialize
    # in HBM on the update path.  Only the skinny refresh/fold branch
    # (inside its lax.cond, O((m+n) r) transient) sees f32 factors.
    is_q8 = hasattr(q, "q8")

    def _deq():
        QZ = _quantized()
        return QZ.dequantize(q), QZ.dequantize(u)

    # The skinny f32 view of the factors the refresh/fold branches consume
    # must be dequantized OUTSIDE the cond, for the same reason pass 1
    # stays outside it (see below): XLA contracts the codec's mul-add to
    # fma differently across program contexts, and the eager unfused path
    # dequantizes up front — in-branch dequant breaks the bitwise
    # contract.  O((m+n) r) transient, invisible next to the O(mn) update.
    q32u32 = _deq() if is_q8 else None

    v_op = None if is_q8 else S.make_implicit_v(q, u, g32, cfg.b2)

    # V_t is needed every step for the elementwise update unless the fused
    # pipeline (or the lowrank_update kernel) reconstructs it tile-wise;
    # the dense-S-RSI refresh reuses it.
    vmat = None
    if not cfg.fused_update and not cfg.use_kernels:
        vmat = v_op.materialize()          # paper-faithful: V_t formed

    # --- fused pass 1: u_hat + every tail reduction in one read of G, V
    # never stored (the dense-S-RSI refresh, if any, re-forms it inside
    # its lax.cond branch, so fold steps skip the materialisation).
    # ||V||_F^2 rides along only when the implicit S-RSI will consume it.
    # NOTE pass 1 must stay OUTSIDE the refresh/fold cond: XLA's fusion of
    # the V expression is not bit-stable across program contexts (fma
    # contraction differs), and the bitwise contract compares against the
    # unfused path, which forms V outside the cond.
    vfro = None
    yfold = None
    if cfg.fused_update:
        need_guid = cfg.b1 > 0 and cfg.guidance != "off"
        # Fold-fused: on an amortized-refresh cadence pass 1 also emits
        # the fold projection (G^2)^T Q from its already-resident G tiles,
        # so fold steps skip the standalone sq_matmul_t pass over G.
        # Computed EVERY step (pass 1 must stay outside the cond, see
        # above) and discarded on refresh steps — O(gm n r) partial words,
        # cheap next to the 3 m n the fold pass used to cost.
        with_fold = dynamic or cfg.refresh_every > 1
        (u_hat_raw, vfro, usq, m1dot, m1sq,
         yfold) = _kernel_ops().fused_precond(
            q, u, g32, cfg.b2, cfg.eps, m1=m1 if need_guid else None,
            with_vfro=cfg.implicit, with_fold=with_fold)

    def _run_srsi(n_it: int, u0, use_warm):
        op = (v_op if v_op is not None
              else S.make_implicit_v(*q32u32, g32, cfg.b2))
        if cfg.implicit:
            # ||V||_F^2 from the already-materialised V when we have one
            # (use_kernels=False), or from the fused pass-1 partials —
            # rebuilding it via the streaming frob_sq would duplicate the
            # O(mnr) reconstruct.
            if vfro is not None:
                fs = vfro
            else:
                fs = None if vmat is None else jnp.sum(jnp.square(vmat))
            return S.srsi_implicit(op, r_store, p_eff, n_it, key,
                                   frob_sq=fs, u0=u0, use_warm=use_warm)
        vm = vmat if vmat is not None else op.materialize()
        return S.srsi_dense(vm, r_store, p_eff, n_it, key,
                            u0=u0, use_warm=use_warm)

    def _refresh():
        """Full S-RSI re-factorisation + adaptive rank (the seed path)."""
        if cfg.warm_start:
            # Seed the subspace iteration from the stored U; the drift
            # guard falls back to a cold Gaussian sketch when the last
            # approximation error regressed past warm_drift_xi (srsi.py
            # additionally re-randomizes zero columns: init, rank growth).
            # Step 1 has no subspace to inherit, so it runs the full cold
            # iteration (scalar predicate => stays a real branch under
            # vmap).  A *drift-guard* cold restart keeps n_iter_warm —
            # its predicate is per-leaf (batched), so a cond would decay
            # to a both-branches select under vmap and always pay the
            # full-l cost; instead the re-randomized sketch re-converges
            # over the next couple of warm refreshes (power iterations
            # accumulate across steps on the slow-moving EMA operator).
            use_warm = xi_prev <= cfg.warm_drift_xi
            u_seed = q32u32[1] if is_q8 else u
            res = jax.lax.cond(
                step == 1,
                lambda: _run_srsi(cfg.n_iter, None, None),
                lambda: _run_srsi(cfg.n_iter_warm, u_seed, use_warm))
        else:
            res = _run_srsi(cfg.n_iter, None, None)
        # --- adaptive rank (Algorithm 2 over the captured-energy CDF)
        k_new = R.select_rank(res.cum_energy, res.frob_sq, cfg.rank,
                              k_max_leaf, step, jnp.minimum(k, k_max_leaf),
                              refresh_every=r_every)
        xi = R.xi_of_k(res.cum_energy, res.frob_sq, k_new)
        mask = S.col_mask(r_store, k_new)
        return res.q * mask[None, :], res.u * mask[None, :], k_new, xi

    def _fold():
        """Between refreshes: fold G_t^2 into U under the frozen basis Q —
        U <- mask * (b2*U + (1-b2) (G^2)^T Q), the exact projection of
        V_t = b2 V_{t-1} + (1-b2) G^2 onto span(Q).  O(mnr) matmul, no
        subspace iteration, no QR.  With the fold-fused pass 1 (yfold
        from above) the matmul has already been paid for by the update's
        read of G and only the rank-r EMA runs here."""
        mask = S.col_mask(r_store, jnp.minimum(k, k_max_leaf))
        q32, u32 = q32u32 if is_q8 else (q, u)
        if yfold is not None:
            # yfold is the same single-dot (G^2)^T Q product the branches
            # below compute (one HLO, bit-stable in or out of the cond),
            # and the EMA runs inside the branch in both layouts — the
            # fused == unfused bitwise contract holds.
            u_new = (cfg.b2 * u32
                     + (1.0 - cfg.b2) * yfold) * mask[None, :]
        elif cfg.use_kernels:
            u_new = _kernel_ops().one_sided_fold(u32, q32, g32, cfg.b2,
                                                 mask)
        else:
            u_new = (cfg.b2 * u32
                     + (1.0 - cfg.b2) * ((g32 * g32).T @ q32)) \
                * mask[None, :]
        return q32, u_new, k, xi_prev

    if dynamic:
        # Traced cadence: the refresh/fold cond is always present in the
        # program and the predicate depends only on traced scalars, so a
        # host-side cadence change re-uses the compiled executable (zero
        # recompilation — tests/test_telemetry.py).  T = 1 refreshes every
        # step through the cond (same arithmetic as the direct call).
        pred = _refresh_pred(step, refresh_t)
    elif cfg.refresh_every > 1:
        # step counts from 1; refresh at t = 1, 1+T, 1+2T, ...  The scalar
        # predicate is unbatched under vmap, so lax.cond stays a real
        # branch (fold steps never pay for the S-RSI HLO).
        pred = _refresh_pred(step, cfg.refresh_every)
    else:
        pred = None                        # refresh every step, no cond
    if pred is not None:
        if force_refresh is not None:
            pred = jnp.logical_or(pred, force_refresh > 0)
        q_new, u_new, k_new, xi = jax.lax.cond(pred, _refresh, _fold)
    else:
        q_new, u_new, k_new, xi = _refresh()

    # --- elementwise tail, fused: host-combine the pass-1 reductions into
    # the clip / guidance scalars, then one read-modify-write (pass 2)
    # applies clip + first-moment EMA + guidance together.
    if cfg.fused_update:
        denom, out_scale, store_scale = _fused_scalars(
            usq, m1dot, m1sq, g32.size, cfg, need_guid)
        clip_active = (denom > 1.0).astype(jnp.float32)
        if cfg.b1 > 0:
            # guidance "off"/"stored": out_scale == store_scale, so the
            # step direction IS the new first moment (same as unfused) —
            # the shared-output kernel writes it once.
            m_out, m1_new = _kernel_ops().fused_apply(
                u_hat_raw, m1, denom, cfg.b1, out_scale, store_scale,
                shared_out=cfg.guidance != "update")
        else:
            m_out, m1_new = _kernel_ops().fused_apply(
                u_hat_raw, None, denom, cfg.b1, out_scale, store_scale)
        return m_out, q_new, u_new, k_new, xi, m1_new, clip_active

    # --- elementwise update from V_t (prev factors + fresh G^2), unfused
    if cfg.use_kernels:
        u_hat = _kernel_ops().lowrank_update(q, u, g32, cfg.b2, cfg.eps)
    else:
        u_hat = g32 / (jnp.sqrt(vmat) + cfg.eps)

    clip_denom = jnp.maximum(1.0, _rms(u_hat) / cfg.clip_d)
    clip_active = (clip_denom > 1.0).astype(jnp.float32)
    u_hat = u_hat / clip_denom

    # --- first moment over updates + optional cosine guidance
    if cfg.b1 > 0:
        m1_acc = cfg.b1 * m1 + (1.0 - cfg.b1) * u_hat
        if cfg.guidance != "off":
            num = jnp.sum(u_hat * m1_acc)
            den = jnp.sqrt(jnp.sum(u_hat**2)) * jnp.sqrt(jnp.sum(m1_acc**2))
            theta = num / (den + 1e-30)
            scale = jnp.clip(1.0 / (1.0 - theta + cfg.eps), 0.0,
                             cfg.guidance_max_scale)
            if cfg.guidance == "stored":
                m1_acc = m1_acc * scale      # Eq. (18) literally
                m_out = m1_acc
            else:                            # "update": scale applied step only
                m_out = m1_acc * scale
        else:
            m_out = m1_acc
        m1_new = m1_acc
    else:
        m_out, m1_new = u_hat, None

    return m_out, q_new, u_new, k_new, xi, m1_new, clip_active


def _leaf_meta(w_shape, r_store: int, cfg: AdapproxConfig):
    p_eff = _leaf_oversample(w_shape, r_store, cfg)
    k_max_leaf = (r_store if cfg.rank.mode == "static"
                  else R.resolve_k_max(w_shape, cfg.rank, cfg.k_max_frac))
    return p_eff, k_max_leaf


def _dequant_factors(leaf: F.FactoredLeaf, cfg: AdapproxConfig):
    if cfg.factor_dtype == "int8":
        QZ = _quantized()
        return QZ.dequantize(leaf.q), QZ.dequantize(leaf.u)
    return leaf.q, leaf.u


def _lazy_q8(cfg: AdapproxConfig) -> bool:
    """True when int8 factors skip the upfront dequant and ride into the
    fused pipeline as QuantizedMatrix triples (dequant fused into the
    pass-1 tile loads; refresh/fold dequantize transiently in-branch)."""
    return cfg.factor_dtype == "int8" and cfg.fused_update


def _run_factored_core(g, q32, u32, k, xi, m1, keys, step,
                       cfg: AdapproxConfig, r_store: int, p_eff: int,
                       k_max_leaf: int, n_batch: int, refresh_t=None,
                       force_refresh=None):
    """vmap ``_factored_update_2d`` over ``n_batch`` leading axes — the
    shared engine of the per-leaf path (n_batch = len(batch_dims)) and the
    bucketed path (one extra stacking axis).  ``step``, ``refresh_t`` and
    ``force_refresh`` ride in via closure, so they stay UNbatched scalars
    under vmap and the refresh/fold ``lax.cond`` remains a real branch."""
    fn = functools.partial(_factored_update_2d, cfg=cfg, r_store=r_store,
                           p_eff=p_eff, k_max_leaf=k_max_leaf)
    # ``m1`` may be None (b1 = 0); None is an empty pytree so it passes
    # through vmap untouched.
    core = lambda g, q, u, k, xi, m1, key: fn(g, q, u, k, xi, m1, key, step,
                                              refresh_t=refresh_t,
                                              force_refresh=force_refresh)
    mapped = F.vmap_over_batch(core, n_batch)
    return mapped(g, q32, u32, k, xi, m1, keys)


def _update_factored(g, leaf: F.FactoredLeaf, w, key, step,
                     cfg: AdapproxConfig, refresh_t=None, force_refresh=None):
    bd = F.batch_dims(w.shape)
    if _lazy_q8(cfg):
        # Dequant-fused: the stored QuantizedMatrix triples flow straight
        # into fused pass 1 (per-tile dequant in VMEM) — no upfront f32
        # materialisation of the factors.
        leaf_q, leaf_u = leaf.q, leaf.u
        r_store = leaf.q.q8.shape[-1]
    else:
        leaf_q, leaf_u = _dequant_factors(leaf, cfg)
        r_store = leaf_q.shape[-1]
    p_eff, k_max_leaf = _leaf_meta(w.shape, r_store, cfg)
    keys = F.batched_keys(key, bd)
    m_out, q, u, k, xi, m1, clip = _run_factored_core(
        g, leaf_q, leaf_u, leaf.k, leaf.xi, leaf.m1, keys, step, cfg,
        r_store, p_eff, k_max_leaf, len(bd), refresh_t, force_refresh)
    if cfg.factor_dtype == "int8":
        QZ = _quantized()
        q, u = QZ.quantize(q), QZ.quantize(u)
    return (m_out, F.FactoredLeaf(q=q, u=u, k=k, xi=xi, m1=m1),
            (clip, k_max_leaf))


def _update_factored_guarded(g, leaf: F.FactoredLeaf, w, key, step,
                             cfg: AdapproxConfig, refresh_t, guard):
    """Per-leaf update under the xi guard (``cfg.guards``).

    ``guard = (force_refresh, demoted, dense_v)`` — per-leaf int32 scalars
    from the prior :class:`GuardState` plus the leaf's dense shadow buffer
    (``None`` when ``max_demotions == 0``; then only forced refresh
    applies and the factored path runs unconditionally).

    A demoted leaf runs the exact dense second moment on its shadow
    buffer: same elementwise tail as ``_update_dense`` but with the
    PER-MATRIX RMS clip of the factored path (reduced over the trailing
    two axes, so batched leaves clip slice-wise exactly like before
    demotion), guidance off, factors/k frozen, xi pinned to 0 — a demoted
    leaf reads as healthy downstream.  The dispatch is a scalar-predicate
    ``lax.cond``, so un-demoted leaves never execute the dense HLO.

    Returns ``(m_out, new_leaf, (clip, k_max_leaf), dense_v_new)``.
    """
    force, demoted, dense_v = guard
    r_store = (leaf.q.q8.shape[-1] if cfg.factor_dtype == "int8"
               else leaf.q.shape[-1])
    _, k_max_leaf = _leaf_meta(w.shape, r_store, cfg)
    if dense_v is None:
        m_out, nl, tap = _update_factored(g, leaf, w, key, step, cfg,
                                          refresh_t, force_refresh=force)
        return m_out, nl, tap, None

    def _fact_branch():
        m_out, nl, tap = _update_factored(g, leaf, w, key, step, cfg,
                                          refresh_t, force_refresh=force)
        return (m_out, nl.q, nl.u, nl.k, nl.xi, nl.m1, tap[0], dense_v)

    def _dense_branch():
        g32 = g.astype(jnp.float32)
        v = cfg.b2 * dense_v + (1.0 - cfg.b2) * jnp.square(g32)
        u_hat = g32 / (jnp.sqrt(v) + cfg.eps)
        rms = jnp.sqrt(jnp.mean(jnp.square(u_hat), axis=(-2, -1)) + 1e-30)
        clip_denom = jnp.maximum(1.0, rms / cfg.clip_d)
        clip_active = (clip_denom > 1.0).astype(jnp.float32)
        u_hat = u_hat / clip_denom[..., None, None]
        if leaf.m1 is not None:
            m1_new = cfg.b1 * leaf.m1 + (1.0 - cfg.b1) * u_hat
            m_out = m1_new
        else:
            m1_new, m_out = None, u_hat
        return (m_out, leaf.q, leaf.u, leaf.k, jnp.zeros_like(leaf.xi),
                m1_new, clip_active, v)

    m_out, q, u, k, xi, m1, clip, dv = jax.lax.cond(
        demoted > 0, _dense_branch, _fact_branch)
    return (m_out, F.FactoredLeaf(q=q, u=u, k=k, xi=xi, m1=m1),
            (clip, k_max_leaf), dv)


def _update_factored_bucket(gs, leaves, ws, idxs, step_key, step,
                            cfg: AdapproxConfig, refresh_t=None):
    """One vmapped S-RSI + update for a bucket of same-signature leaves.

    All leaves share ``(batch_dims, m, n, r_store)`` (see
    ``F.leaf_signature``), so their state stacks along a new leading axis
    and the whole bucket traces ONCE — for a transformer stack with dozens
    of shape-sharing projection matrices this collapses N sequential HLO
    copies into one batched program (smaller HLO, fewer launches).  Each
    slice sees exactly the per-leaf PRNG key ``fold_in(step_key, i)`` and
    the same arithmetic, merely batched — updates, factors, rank and first
    moment are bit-identical to the per-leaf loop (the metrics-only ``xi``
    scalar can wobble 1 ulp from batched-vs-unbatched XLA fusion; see
    tests/test_refresh.py).
    """
    bd = F.batch_dims(ws[0].shape)
    if _lazy_q8(cfg):
        # QuantizedMatrix is a NamedTuple pytree: stacking fieldwise keeps
        # the triples intact for the dequant-fused pass-1 loads.
        stk = lambda ms: jax.tree.map(lambda *xs: jnp.stack(xs), *ms)
        q_stk = stk([leaf.q for leaf in leaves])
        u_stk = stk([leaf.u for leaf in leaves])
        r_store = q_stk.q8.shape[-1]
    else:
        deq = [_dequant_factors(leaf, cfg) for leaf in leaves]
        q_stk = jnp.stack([q for q, _ in deq])
        u_stk = jnp.stack([u for _, u in deq])
        r_store = q_stk.shape[-1]
    p_eff, k_max_leaf = _leaf_meta(ws[0].shape, r_store, cfg)
    g_stk = jnp.stack(gs)          # uniform dtype: part of the signature
    k_stk = jnp.stack([leaf.k for leaf in leaves])
    xi_stk = jnp.stack([leaf.xi for leaf in leaves])
    m1_stk = (jnp.stack([leaf.m1 for leaf in leaves])
              if leaves[0].m1 is not None else None)
    keys = jnp.stack([
        F.batched_keys(jax.random.fold_in(step_key, i), bd) for i in idxs])
    m_out, q, u, k, xi, m1, clip = _run_factored_core(
        g_stk, q_stk, u_stk, k_stk, xi_stk, m1_stk, keys, step, cfg,
        r_store, p_eff, k_max_leaf, len(bd) + 1, refresh_t)
    results = []
    for j in range(len(idxs)):
        qj, uj = q[j], u[j]
        if cfg.factor_dtype == "int8":
            QZ = _quantized()
            qj, uj = QZ.quantize(qj), QZ.quantize(uj)
        m1j = m1[j] if m1 is not None else None
        results.append((m_out[j],
                        F.FactoredLeaf(q=qj, u=uj, k=k[j], xi=xi[j], m1=m1j),
                        (clip[j], k_max_leaf)))
    return results


def _update_dense(g, leaf: F.DenseLeaf, cfg: AdapproxConfig):
    g32 = g.astype(jnp.float32)
    v = cfg.b2 * leaf.v + (1.0 - cfg.b2) * jnp.square(g32)
    u_hat = g32 / (jnp.sqrt(v) + cfg.eps)
    if cfg.fused_update:
        # Same pass-2 fusion as the factored leaves (dense leaves have no
        # guidance): the leaf is viewed as one (1, size) row so the pass-2
        # kernel / oracle applies clip + EMA in a single read-modify-write.
        denom, out_scale, store_scale = _fused_scalars(
            jnp.sum(jnp.square(u_hat)), None, None, u_hat.size, cfg,
            guidance=False)
        clip_active = (denom > 1.0).astype(jnp.float32)
        u2 = u_hat.reshape(1, -1)
        if leaf.m1 is not None:
            m_out2, m1_new2 = _kernel_ops().fused_apply(
                u2, leaf.m1.reshape(1, -1), denom, cfg.b1,
                out_scale, store_scale, shared_out=True)
            return (m_out2.reshape(u_hat.shape),
                    F.DenseLeaf(v=v, m1=m1_new2.reshape(u_hat.shape)),
                    clip_active)
        m_out2, _ = _kernel_ops().fused_apply(u2, None, denom, cfg.b1,
                                              out_scale, store_scale)
        return m_out2.reshape(u_hat.shape), F.DenseLeaf(v=v, m1=None), \
            clip_active
    clip_denom = jnp.maximum(1.0, _rms(u_hat) / cfg.clip_d)
    clip_active = (clip_denom > 1.0).astype(jnp.float32)
    u_hat = u_hat / clip_denom
    if leaf.m1 is not None:
        m1 = cfg.b1 * leaf.m1 + (1.0 - cfg.b1) * u_hat
        m_out = m1
    else:
        m1, m_out = None, u_hat
    return m_out, F.DenseLeaf(v=v, m1=m1), clip_active


# ---------------------------------------------------------------------------
# Telemetry assembly (cfg.telemetry; repro.telemetry.snapshot)
# ---------------------------------------------------------------------------

def _assemble_snapshot(prev: TelemetrySnapshot, step, new_leaves, taps,
                       refresh_t, cfg: AdapproxConfig) -> TelemetrySnapshot:
    """Fold this step's per-leaf taps into the fixed-shape snapshot.

    Everything here is a scalar mean over values the update already
    produced (xi / k live in the new leaves, clip flags in ``taps``) —
    collection adds no reductions over parameter-sized arrays, which is
    what keeps its overhead in the noise (see
    ``adapprox_refresh5_warm1_telemetry`` in BENCH_step_time.json).
    """
    f32 = jnp.float32
    xi, k, k_frac = [], [], []
    for leaf, tap in zip(new_leaves, taps):
        if not isinstance(leaf, F.FactoredLeaf):
            continue
        _, k_max_leaf = tap
        xi.append(jnp.mean(leaf.xi))
        kf = jnp.minimum(leaf.k, k_max_leaf).astype(f32)
        k.append(jnp.mean(kf))
        k_frac.append(jnp.mean(kf / k_max_leaf))
    clip_rate = [jnp.mean(tap[0] if isinstance(tap, tuple) else tap)
                 for tap in taps]

    def stack(xs, n):
        return jnp.stack(xs) if xs else jnp.zeros((n,), f32)

    if cfg.dynamic_refresh and refresh_t is not None:
        t_now = refresh_t
        did = _refresh_pred(step, t_now).astype(f32)
    else:
        t_now = jnp.asarray(cfg.refresh_every, jnp.int32)
        if cfg.refresh_every > 1:
            did = _refresh_pred(step, cfg.refresh_every).astype(f32)
        else:
            did = jnp.ones((), f32)    # refresh_every=1: every step refreshes
    return TelemetrySnapshot(
        step=step,
        xi=stack(xi, 0), k=stack(k, 0), k_frac=stack(k_frac, 0),
        clip_rate=stack(clip_rate, len(taps)),
        did_refresh=did,
        refresh_steps=prev.refresh_steps + did.astype(jnp.int32),
        fold_steps=prev.fold_steps + (1 - did).astype(jnp.int32),
        refresh_every=t_now,
        leaf_indices=prev.leaf_indices,
        dense_indices=prev.dense_indices,
    )


# ---------------------------------------------------------------------------
# Sharding protocol
# ---------------------------------------------------------------------------

def _factored_leaf_spec(pspec: P, has_m1: bool) -> F.FactoredLeaf:
    """Param (…, m, n) with spec (…, a, b):
    q (…, m, r) -> (…, a, None); u (…, n, r) -> (…, b, None);
    k/xi (…,) -> batch part; m1 -> param spec.  (The factors of a sharded
    matrix shard along the same axes as the matrix itself.)"""
    parts = list(pspec)
    bd, a, b = parts[:-2], parts[-2], parts[-1]
    return F.FactoredLeaf(
        q=P(*bd, a, None), u=P(*bd, b, None),
        k=P(*bd), xi=P(*bd),
        m1=P(*parts) if has_m1 else None)


def _state_spec(state: AdapproxState, param_specs) -> AdapproxState:
    flat_specs = jax.tree.leaves(param_specs,
                                 is_leaf=lambda x: isinstance(x, P))
    leaves = []
    for pspec, leaf in zip(flat_specs, state.leaves):
        has_m1 = leaf.m1 is not None
        if isinstance(leaf, F.FactoredLeaf):
            leaves.append(_factored_leaf_spec(pspec, has_m1))
        else:
            leaves.append(F.DenseLeaf(v=pspec, m1=pspec if has_m1 else None))
    # telemetry scalars / per-leaf vectors and the dynamic cadence scalar
    # are replicated on every device — nothing to shard, no host sync
    # beyond the existing metric fetch.
    tel = (snapshot_spec(state.telemetry)
           if state.telemetry is not None else None)
    re_spec = P() if state.refresh_every is not None else None
    g_spec = None
    if state.guards is not None:
        fpspecs = [pspec for pspec, leaf in zip(flat_specs, state.leaves)
                   if isinstance(leaf, F.FactoredLeaf)]
        g_spec = guard_spec(state.guards, fpspecs)
    return AdapproxState(step=P(), key=P(), leaves=tuple(leaves),
                         telemetry=tel, refresh_every=re_spec,
                         guards=g_spec)


# ---------------------------------------------------------------------------
# xi-guard bookkeeping (cfg.guards; repro.resilience.guards)
# ---------------------------------------------------------------------------

def _advance_guard_state(gstate: GuardState, gcfg: GuardConfig,
                         cfg: AdapproxConfig, new_leaves, dv_out):
    """Fold this step's xi outcomes into the next :class:`GuardState`.

    A leaf trips when its WORST batch slice exceeds ``xi_trip`` (max, not
    the telemetry mean — one blown slice corrupts that slice's updates
    regardless of how healthy its siblings are).  Trips are consecutive:
    any calm step resets the leaf's count.  A trip schedules a forced
    full refresh for the NEXT step; ``max_demotions`` consecutive trips
    demote the leaf instead, seeding its dense shadow buffer from the
    just-refreshed factors (``max(Q Uᵀ, 0)`` — the reconstruction can go
    epsilon-negative, and sqrt of that is a NaN factory).  The seeding
    cond has a scalar predicate, so steps without a demotion never pay
    the O(mnr) reconstruction.
    """
    f_leaves = [l for l in new_leaves if isinstance(l, F.FactoredLeaf)]
    if not f_leaves:
        return gstate
    xi_vec = jnp.stack([jnp.max(l.xi) for l in f_leaves])
    already = gstate.demoted > 0
    tripped = jnp.logical_and(xi_vec > gcfg.xi_trip, ~already)
    trips = jnp.where(tripped, gstate.trips + 1, 0).astype(jnp.int32)
    if gcfg.max_demotions > 0:
        newly = jnp.logical_and(~already, trips >= gcfg.max_demotions)
        demoted = jnp.maximum(gstate.demoted, newly.astype(jnp.int32))
        force = jnp.logical_and(tripped, ~newly).astype(jnp.int32)
        dense_v = []
        for j, leaf in enumerate(f_leaves):
            def _seed(leaf=leaf):
                q32, u32 = _dequant_factors(leaf, cfg)
                recon = jnp.einsum("...mr,...nr->...mn", q32, u32)
                return jnp.maximum(recon, 0.0)
            dense_v.append(jax.lax.cond(
                newly[j], _seed, lambda j=j: dv_out[j]))
        demotions = (gstate.demotions
                     + jnp.sum(newly).astype(jnp.int32))
        dense_v = tuple(dense_v)
    else:
        demoted = gstate.demoted
        force = tripped.astype(jnp.int32)
        dense_v = gstate.dense_v
        demotions = gstate.demotions
    return GuardState(
        trips=trips, force_refresh=force, demoted=demoted,
        trip_total=gstate.trip_total + jnp.sum(tripped).astype(jnp.int32),
        demotions=demotions, dense_v=dense_v)


# ---------------------------------------------------------------------------
# Public factories
# ---------------------------------------------------------------------------

def scale_by_adapprox(cfg: AdapproxConfig) -> GradientTransformation:
    """The pure Adapprox preconditioner: gradients -> update direction.

    Owns the factored second moment (S-RSI refresh, adaptive rank), the
    update-EMA first moment, per-matrix RMS clipping and cosine guidance.
    Learning rate, weight decay and the descent sign are NOT applied —
    chain with ``add_decayed_weights`` / ``scale_by_schedule`` / ``scale``
    (see :func:`adapprox`).  ``cfg.lr`` / ``cfg.weight_decay`` are ignored
    here.
    """

    def init(params):
        flat, _ = jax.tree.flatten(params)
        leaves = tuple(_init_leaf(p, cfg) for p in flat)
        tel = None
        if cfg.telemetry:
            fidx = tuple(i for i, l in enumerate(leaves)
                         if isinstance(l, F.FactoredLeaf))
            didx = tuple(i for i, l in enumerate(leaves)
                         if not isinstance(l, F.FactoredLeaf))
            tel = init_snapshot(len(fidx), len(leaves), cfg.refresh_every,
                                leaf_indices=fidx, dense_indices=didx)
        r_every = (jnp.asarray(cfg.refresh_every, jnp.int32)
                   if cfg.dynamic_refresh else None)
        gstate = None
        if cfg.guards is not None:
            fshapes = [p.shape for p, l in zip(flat, leaves)
                       if isinstance(l, F.FactoredLeaf)]
            gstate = init_guard_state(fshapes, cfg.guards.max_demotions)
        return AdapproxState(step=jnp.zeros((), jnp.int32),
                             key=jax.random.PRNGKey(cfg.seed),
                             leaves=leaves, telemetry=tel,
                             refresh_every=r_every, guards=gstate)

    def update(grads, state: AdapproxState, params):
        step = state.step + 1              # paper counts from t = 1
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        step_key = jax.random.fold_in(state.key, step)
        refresh_t = state.refresh_every if cfg.dynamic_refresh else None

        n_leaves = len(flat_p)
        outs = [None] * n_leaves
        new_leaves = [None] * n_leaves
        # per-leaf telemetry taps: (clip_active, k_max_leaf | None).  The
        # clip flag is an output the update computes anyway; when
        # cfg.telemetry is off nothing consumes it and XLA dead-code
        # eliminates it, so the off path stays bitwise-identical.
        taps = [None] * n_leaves

        gcfg, gstate = cfg.guards, state.guards
        # guards force the per-leaf path: the per-leaf demotion lax.cond
        # would decay to a both-branches select inside a bucketed vmap.
        if not (cfg.bucketed and gcfg is None):
            dv_out = (list(gstate.dense_v)
                      if gcfg is not None and gstate.dense_v else None)
            j = 0                        # factored-leaf ordinal
            for i, (g, leaf, w) in enumerate(
                    zip(flat_g, state.leaves, flat_p)):
                if isinstance(leaf, F.FactoredLeaf):
                    if gcfg is not None:
                        guard = (gstate.force_refresh[j], gstate.demoted[j],
                                 dv_out[j] if dv_out is not None else None)
                        d, nl, tap, dv = _update_factored_guarded(
                            g, leaf, w, jax.random.fold_in(step_key, i),
                            step, cfg, refresh_t, guard)
                        if dv_out is not None:
                            dv_out[j] = dv
                    else:
                        d, nl, tap = _update_factored(
                            g, leaf, w, jax.random.fold_in(step_key, i),
                            step, cfg, refresh_t)
                    j += 1
                else:
                    d, nl, clip = _update_dense(g, leaf, cfg)
                    tap = (clip, None)
                outs[i], new_leaves[i], taps[i] = d, nl, tap
        else:
            # Bucketed execution: dense leaves update inline; factored
            # leaves group by (batch_dims, m, n, dtype) signature and run
            # one vmapped trace per bucket (bit-identical — per-leaf PRNG
            # folding is preserved inside the bucket).
            buckets: dict = {}
            for i, (g, leaf, w) in enumerate(
                    zip(flat_g, state.leaves, flat_p)):
                if isinstance(leaf, F.FactoredLeaf):
                    buckets.setdefault(
                        F.leaf_signature(w.shape, g.dtype), []).append(i)
                else:
                    d, nl, clip = _update_dense(g, leaf, cfg)
                    outs[i], new_leaves[i], taps[i] = d, nl, (clip, None)
            for idxs in buckets.values():
                if len(idxs) == 1:          # singleton: skip stack/unstack
                    i = idxs[0]
                    outs[i], new_leaves[i], taps[i] = _update_factored(
                        flat_g[i], state.leaves[i], flat_p[i],
                        jax.random.fold_in(step_key, i), step, cfg,
                        refresh_t)
                    continue
                res = _update_factored_bucket(
                    [flat_g[i] for i in idxs],
                    [state.leaves[i] for i in idxs],
                    [flat_p[i] for i in idxs],
                    idxs, step_key, step, cfg, refresh_t)
                for i, (d, nl, tap) in zip(idxs, res):
                    outs[i], new_leaves[i], taps[i] = d, nl, tap

        tel = None
        if cfg.telemetry:
            tel = _assemble_snapshot(state.telemetry, step, new_leaves,
                                     taps, refresh_t, cfg)
        new_gstate = None
        if gcfg is not None:
            new_gstate = _advance_guard_state(gstate, gcfg, cfg, new_leaves,
                                              dv_out)
        updates = jax.tree.unflatten(treedef, outs)
        return updates, AdapproxState(step=step, key=state.key,
                                      leaves=tuple(new_leaves),
                                      telemetry=tel,
                                      refresh_every=state.refresh_every,
                                      guards=new_gstate)

    return GradientTransformation(init, update, _state_spec)


def adapprox(cfg: AdapproxConfig,
             decay_mask: Optional[Callable] = None) -> GradientTransformation:
    """Algorithm 3 as a documented chain (bit-identical to the former
    monolithic implementation for any config):

        preconditioner -> + wd*W -> * lr_t -> * (-1)

    ``decay_mask``: optional mask forwarded to ``add_decayed_weights``
    (e.g. ``transform.mask_nd(2)`` to exempt biases/norms from decay).
    """
    return chain(
        scale_by_adapprox(cfg),
        add_decayed_weights(cfg.weight_decay, decay_mask),
        scale_by_schedule(cfg.lr),
        scale(-1.0),
    )


def _find_states(state, cls):
    """Yield every ``cls`` instance inside an (arbitrarily nested) optimizer
    state — chains are tuples, partitions are dicts."""
    if isinstance(state, cls):
        yield state
        return
    if isinstance(state, (tuple, list)):
        for s in state:
            yield from _find_states(s, cls)
    elif isinstance(state, dict):
        for s in state.values():
            yield from _find_states(s, cls)
    elif hasattr(state, "inner"):           # PartitionState
        yield from _find_states(state.inner, cls)


def rank_metrics(state) -> dict:
    """Mean effective rank / xi across factored leaves (for logging).

    Accepts a bare ``AdapproxState`` or any chain/partition state
    containing one.
    """
    ks, xis = [], []
    for sub in _find_states(state, AdapproxState):
        for leaf in sub.leaves:
            if isinstance(leaf, F.FactoredLeaf):
                ks.append(jnp.mean(leaf.k.astype(jnp.float32)))
                xis.append(jnp.mean(leaf.xi))
    if not ks:
        return {}
    return {"adapprox/mean_rank": jnp.mean(jnp.stack(ks)),
            "adapprox/mean_xi": jnp.mean(jnp.stack(xis))}


def adapprox_state(state) -> AdapproxState:
    """Extract the ``AdapproxState`` from a (possibly chained/partitioned)
    optimizer state — convenience for tests and metric probes."""
    for sub in _find_states(state, AdapproxState):
        return sub
    raise ValueError("no AdapproxState found in optimizer state")
