"""CAME baseline (Luo et al., ACL 2023): Confidence-guided Adaptive Memory
Efficient optimization.

Adafactor's factored second moment, plus a factored *instability* statistic
``S_t = (u_hat_t - m_t)^2`` whose inverse square root scales the first-moment
update (confidence guidance).  CAME requires ``b1 > 0`` (the paper notes it
is non-viable at ``b1 = 0`` — our constructor enforces that, matching
Table 2's "--" entry).

:func:`scale_by_came` is the pure preconditioner; :func:`came` is the
documented chain

    chain(scale_by_came(cfg),
          add_decayed_weights(wd),
          scale_by_schedule(lr),
          scale(-1.0))

bit-identical to the former monolithic implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.transform import (add_decayed_weights, scale,
                                  scale_by_schedule)
from repro.core.types import GradientTransformation, chain


@dataclasses.dataclass(frozen=True)
class CAMEConfig:
    lr: "float | Callable" = 1e-3
    b1: float = 0.9
    b2: float = 0.999      # second-moment decay
    b3: float = 0.9999     # instability-statistic decay
    eps1: float = 1e-30
    eps2: float = 1e-16
    clip_d: float = 1.0
    weight_decay: float = 0.0
    min_dim_factor: int = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CAMELeaf:
    r: Optional[jnp.ndarray]      # second-moment row stats
    c: Optional[jnp.ndarray]
    v: Optional[jnp.ndarray]      # dense fallback
    rs: Optional[jnp.ndarray]     # instability row stats
    cs: Optional[jnp.ndarray]
    m1: jnp.ndarray               # first moment (required)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CAMEState:
    step: jnp.ndarray
    leaves: tuple


def _should_factor(shape, min_dim):
    return len(shape) >= 2 and min(shape[-2], shape[-1]) >= min_dim


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def _factored_vhat(r, c):
    denom = jnp.mean(r, axis=-1, keepdims=True)[..., None]
    return (r[..., :, None] * c[..., None, :]) / (denom + 1e-30)


def _came_state_spec(state: CAMEState, param_specs):
    flat_specs = jax.tree.leaves(param_specs,
                                 is_leaf=lambda x: isinstance(x, P))
    leaves = []
    for pspec, leaf in zip(flat_specs, state.leaves):
        parts = list(pspec)
        if leaf.r is not None:
            bd, a, b = parts[:-2], parts[-2], parts[-1]
            rs, cs = P(*bd, a), P(*bd, b)
            leaves.append(CAMELeaf(r=rs, c=cs, v=None, rs=rs, cs=cs,
                                   m1=pspec))
        else:
            leaves.append(CAMELeaf(r=None, c=None, v=pspec, rs=None, cs=None,
                                   m1=pspec))
    return CAMEState(step=P(), leaves=tuple(leaves))


def scale_by_came(cfg: CAMEConfig) -> GradientTransformation:
    """CAME's preconditioner: factored second moment + RMS clip + first
    moment + factored-instability confidence scaling.  Step size / decay /
    sign live in the chain (see module docstring)."""
    if cfg.b1 <= 0:
        raise ValueError("CAME requires b1 > 0 (confidence guidance depends "
                         "on the first moment; see Adapprox Table 2).")

    def init(params):
        def mk(p):
            m1 = jnp.zeros(p.shape, jnp.float32)
            if _should_factor(p.shape, cfg.min_dim_factor):
                bd = p.shape[:-2]
                zr = jnp.zeros(bd + (p.shape[-2],), jnp.float32)
                zc = jnp.zeros(bd + (p.shape[-1],), jnp.float32)
                return CAMELeaf(r=zr, c=zc, v=None, rs=zr, cs=zc, m1=m1)
            return CAMELeaf(r=None, c=None,
                            v=jnp.zeros(p.shape, jnp.float32),
                            rs=None, cs=None, m1=m1)
        flat, _ = jax.tree.flatten(params)
        return CAMEState(step=jnp.zeros((), jnp.int32),
                         leaves=tuple(mk(p) for p in flat))

    def update(grads, state: CAMEState, params):
        step = state.step + 1
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        del flat_p

        outs, new_leaves = [], []
        for g, leaf in zip(flat_g, state.leaves):
            g32 = g.astype(jnp.float32)
            gsq = jnp.square(g32) + cfg.eps1
            if leaf.r is not None:
                r = cfg.b2 * leaf.r + (1.0 - cfg.b2) * jnp.mean(gsq, axis=-1)
                c = cfg.b2 * leaf.c + (1.0 - cfg.b2) * jnp.mean(gsq, axis=-2)
                u = g32 / (jnp.sqrt(_factored_vhat(r, c)) + 1e-30)
            else:
                r = c = None
                v = cfg.b2 * leaf.v + (1.0 - cfg.b2) * gsq
                u = g32 / (jnp.sqrt(v) + 1e-30)

            u = u / jnp.maximum(1.0, _rms(u) / cfg.clip_d)
            m1 = cfg.b1 * leaf.m1 + (1.0 - cfg.b1) * u

            if leaf.r is not None:
                s = jnp.square(u - m1) + cfg.eps2
                rs = cfg.b3 * leaf.rs + (1.0 - cfg.b3) * jnp.mean(s, axis=-1)
                cs = cfg.b3 * leaf.cs + (1.0 - cfg.b3) * jnp.mean(s, axis=-2)
                out = m1 / (jnp.sqrt(_factored_vhat(rs, cs)) + 1e-30)
                new = CAMELeaf(r=r, c=c, v=None, rs=rs, cs=cs, m1=m1)
            else:
                out = m1
                new = CAMELeaf(r=None, c=None, v=v, rs=None, cs=None, m1=m1)

            outs.append(out)
            new_leaves.append(new)

        return (jax.tree.unflatten(treedef, outs),
                CAMEState(step=step, leaves=tuple(new_leaves)))

    return GradientTransformation(init, update, _came_state_spec)


def came(cfg: CAMEConfig,
         decay_mask: Optional[Callable] = None) -> GradientTransformation:
    """CAME as a documented chain (see module docstring)."""
    return chain(
        scale_by_came(cfg),
        add_decayed_weights(cfg.weight_decay, decay_mask),
        scale_by_schedule(cfg.lr),
        scale(-1.0),
    )
