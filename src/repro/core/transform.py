"""Composable gradient-transformation primitives (optax-style).

The named optimizers in this package are *chains* of small pure stages:

    chain(scale_by_<preconditioner>(cfg),   # grads -> update direction
          add_decayed_weights(wd, mask),    # + wd * W   (decoupled decay)
          scale_by_schedule(schedule),      # * lr_t
          scale(-1.0))                      # descent sign

Each stage owns exactly one concern, so the paper's ablations (guidance
on/off, first-moment on/off, rank modes) and production needs (per-group
decay masks, runtime LR control, mixed dense/factored second moments) are
config changes instead of optimizer forks.  :func:`partition` routes
different parameter groups through different transforms — e.g. dense Adam
on 1-D leaves, Adapprox on matrices, no decay on norms/biases.

All stages follow the :class:`~repro.core.types.GradientTransformation`
protocol, including the optional ``state_sharding_spec`` hook used by
``distributed/sharding.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import (EmptyState, GradientTransformation,
                              resolve_schedule, state_sharding_spec)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CountState:
    """A bare step counter (int32 scalar, counts from 0)."""

    count: jnp.ndarray


def _count_init(params):
    del params
    return CountState(count=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Stateless elementwise stages
# ---------------------------------------------------------------------------

def scale(factor: float) -> GradientTransformation:
    """Multiply every update leaf by a static ``factor`` (e.g. -1.0 for the
    descent sign at the end of a chain)."""

    def init(params):
        del params
        return EmptyState()

    def update(updates, state, params):
        del params
        return jax.tree.map(lambda u: u * factor, updates), state

    return GradientTransformation(init, update)


def resolve_decay_mask(mask):
    """Normalise a decay-mask spec: None / callable / bool pytree pass
    through; the string forms ``"all"`` (decay everything) and ``"no_1d"``
    (exempt 1-D leaves) resolve to their canonical masks."""
    if isinstance(mask, str):
        if mask == "all":
            return None
        if mask == "no_1d":
            return mask_nd(2)
        raise ValueError(f"unknown decay_mask {mask!r} "
                         f"(expected 'all', 'no_1d', a callable, or a "
                         f"bool pytree)")
    return mask


def add_decayed_weights(weight_decay: float,
                        mask: Optional[Callable] = None
                        ) -> GradientTransformation:
    """Decoupled weight decay: ``u <- u + wd * W`` (the chain's trailing
    ``scale_by_schedule`` and ``scale(-1)`` turn this into AdamW-style
    ``-lr * wd * W``).

    ``mask``: optional ``params -> pytree of bool`` (or a bool pytree, or
    the string ``"all"`` / ``"no_1d"``) selecting which leaves decay.  The
    canonical production mask excludes 1-D leaves (norm scales, biases) —
    see :func:`mask_nd`.
    """
    mask = resolve_decay_mask(mask)

    def init(params):
        del params
        return EmptyState()

    def update(updates, state, params):
        if weight_decay == 0.0:
            return updates, state
        if mask is None:
            return jax.tree.map(
                lambda u, w: u + weight_decay * w.astype(jnp.float32),
                updates, params), state
        m = mask(params) if callable(mask) else mask
        return jax.tree.map(
            lambda u, w, keep:
                u + weight_decay * w.astype(jnp.float32) if keep else u,
            updates, params, m), state

    return GradientTransformation(init, update)


def mask_nd(min_ndim: int = 2) -> Callable:
    """Decay-mask factory: keep only leaves with ``ndim >= min_ndim``
    (default: exclude biases / norm scales / scalars from weight decay)."""
    return lambda params: jax.tree.map(lambda p: p.ndim >= min_ndim, params)


def clip_update_rms(d: float) -> GradientTransformation:
    """Per-leaf RMS clipping ``u <- u / max(1, RMS(u)/d)`` (Shazeer & Stern
    update clipping).  The factored preconditioners apply this *per 2-D
    matrix inside the vmap* (paper semantics); this standalone stage is the
    per-leaf variant for custom chains."""

    def init(params):
        del params
        return EmptyState()

    def update(updates, state, params):
        del params

        def clip(u):
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            return u / jnp.maximum(1.0, rms / d)

        return jax.tree.map(clip, updates), state

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# Schedule stages
# ---------------------------------------------------------------------------

def scale_by_schedule(schedule: "float | Callable",
                      lr_scale: float = 1.0) -> GradientTransformation:
    """Multiply updates by ``schedule(t)`` with ``t`` counting from 1 (the
    paper's convention; every seed optimizer evaluated its LR at
    ``state.step + 1``).

    ``lr_scale`` is a static per-group multiplier on top of the shared
    schedule — the "labeled schedule" used inside :func:`partition`
    chains, where every group follows the same warmup/decay shape but at
    a scaled peak (``OptimizerConfig.groups[label].lr_scale``).  The
    default 1.0 compiles to the identical HLO as the unscaled stage, so
    existing chains stay bit-exact.
    """
    sched = resolve_schedule(schedule)

    def update(updates, state, params):
        del params
        count = state.count + 1
        lr = sched(count)
        if lr_scale != 1.0:
            lr = lr * lr_scale
        return (jax.tree.map(lambda u: u * lr, updates),
                CountState(count=count))

    return GradientTransformation(_count_init, update)


def scale_by_relative_step(eps2: float = 1e-3,
                           lr_scale: float = 1.0) -> GradientTransformation:
    """Adafactor's relative step size: per-leaf
    ``alpha_t = max(eps2, RMS(W)) * min(1e-2, 1/sqrt(t))`` — replaces
    :func:`scale_by_schedule` in the adafactor chain when
    ``relative_step=True``.  ``lr_scale`` plays the same per-group
    multiplier role as in :func:`scale_by_schedule` (1.0 is bit-exact
    with the unscaled stage)."""

    def update(updates, state, params):
        count = state.count + 1
        t = count.astype(jnp.float32)
        rho = jnp.minimum(1e-2, 1.0 / jnp.sqrt(t))
        if lr_scale != 1.0:
            rho = rho * lr_scale

        def one(u, w):
            w32 = w.astype(jnp.float32)
            rms = jnp.sqrt(jnp.mean(jnp.square(w32)) + 1e-30)
            return u * (jnp.maximum(eps2, rms) * rho)

        return jax.tree.map(one, updates, params), CountState(count=count)

    return GradientTransformation(_count_init, update)


# ---------------------------------------------------------------------------
# Parameter-group partitioning
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PartitionState:
    """State of :func:`partition`.

    ``inner``: dict ``{label: sub_state}`` (a plain pytree: jits,
    checkpoints and shards like any optimizer state).
    ``labels``: flat per-param-leaf label tuple, stored as *static* pytree
    metadata — it survives ``jit`` / ``eval_shape``, which is what lets the
    ``state_sharding_spec`` hook recover ownership without re-running the
    labeler on params it does not have.
    """

    inner: dict = dataclasses.field(metadata=dict(static=False))
    labels: tuple = dataclasses.field(metadata=dict(static=True))


def _flat_labels(labeler, params, treedef):
    labels = labeler(params) if callable(labeler) else labeler
    return tuple(treedef.flatten_up_to(labels))


def _select(tree, flat_labels, label, treedef):
    """Copy of ``tree`` with leaves not carrying ``label`` replaced by None
    (None is an empty pytree, so sub-transforms skip them naturally)."""
    flat = treedef.flatten_up_to(tree)
    return jax.tree.unflatten(
        treedef, [x if l == label else None
                  for x, l in zip(flat, flat_labels)])


def partition(labeler,
              transforms: "dict[str, GradientTransformation]"
              ) -> GradientTransformation:
    """Route parameter groups through per-label transforms.

    ``labeler``: a pytree of string labels mirroring the params, or a
    callable ``params -> label pytree`` (it may only inspect leaf
    shapes/dtypes — it runs under tracing).  Every label it produces must
    be a key of ``transforms``.

    Each sub-transform sees the full param structure with non-owned leaves
    replaced by ``None`` (an empty pytree), so its state only holds its own
    leaves; updates are merged back by ownership.  Example — dense Adam on
    small/1-D leaves, Adapprox on matrices::

        opt = partition(
            lambda params: jax.tree.map(
                lambda p: "factored" if p.ndim >= 2 else "dense", params),
            {"factored": adapprox(acfg), "dense": adamw(AdamWConfig())})
    """
    items = tuple(sorted(transforms.items()))

    def _check(flat_labels):
        known = {label for label, _ in items}
        seen = set(flat_labels)
        if not seen <= known:
            raise ValueError(f"labeler produced labels {sorted(seen - known)} "
                             f"with no transform; known: {sorted(known)}")

    def init(params):
        treedef = jax.tree.structure(params)
        labels = _flat_labels(labeler, params, treedef)
        _check(labels)
        inner = {label: t.init(_select(params, labels, label, treedef))
                 for label, t in items}
        return PartitionState(inner=inner, labels=labels)

    def update(grads, state, params):
        flat_p, treedef = jax.tree.flatten(params)
        labels = state.labels      # ownership fixed at init; never re-label
        merged = [None] * len(flat_p)
        inner = {}
        for label, t in items:
            sub_g = _select(grads, labels, label, treedef)
            sub_p = _select(params, labels, label, treedef)
            upd, inner[label] = t.update(sub_g, state.inner[label], sub_p)
            for i, u in enumerate(treedef.flatten_up_to(upd)):
                if labels[i] == label:
                    merged[i] = u
        return (jax.tree.unflatten(treedef, merged),
                PartitionState(inner=inner, labels=labels))

    def spec(state, param_specs):
        is_spec = lambda x: isinstance(x, P)
        treedef = jax.tree.structure(param_specs, is_leaf=is_spec)
        flat_specs = treedef.flatten_up_to(param_specs)
        inner = {}
        for label, t in items:
            sub_specs = jax.tree.unflatten(
                treedef, [s if l == label else None
                          for s, l in zip(flat_specs, state.labels)])
            inner[label] = state_sharding_spec(t, state.inner[label],
                                               sub_specs)
        return PartitionState(inner=inner, labels=state.labels)

    return GradientTransformation(init, update, spec)
