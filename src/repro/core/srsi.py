"""Streamlined Randomized Subspace Iteration (S-RSI) — Algorithm 1 of Adapprox.

Computes feature matrices ``Q (m, k)``, ``U (n, k)`` such that ``A ~= Q @ U.T``
for a PSD-entry (elementwise non-negative) target ``A`` — in our use the Adam
second-moment matrix ``V_t``.

TPU adaptation notes (see DESIGN.md §Hardware-adaptation):

* The QR factorisation in the subspace iteration is replaced by CholeskyQR2,
  which is pure matmul + small Cholesky — MXU friendly and, crucially,
  *distribution friendly*: when the row dimension ``m`` is sharded across a
  mesh axis, ``Y.T @ Y`` reduces to a local matmul plus one small ``(r, r)``
  all-reduce that GSPMD inserts automatically.  Householder QR would gather
  the full tall matrix to one device.

* The second moment never has to be materialised: ``V_t = b2 * Q U^T +
  (1 - b2) * G**2`` is available as an *implicit operator* (matvec /
  rmatvec), so the subspace iteration runs in
  ``O((m + n) * (k + p))`` memory instead of ``O(m n)``.  The explicit-``A``
  path is kept both as the paper-faithful baseline and as the oracle for
  kernel tests.

All functions are shape-polymorphic over leading batch dims via ``vmap``
(used for scan-stacked layer parameters ``(L, m, n)`` and MoE expert stacks
``(L, E, m, n)``).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Orthonormalisation: CholeskyQR2
# ---------------------------------------------------------------------------

def _tri_inv_lower(l: jnp.ndarray) -> jnp.ndarray:
    """Inverse of a small (r, r) lower-triangular matrix via row-wise
    forward substitution:  X[i] = (e_i - L[i] @ X) / L[i, i].

    Deliberately NOT ``triangular_solve``: LAPACK's trsm takes a different
    code path under a leading batch dimension, so vmapped and unbatched
    results differ in the last ulp — which would make bucketed (stacked +
    vmapped) leaf execution bitwise-diverge from the per-leaf loop.  The
    substitution loop uses only matmul / dynamic-slice / where, whose
    batching rules are bit-stable, at the same O(r^3) flop count.  r is the
    sketch width (~k_max + p ≲ 150), so the r-step loop is negligible next
    to the (m, n, r) sketch matmuls.
    """
    r = l.shape[0]
    eye = jnp.eye(r, dtype=l.dtype)

    def body(i, x):
        row = (eye[i] - l[i] @ x) / l[i, i]
        return jax.lax.dynamic_update_slice(x, row[None, :], (i, 0))

    return jax.lax.fori_loop(0, r, body, jnp.zeros_like(l))


def _cholesky_qr(y: jnp.ndarray, shift_rel: float = 1e-5) -> jnp.ndarray:
    """One shifted CholeskyQR pass: returns Q with (approximately)
    orthonormal columns.

    ``y``: (m, r).  Gram matrix is (r, r); under a sharded ``m`` this is a
    local matmul + one small all-reduce.  Two robustness devices (needed
    because power iteration drives the sketch columns towards the dominant
    singular directions, so the Gram matrix can be numerically singular in
    fp32):

      * column scaling — removes the huge dynamic range between columns;
      * a trace-relative diagonal shift (shifted-CholeskyQR, Fukaya et al.)
        — guarantees the Cholesky succeeds and the triangular solve has a
        bounded diagonal.  The shift perturbs orthonormality by O(shift),
        which the following passes remove.
    """
    y32 = y.astype(jnp.float32)
    col = jnp.sqrt(jnp.sum(jnp.square(y32), axis=0) + 1e-30)
    # Relative clamp: once power iteration collapses the sketch onto a
    # low-dim subspace, orthogonal-complement columns have norms ~eps *
    # max-col.  Normalising those to unit length amplifies garbage (and
    # XLA's fused loop bodies turn the 0/0 into NaN — observed on CPU with
    # fori_loop but not unrolled!).  Clamped columns stay ~zero; the
    # diagonal shift keeps the Gram factorisable.
    col = jnp.maximum(col, 1e-6 * jnp.max(col) + 1e-30)
    ys = y32 / col[None, :]
    gram = ys.T @ ys  # (r, r), diag ~= 1
    r = gram.shape[0]
    gram = gram + (shift_rel + 1e-30) * jnp.eye(r, dtype=jnp.float32)
    chol = jnp.linalg.cholesky(gram)
    # Q = Y_s R^{-1} = Y_s L^{-T}  (R = chol.T upper triangular).  The
    # explicit small inverse (not trsm) keeps vmapped == unbatched bitwise
    # — see _tri_inv_lower.
    q = ys @ _tri_inv_lower(chol).T
    # Degenerate sketch directions (collapsed by power iteration) can turn
    # into NaN under XLA's fused loop bodies even though the unrolled math
    # is finite.  Zeroing them is semantically "drop that sketch column":
    # it carries ~no energy, and the Gram shift keeps later passes PD.
    return jnp.where(jnp.isfinite(q), q, 0.0)


def cholesky_qr2(y: jnp.ndarray) -> jnp.ndarray:
    """Shifted CholeskyQR3 — three matmul+small-Cholesky passes give
    near-Householder orthonormality even for the ill-conditioned sketches
    produced by l = 5 power iterations.  The first-pass shift tames the
    condition number; later passes stay at ~1e-6, the fp32 Gram rounding
    floor: an orthonormal Q's computed Gram can have eigmin ~ -eps*r
    (observed -1.1e-8 at r = 10), so any smaller shift risks a non-PD
    Cholesky.  Final orthonormality error ~1e-6 — ample for subspace
    iteration."""
    return _cholesky_qr(_cholesky_qr(_cholesky_qr(y, 1e-5), 1e-6), 1e-6)


# ---------------------------------------------------------------------------
# Implicit second-moment operator
# ---------------------------------------------------------------------------

class ImplicitV(NamedTuple):
    """``V = b2 * (Q @ U.T) + (1 - b2) * G * G`` without materialisation.

    ``col_mask``: (r,) float mask selecting the active columns of the stored
    factors (adaptive-rank support; inactive columns are zeros anyway in
    steady state but the mask makes truncation explicit).
    """

    q: jnp.ndarray        # (m, r) float32
    u: jnp.ndarray        # (n, r) float32
    g: jnp.ndarray        # (m, n) grad (any float dtype)
    b2: jnp.ndarray       # scalar
    col_mask: jnp.ndarray  # (r,) float32

    @property
    def shape(self):
        return self.g.shape

    def mv(self, x: jnp.ndarray) -> jnp.ndarray:
        """V @ x for x: (n, s)."""
        g32 = self.g.astype(jnp.float32)
        qm = self.q * self.col_mask[None, :]
        low = qm @ (self.u.T @ x)
        dense = (g32 * g32) @ x
        return self.b2 * low + (1.0 - self.b2) * dense

    def rmv(self, y: jnp.ndarray) -> jnp.ndarray:
        """V.T @ y for y: (m, s).  V is not symmetric in general."""
        g32 = self.g.astype(jnp.float32)
        um = self.u * self.col_mask[None, :]
        low = um @ (self.q.T @ y)
        dense = (g32 * g32).T @ y
        return self.b2 * low + (1.0 - self.b2) * dense

    def materialize(self) -> jnp.ndarray:
        """Clamp the *low-rank term* at zero before adding the fresh G^2.

        V's entries are non-negative but Q U^T can dip negative where the
        approximation is poor.  Clamping the low-rank term (rather than the
        sum) preserves the stability floor V >= (1 - b2) * G^2, which bounds
        per-entry update amplification by 1/sqrt(1 - b2) — without it a
        negative Q U^T could zero the denominator entirely.
        """
        g32 = self.g.astype(jnp.float32)
        qm = self.q * self.col_mask[None, :]
        return (self.b2 * jnp.maximum(qm @ self.u.T, 0.0)
                + (1.0 - self.b2) * g32 * g32)

    def frob_sq(self, row_tile: int = 512) -> jnp.ndarray:
        """||V||_F^2 — streaming: O(mn) flops but O(row_tile * n) transient
        memory instead of materialising the full (m, n) matrix in HBM.

        The clamp ``max(Q U^T, 0)`` is applied tile-wise: a ``lax.scan`` over
        row blocks of Q (and G) reconstructs one (row_tile, n) slab at a
        time, accumulating ``sum(V_tile**2)`` in fp32.  Zero-padded rows
        contribute exactly 0 (padded Q rows give a zero low-rank slab and
        padded G rows a zero dense slab), so padding is free.
        """
        g32 = self.g.astype(jnp.float32)
        qm = self.q * self.col_mask[None, :]
        m = g32.shape[0]
        if m <= row_tile:
            v = (self.b2 * jnp.maximum(qm @ self.u.T, 0.0)
                 + (1.0 - self.b2) * g32 * g32)
            return jnp.sum(jnp.square(v))
        pad = (-m) % row_tile
        qp = jnp.pad(qm, ((0, pad), (0, 0)))
        gp = jnp.pad(g32, ((0, pad), (0, 0)))
        n_tiles = (m + pad) // row_tile
        qt = qp.reshape(n_tiles, row_tile, qm.shape[1])
        gt = gp.reshape(n_tiles, row_tile, g32.shape[1])

        def body(acc, slab):
            q_blk, g_blk = slab
            v = (self.b2 * jnp.maximum(q_blk @ self.u.T, 0.0)
                 + (1.0 - self.b2) * g_blk * g_blk)
            return acc + jnp.sum(jnp.square(v)), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (qt, gt))
        return total


def make_implicit_v(q, u, g, b2, col_mask=None) -> ImplicitV:
    if col_mask is None:
        col_mask = jnp.ones((q.shape[-1],), jnp.float32)
    return ImplicitV(q.astype(jnp.float32), u.astype(jnp.float32), g,
                     jnp.asarray(b2, jnp.float32), col_mask.astype(jnp.float32))


# ---------------------------------------------------------------------------
# S-RSI proper
# ---------------------------------------------------------------------------

class SRSIResult(NamedTuple):
    q: jnp.ndarray          # (m, r_store)
    u: jnp.ndarray          # (n, r_store)
    # Cumulative captured energy: cum_energy[j] = ||U[:, :j+1]||_F^2 summed
    # over columns; with U = A^T Q and Q orthonormal this equals
    # ||Q[:, :j+1]^T A||_F^2, the energy captured by a rank-(j+1) truncation.
    cum_energy: jnp.ndarray  # (r_store,) float32
    frob_sq: jnp.ndarray     # scalar ||A||_F^2


def _srsi_core(matmul_a: Callable[[jnp.ndarray], jnp.ndarray],
               matmul_at: Callable[[jnp.ndarray], jnp.ndarray],
               frob_sq: jnp.ndarray,
               n: int,
               r_store: int,
               oversample: int,
               n_iter: int,
               key: jax.Array,
               u0: Optional[jnp.ndarray] = None,
               use_warm: Optional[jnp.ndarray] = None) -> SRSIResult:
    """Shared implementation.  ``matmul_a(x: (n, r)) -> (m, r)``,
    ``matmul_at(y: (m, r)) -> (n, r)``.

    Faithful to Algorithm 1: l rounds of  Q <- orth(A U); U <- A^T Q,
    sampling ``r_store + oversample`` columns and truncating to ``r_store``
    at the end (the paper truncates to ``k``; we store ``k_max`` columns in
    adaptive mode and mask down to ``k_t`` — see rank.py).

    Warm start (``u0``): because V_t is a slow EMA (b2 ~ 0.999), the
    previous step's right factor U is already a near-converged subspace
    iterate.  When ``u0: (n, r_store)`` is given, its columns seed the
    sketch instead of fresh Gaussians, so 1–2 power iterations recover the
    accuracy that a cold Gaussian start needs l = 5 for.  Robustness:

      * zero columns of ``u0`` (init state; rank-masked columns after
        adaptive-rank truncation) individually fall back to the Gaussian
        column — they carry no subspace information and would be degenerate
        sketch directions;
      * the ``oversample`` columns are ALWAYS fresh Gaussians, so the
        iteration keeps exploring outside the inherited subspace (this is
        what lets rank growth and slow subspace drift be picked up);
      * ``use_warm`` (traced bool, optional) drops the entire warm seed in
        favour of the Gaussian sketch — the caller's drift guard.

    Scale normalisation: second-moment matrices late in training have
    entries ~(1-b2)*g^2 ~ 1e-8; the implicit power (A A^T)^l A then
    underflows fp32.  The iteration runs on A/s with s = ||A||_F (all
    outputs are scale-equivariant: Q invariant, U and cum_energy rescale).
    """
    scale = jnp.sqrt(frob_sq) + 1e-30
    inv = (1.0 / scale).astype(jnp.float32)
    r_total = r_store + oversample
    u = jax.random.normal(key, (n, r_total), dtype=jnp.float32)
    if u0 is not None:
        r_warm = u0.shape[-1]
        u032 = u0.astype(jnp.float32)
        col_ok = jnp.sum(jnp.square(u032), axis=0) > 0.0
        warm_cols = jnp.where(col_ok[None, :], u032, u[:, :r_warm])
        warm = jnp.concatenate([warm_cols, u[:, r_warm:]], axis=1)
        if use_warm is not None:
            u = jnp.where(use_warm, warm, u)
        else:
            u = warm

    def half_step(u):
        q = matmul_a(u) * inv
        q = cholesky_qr2(q)
        return q, matmul_at(q) * inv

    # The loop count l is a static hyperparameter (paper: l = 5).  The final
    # iterate has U = A^T Q with Q orthonormal, which is exactly the pair the
    # reconstruction Q U^T = Q Q^T A needs.  First iteration runs eagerly so
    # the fori_loop carry has concrete shapes for both factors.
    q, u = half_step(u)
    if n_iter > 1:
        q, u = jax.lax.fori_loop(
            0, n_iter - 1, lambda _, c: half_step(c[1]), (q, u))

    q = q[:, :r_store]
    u = u[:, :r_store] * scale            # back to unscaled units
    col_energy = jnp.sum(jnp.square(u * inv), axis=0)  # scaled (stable)
    cum_energy = jnp.cumsum(col_energy) * frob_sq      # = unscaled energy
    return SRSIResult(q=q, u=u, cum_energy=cum_energy, frob_sq=frob_sq)


def srsi_dense(a: jnp.ndarray, r_store: int, oversample: int, n_iter: int,
               key: jax.Array,
               u0: Optional[jnp.ndarray] = None,
               use_warm: Optional[jnp.ndarray] = None) -> SRSIResult:
    """Paper-faithful S-RSI on an explicit target matrix ``a: (m, n)``.
    ``u0``/``use_warm``: optional warm-start seed (see ``_srsi_core``)."""
    a32 = a.astype(jnp.float32)
    return _srsi_core(lambda x: a32 @ x,
                      lambda y: a32.T @ y,
                      jnp.sum(jnp.square(a32)),
                      a.shape[1], r_store, oversample, n_iter, key,
                      u0=u0, use_warm=use_warm)


def srsi_implicit(v: ImplicitV, r_store: int, oversample: int, n_iter: int,
                  key: jax.Array,
                  frob_sq: Optional[jnp.ndarray] = None,
                  u0: Optional[jnp.ndarray] = None,
                  use_warm: Optional[jnp.ndarray] = None) -> SRSIResult:
    """S-RSI on the implicit operator — never materialises ``V`` (beyond-paper
    memory optimisation; bitwise-different but statistically identical).
    ``u0``/``use_warm``: optional warm-start seed (see ``_srsi_core``)."""
    if frob_sq is None:
        frob_sq = v.frob_sq()
    return _srsi_core(v.mv, v.rmv, frob_sq, v.shape[1], r_store, oversample,
                      n_iter, key, u0=u0, use_warm=use_warm)


def reconstruct(q: jnp.ndarray, u: jnp.ndarray,
                col_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """``A_k = Q diag(mask) U^T`` clamped at zero (V entries are >= 0; the
    low-rank approximation can dip slightly negative)."""
    q32 = q.astype(jnp.float32)
    if col_mask is not None:
        q32 = q32 * col_mask[None, :]
    return jnp.maximum(q32 @ u.astype(jnp.float32).T, 0.0)


def approx_error_rate(res: SRSIResult, k: jnp.ndarray) -> jnp.ndarray:
    """xi(k) = ||A - Q_k U_k^T||_F / ||A||_F  via the projection identity

        ||A - Q_k Q_k^T A||_F^2 = ||A||_F^2 - ||Q_k^T A||_F^2,

    so no residual materialisation is needed.  ``k`` may be traced (int32).

    Accuracy note: the identity assumes exactly orthonormal Q_k.
    CholeskyQR3 leaves ~1e-6 relative orthonormality error in fp32, which
    gives xi an absolute floor of ~sqrt(1e-6) = 1e-3 — irrelevant for rank
    selection (xi_thresh ~1e-2) but visible when the true residual is
    smaller than the floor.
    """
    r = res.cum_energy.shape[0]
    idx = jnp.clip(k - 1, 0, r - 1)
    captured = jnp.where(k > 0, res.cum_energy[idx], 0.0)
    resid = jnp.maximum(res.frob_sq - captured, 0.0)
    return jnp.sqrt(resid / (res.frob_sq + 1e-30))


def col_mask(r_store: int, k: jnp.ndarray) -> jnp.ndarray:
    """(r_store,) float32 mask with the first ``k`` entries = 1."""
    return (jnp.arange(r_store) < k).astype(jnp.float32)


# Batched variants (leading dims mapped).  ``keys`` must carry the same
# leading dims so every matrix in a stack gets an independent sketch.

def srsi_dense_batched(a, r_store, oversample, n_iter, keys):
    fn = functools.partial(srsi_dense, r_store=r_store, oversample=oversample,
                           n_iter=n_iter)
    for _ in range(a.ndim - 2):
        fn = jax.vmap(fn)
    return fn(a, key=keys)
