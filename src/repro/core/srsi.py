"""Streamlined Randomized Subspace Iteration (S-RSI) — Algorithm 1 of Adapprox.

Computes feature matrices ``Q (m, k)``, ``U (n, k)`` such that ``A ~= Q @ U.T``
for a PSD-entry (elementwise non-negative) target ``A`` — in our use the Adam
second-moment matrix ``V_t``.

TPU adaptation notes (see DESIGN.md §Hardware-adaptation):

* The QR factorisation in the subspace iteration is replaced by CholeskyQR2,
  which is pure matmul + small Cholesky — MXU friendly and, crucially,
  *distribution friendly*: when the row dimension ``m`` is sharded across a
  mesh axis, ``Y.T @ Y`` reduces to a local matmul plus one small ``(r, r)``
  all-reduce that GSPMD inserts automatically.  Householder QR would gather
  the full tall matrix to one device.

* The second moment never has to be materialised: ``V_t = b2 * Q U^T +
  (1 - b2) * G**2`` is available as an *implicit operator* (matvec /
  rmatvec), so the subspace iteration runs in
  ``O((m + n) * (k + p))`` memory instead of ``O(m n)``.  The explicit-``A``
  path is kept both as the paper-faithful baseline and as the oracle for
  kernel tests.

All functions are shape-polymorphic over leading batch dims via ``vmap``
(used for scan-stacked layer parameters ``(L, m, n)`` and MoE expert stacks
``(L, E, m, n)``).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Orthonormalisation: CholeskyQR2
# ---------------------------------------------------------------------------

def _cholesky_qr(y: jnp.ndarray, shift_rel: float = 1e-5) -> jnp.ndarray:
    """One shifted CholeskyQR pass: returns Q with (approximately)
    orthonormal columns.

    ``y``: (m, r).  Gram matrix is (r, r); under a sharded ``m`` this is a
    local matmul + one small all-reduce.  Two robustness devices (needed
    because power iteration drives the sketch columns towards the dominant
    singular directions, so the Gram matrix can be numerically singular in
    fp32):

      * column scaling — removes the huge dynamic range between columns;
      * a trace-relative diagonal shift (shifted-CholeskyQR, Fukaya et al.)
        — guarantees the Cholesky succeeds and the triangular solve has a
        bounded diagonal.  The shift perturbs orthonormality by O(shift),
        which the following passes remove.
    """
    y32 = y.astype(jnp.float32)
    col = jnp.sqrt(jnp.sum(jnp.square(y32), axis=0) + 1e-30)
    # Relative clamp: once power iteration collapses the sketch onto a
    # low-dim subspace, orthogonal-complement columns have norms ~eps *
    # max-col.  Normalising those to unit length amplifies garbage (and
    # XLA's fused loop bodies turn the 0/0 into NaN — observed on CPU with
    # fori_loop but not unrolled!).  Clamped columns stay ~zero; the
    # diagonal shift keeps the Gram factorisable.
    col = jnp.maximum(col, 1e-6 * jnp.max(col) + 1e-30)
    ys = y32 / col[None, :]
    gram = ys.T @ ys  # (r, r), diag ~= 1
    r = gram.shape[0]
    gram = gram + (shift_rel + 1e-30) * jnp.eye(r, dtype=jnp.float32)
    chol = jnp.linalg.cholesky(gram)
    # Q = Y_s R^{-1}  (R = chol.T upper triangular).
    q = jax.scipy.linalg.solve_triangular(chol, ys.T, lower=True).T
    # Degenerate sketch directions (collapsed by power iteration) can turn
    # into NaN under XLA's fused loop bodies even though the unrolled math
    # is finite.  Zeroing them is semantically "drop that sketch column":
    # it carries ~no energy, and the Gram shift keeps later passes PD.
    return jnp.where(jnp.isfinite(q), q, 0.0)


def cholesky_qr2(y: jnp.ndarray) -> jnp.ndarray:
    """Shifted CholeskyQR3 — three matmul+small-Cholesky passes give
    near-Householder orthonormality even for the ill-conditioned sketches
    produced by l = 5 power iterations.  The first-pass shift tames the
    condition number; later passes stay at ~1e-6, the fp32 Gram rounding
    floor: an orthonormal Q's computed Gram can have eigmin ~ -eps*r
    (observed -1.1e-8 at r = 10), so any smaller shift risks a non-PD
    Cholesky.  Final orthonormality error ~1e-6 — ample for subspace
    iteration."""
    return _cholesky_qr(_cholesky_qr(_cholesky_qr(y, 1e-5), 1e-6), 1e-6)


# ---------------------------------------------------------------------------
# Implicit second-moment operator
# ---------------------------------------------------------------------------

class ImplicitV(NamedTuple):
    """``V = b2 * (Q @ U.T) + (1 - b2) * G * G`` without materialisation.

    ``col_mask``: (r,) float mask selecting the active columns of the stored
    factors (adaptive-rank support; inactive columns are zeros anyway in
    steady state but the mask makes truncation explicit).
    """

    q: jnp.ndarray        # (m, r) float32
    u: jnp.ndarray        # (n, r) float32
    g: jnp.ndarray        # (m, n) grad (any float dtype)
    b2: jnp.ndarray       # scalar
    col_mask: jnp.ndarray  # (r,) float32

    @property
    def shape(self):
        return self.g.shape

    def mv(self, x: jnp.ndarray) -> jnp.ndarray:
        """V @ x for x: (n, s)."""
        g32 = self.g.astype(jnp.float32)
        qm = self.q * self.col_mask[None, :]
        low = qm @ (self.u.T @ x)
        dense = (g32 * g32) @ x
        return self.b2 * low + (1.0 - self.b2) * dense

    def rmv(self, y: jnp.ndarray) -> jnp.ndarray:
        """V.T @ y for y: (m, s).  V is not symmetric in general."""
        g32 = self.g.astype(jnp.float32)
        um = self.u * self.col_mask[None, :]
        low = um @ (self.q.T @ y)
        dense = (g32 * g32).T @ y
        return self.b2 * low + (1.0 - self.b2) * dense

    def materialize(self) -> jnp.ndarray:
        """Clamp the *low-rank term* at zero before adding the fresh G^2.

        V's entries are non-negative but Q U^T can dip negative where the
        approximation is poor.  Clamping the low-rank term (rather than the
        sum) preserves the stability floor V >= (1 - b2) * G^2, which bounds
        per-entry update amplification by 1/sqrt(1 - b2) — without it a
        negative Q U^T could zero the denominator entirely.
        """
        g32 = self.g.astype(jnp.float32)
        qm = self.q * self.col_mask[None, :]
        return (self.b2 * jnp.maximum(qm @ self.u.T, 0.0)
                + (1.0 - self.b2) * g32 * g32)

    def frob_sq(self) -> jnp.ndarray:
        """||V||_F^2 — streaming, O(mn) flops, O(1) extra memory.

        XLA fuses the reconstruct + square + reduce; the Pallas kernel path
        (kernels/lowrank_update.py) does the same tiling explicitly.
        """
        return jnp.sum(jnp.square(self.materialize()))


def make_implicit_v(q, u, g, b2, col_mask=None) -> ImplicitV:
    if col_mask is None:
        col_mask = jnp.ones((q.shape[-1],), jnp.float32)
    return ImplicitV(q.astype(jnp.float32), u.astype(jnp.float32), g,
                     jnp.asarray(b2, jnp.float32), col_mask.astype(jnp.float32))


# ---------------------------------------------------------------------------
# S-RSI proper
# ---------------------------------------------------------------------------

class SRSIResult(NamedTuple):
    q: jnp.ndarray          # (m, r_store)
    u: jnp.ndarray          # (n, r_store)
    # Cumulative captured energy: cum_energy[j] = ||U[:, :j+1]||_F^2 summed
    # over columns; with U = A^T Q and Q orthonormal this equals
    # ||Q[:, :j+1]^T A||_F^2, the energy captured by a rank-(j+1) truncation.
    cum_energy: jnp.ndarray  # (r_store,) float32
    frob_sq: jnp.ndarray     # scalar ||A||_F^2


def _srsi_core(matmul_a: Callable[[jnp.ndarray], jnp.ndarray],
               matmul_at: Callable[[jnp.ndarray], jnp.ndarray],
               frob_sq: jnp.ndarray,
               n: int,
               r_store: int,
               oversample: int,
               n_iter: int,
               key: jax.Array) -> SRSIResult:
    """Shared implementation.  ``matmul_a(x: (n, r)) -> (m, r)``,
    ``matmul_at(y: (m, r)) -> (n, r)``.

    Faithful to Algorithm 1: l rounds of  Q <- orth(A U); U <- A^T Q,
    sampling ``r_store + oversample`` columns and truncating to ``r_store``
    at the end (the paper truncates to ``k``; we store ``k_max`` columns in
    adaptive mode and mask down to ``k_t`` — see rank.py).

    Scale normalisation: second-moment matrices late in training have
    entries ~(1-b2)*g^2 ~ 1e-8; the implicit power (A A^T)^l A then
    underflows fp32.  The iteration runs on A/s with s = ||A||_F (all
    outputs are scale-equivariant: Q invariant, U and cum_energy rescale).
    """
    scale = jnp.sqrt(frob_sq) + 1e-30
    inv = (1.0 / scale).astype(jnp.float32)
    r_total = r_store + oversample
    u = jax.random.normal(key, (n, r_total), dtype=jnp.float32)

    def half_step(u):
        q = matmul_a(u) * inv
        q = cholesky_qr2(q)
        return q, matmul_at(q) * inv

    # The loop count l is a static hyperparameter (paper: l = 5).  The final
    # iterate has U = A^T Q with Q orthonormal, which is exactly the pair the
    # reconstruction Q U^T = Q Q^T A needs.  First iteration runs eagerly so
    # the fori_loop carry has concrete shapes for both factors.
    q, u = half_step(u)
    if n_iter > 1:
        q, u = jax.lax.fori_loop(
            0, n_iter - 1, lambda _, c: half_step(c[1]), (q, u))

    q = q[:, :r_store]
    u = u[:, :r_store] * scale            # back to unscaled units
    col_energy = jnp.sum(jnp.square(u * inv), axis=0)  # scaled (stable)
    cum_energy = jnp.cumsum(col_energy) * frob_sq      # = unscaled energy
    return SRSIResult(q=q, u=u, cum_energy=cum_energy, frob_sq=frob_sq)


def srsi_dense(a: jnp.ndarray, r_store: int, oversample: int, n_iter: int,
               key: jax.Array) -> SRSIResult:
    """Paper-faithful S-RSI on an explicit target matrix ``a: (m, n)``."""
    a32 = a.astype(jnp.float32)
    return _srsi_core(lambda x: a32 @ x,
                      lambda y: a32.T @ y,
                      jnp.sum(jnp.square(a32)),
                      a.shape[1], r_store, oversample, n_iter, key)


def srsi_implicit(v: ImplicitV, r_store: int, oversample: int, n_iter: int,
                  key: jax.Array,
                  frob_sq: Optional[jnp.ndarray] = None) -> SRSIResult:
    """S-RSI on the implicit operator — never materialises ``V`` (beyond-paper
    memory optimisation; bitwise-different but statistically identical)."""
    if frob_sq is None:
        frob_sq = v.frob_sq()
    return _srsi_core(v.mv, v.rmv, frob_sq, v.shape[1], r_store, oversample,
                      n_iter, key)


def reconstruct(q: jnp.ndarray, u: jnp.ndarray,
                col_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """``A_k = Q diag(mask) U^T`` clamped at zero (V entries are >= 0; the
    low-rank approximation can dip slightly negative)."""
    q32 = q.astype(jnp.float32)
    if col_mask is not None:
        q32 = q32 * col_mask[None, :]
    return jnp.maximum(q32 @ u.astype(jnp.float32).T, 0.0)


def approx_error_rate(res: SRSIResult, k: jnp.ndarray) -> jnp.ndarray:
    """xi(k) = ||A - Q_k U_k^T||_F / ||A||_F  via the projection identity

        ||A - Q_k Q_k^T A||_F^2 = ||A||_F^2 - ||Q_k^T A||_F^2,

    so no residual materialisation is needed.  ``k`` may be traced (int32).

    Accuracy note: the identity assumes exactly orthonormal Q_k.
    CholeskyQR3 leaves ~1e-6 relative orthonormality error in fp32, which
    gives xi an absolute floor of ~sqrt(1e-6) = 1e-3 — irrelevant for rank
    selection (xi_thresh ~1e-2) but visible when the true residual is
    smaller than the floor.
    """
    r = res.cum_energy.shape[0]
    idx = jnp.clip(k - 1, 0, r - 1)
    captured = jnp.where(k > 0, res.cum_energy[idx], 0.0)
    resid = jnp.maximum(res.frob_sq - captured, 0.0)
    return jnp.sqrt(resid / (res.frob_sq + 1e-30))


def col_mask(r_store: int, k: jnp.ndarray) -> jnp.ndarray:
    """(r_store,) float32 mask with the first ``k`` entries = 1."""
    return (jnp.arange(r_store) < k).astype(jnp.float32)


# Batched variants (leading dims mapped).  ``keys`` must carry the same
# leading dims so every matrix in a stack gets an independent sketch.

def srsi_dense_batched(a, r_store, oversample, n_iter, keys):
    fn = functools.partial(srsi_dense, r_store=r_store, oversample=oversample,
                           n_iter=n_iter)
    for _ in range(a.ndim - 2):
        fn = jax.vmap(fn)
    return fn(a, key=keys)
