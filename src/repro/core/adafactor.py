"""Adafactor baseline (Shazeer & Stern, 2018).

Faithful features: rank-1 row/column factored second moment for matrices
(I-divergence-optimal nonnegative factorisation: ``V ~ R C / sum(R)``),
optional first moment, RMS update clipping, optional beta2 schedule
``b2_t = 1 - t^{-0.8}``, decoupled weight decay, optional relative step
sizes.  The paper's GPT-2 comparison drives all optimizers with the same
external LR schedule, so ``relative_step`` defaults to False here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.types import GradientTransformation, resolve_schedule


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: "float | Callable" = 1e-3
    b1: float = 0.0                  # Adafactor default: first moment off
    b2: float = 0.999
    b2_schedule: bool = True         # b2_t = 1 - t^decay_exponent
    decay_exponent: float = -0.8
    eps1: float = 1e-30              # regulariser inside the factored stats
    eps2: float = 1e-3               # relative-step floor (only if relative)
    clip_d: float = 1.0
    weight_decay: float = 0.0
    relative_step: bool = False
    min_dim_factor: int = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdafactorLeaf:
    r: Optional[jnp.ndarray]     # (*batch, m) row stats   | None if dense
    c: Optional[jnp.ndarray]     # (*batch, n) col stats   | None if dense
    v: Optional[jnp.ndarray]     # dense fallback          | None if factored
    m1: Optional[jnp.ndarray]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdafactorState:
    step: jnp.ndarray
    leaves: tuple


def _should_factor(shape, min_dim):
    return len(shape) >= 2 and min(shape[-2], shape[-1]) >= min_dim


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def adafactor(cfg: AdafactorConfig) -> GradientTransformation:
    schedule = resolve_schedule(cfg.lr)

    def init(params):
        def mk(p):
            m1 = jnp.zeros(p.shape, jnp.float32) if cfg.b1 > 0 else None
            if _should_factor(p.shape, cfg.min_dim_factor):
                bd = p.shape[:-2]
                return AdafactorLeaf(
                    r=jnp.zeros(bd + (p.shape[-2],), jnp.float32),
                    c=jnp.zeros(bd + (p.shape[-1],), jnp.float32),
                    v=None, m1=m1)
            return AdafactorLeaf(r=None, c=None,
                                 v=jnp.zeros(p.shape, jnp.float32), m1=m1)
        flat, _ = jax.tree.flatten(params)
        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              leaves=tuple(mk(p) for p in flat))

    def update(grads, state: AdafactorState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        b2t = (1.0 - t ** cfg.decay_exponent) if cfg.b2_schedule else cfg.b2

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)

        deltas, new_leaves = [], []
        for g, leaf, w in zip(flat_g, state.leaves, flat_p):
            g32 = g.astype(jnp.float32)
            gsq = jnp.square(g32) + cfg.eps1
            if leaf.r is not None:
                r = b2t * leaf.r + (1.0 - b2t) * jnp.mean(gsq, axis=-1)
                c = b2t * leaf.c + (1.0 - b2t) * jnp.mean(gsq, axis=-2)
                # V-hat = outer(r, c) / mean(r); u = g / sqrt(vhat)
                denom = jnp.mean(r, axis=-1, keepdims=True)[..., None]
                vhat = (r[..., :, None] * c[..., None, :]) / (denom + 1e-30)
                u = g32 / (jnp.sqrt(vhat) + 1e-30)
                new = AdafactorLeaf(r=r, c=c, v=None, m1=leaf.m1)
            else:
                v = b2t * leaf.v + (1.0 - b2t) * gsq
                u = g32 / (jnp.sqrt(v) + 1e-30)
                new = AdafactorLeaf(r=None, c=None, v=v, m1=leaf.m1)

            u = u / jnp.maximum(1.0, _rms(u) / cfg.clip_d)

            if cfg.relative_step:
                rho = jnp.minimum(1e-2, 1.0 / jnp.sqrt(t))
                alpha = jnp.maximum(cfg.eps2, _rms(w.astype(jnp.float32))) * rho
            else:
                alpha = schedule(step)

            if leaf.m1 is not None:
                m1 = cfg.b1 * leaf.m1 + (1.0 - cfg.b1) * u
                out = m1
                new = AdafactorLeaf(r=new.r, c=new.c, v=new.v, m1=m1)
            else:
                out = u

            deltas.append(-(alpha * (out + cfg.weight_decay
                                     * w.astype(jnp.float32))))
            new_leaves.append(new)

        return (jax.tree.unflatten(treedef, deltas),
                AdafactorState(step=step, leaves=tuple(new_leaves)))

    return GradientTransformation(init, update)
