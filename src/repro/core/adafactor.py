"""Adafactor baseline (Shazeer & Stern, 2018).

Faithful features: rank-1 row/column factored second moment for matrices
(I-divergence-optimal nonnegative factorisation: ``V ~ R C / sum(R)``),
optional first moment, RMS update clipping, optional beta2 schedule
``b2_t = 1 - t^{-0.8}``, decoupled weight decay, optional relative step
sizes.  The paper's GPT-2 comparison drives all optimizers with the same
external LR schedule, so ``relative_step`` defaults to False here.

:func:`scale_by_factored_rms` is the pure preconditioner (factored second
moment + clip + optional first moment); :func:`adafactor` is the documented
chain

    chain(scale_by_factored_rms(cfg),
          add_decayed_weights(wd),
          scale_by_schedule(lr) | scale_by_relative_step(eps2),
          scale(-1.0))

bit-identical to the former monolithic implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.transform import (add_decayed_weights, scale,
                                  scale_by_relative_step, scale_by_schedule)
from repro.core.types import GradientTransformation, chain


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: "float | Callable" = 1e-3
    b1: float = 0.0                  # Adafactor default: first moment off
    b2: float = 0.999
    b2_schedule: bool = True         # b2_t = 1 - t^decay_exponent
    decay_exponent: float = -0.8
    eps1: float = 1e-30              # regulariser inside the factored stats
    eps2: float = 1e-3               # relative-step floor (only if relative)
    clip_d: float = 1.0
    weight_decay: float = 0.0
    relative_step: bool = False
    min_dim_factor: int = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdafactorLeaf:
    r: Optional[jnp.ndarray]     # (*batch, m) row stats   | None if dense
    c: Optional[jnp.ndarray]     # (*batch, n) col stats   | None if dense
    v: Optional[jnp.ndarray]     # dense fallback          | None if factored
    m1: Optional[jnp.ndarray]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdafactorState:
    step: jnp.ndarray
    leaves: tuple


def _should_factor(shape, min_dim):
    return len(shape) >= 2 and min(shape[-2], shape[-1]) >= min_dim


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def _rowcol_spec(pspec: P) -> tuple:
    """Row/col stat specs for a param (…, m, n) with spec (…, a, b)."""
    parts = list(pspec)
    bd, a, b = parts[:-2], parts[-2], parts[-1]
    return P(*bd, a), P(*bd, b)


def _adafactor_state_spec(state: AdafactorState, param_specs):
    flat_specs = jax.tree.leaves(param_specs,
                                 is_leaf=lambda x: isinstance(x, P))
    leaves = []
    for pspec, leaf in zip(flat_specs, state.leaves):
        m1 = pspec if leaf.m1 is not None else None
        if leaf.r is not None:
            rs, cs = _rowcol_spec(pspec)
            leaves.append(AdafactorLeaf(r=rs, c=cs, v=None, m1=m1))
        else:
            leaves.append(AdafactorLeaf(r=None, c=None, v=pspec, m1=m1))
    return AdafactorState(step=P(), leaves=tuple(leaves))


def scale_by_factored_rms(cfg: AdafactorConfig) -> GradientTransformation:
    """Adafactor's preconditioner: rank-1 factored (or dense-fallback)
    second moment, RMS clipping and the optional first-moment EMA.  Step
    size / decay / sign live in the chain (see module docstring)."""

    def init(params):
        def mk(p):
            m1 = jnp.zeros(p.shape, jnp.float32) if cfg.b1 > 0 else None
            if _should_factor(p.shape, cfg.min_dim_factor):
                bd = p.shape[:-2]
                return AdafactorLeaf(
                    r=jnp.zeros(bd + (p.shape[-2],), jnp.float32),
                    c=jnp.zeros(bd + (p.shape[-1],), jnp.float32),
                    v=None, m1=m1)
            return AdafactorLeaf(r=None, c=None,
                                 v=jnp.zeros(p.shape, jnp.float32), m1=m1)
        flat, _ = jax.tree.flatten(params)
        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              leaves=tuple(mk(p) for p in flat))

    def update(grads, state: AdafactorState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        b2t = (1.0 - t ** cfg.decay_exponent) if cfg.b2_schedule else cfg.b2

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        del flat_p

        outs, new_leaves = [], []
        for g, leaf in zip(flat_g, state.leaves):
            g32 = g.astype(jnp.float32)
            gsq = jnp.square(g32) + cfg.eps1
            if leaf.r is not None:
                r = b2t * leaf.r + (1.0 - b2t) * jnp.mean(gsq, axis=-1)
                c = b2t * leaf.c + (1.0 - b2t) * jnp.mean(gsq, axis=-2)
                # V-hat = outer(r, c) / mean(r); u = g / sqrt(vhat)
                denom = jnp.mean(r, axis=-1, keepdims=True)[..., None]
                vhat = (r[..., :, None] * c[..., None, :]) / (denom + 1e-30)
                u = g32 / (jnp.sqrt(vhat) + 1e-30)
                new = AdafactorLeaf(r=r, c=c, v=None, m1=leaf.m1)
            else:
                v = b2t * leaf.v + (1.0 - b2t) * gsq
                u = g32 / (jnp.sqrt(v) + 1e-30)
                new = AdafactorLeaf(r=None, c=None, v=v, m1=leaf.m1)

            u = u / jnp.maximum(1.0, _rms(u) / cfg.clip_d)

            if leaf.m1 is not None:
                m1 = cfg.b1 * leaf.m1 + (1.0 - cfg.b1) * u
                out = m1
                new = AdafactorLeaf(r=new.r, c=new.c, v=new.v, m1=m1)
            else:
                out = u

            outs.append(out)
            new_leaves.append(new)

        return (jax.tree.unflatten(treedef, outs),
                AdafactorState(step=step, leaves=tuple(new_leaves)))

    return GradientTransformation(init, update, _adafactor_state_spec)


def adafactor(cfg: AdafactorConfig,
              decay_mask: Optional[Callable] = None
              ) -> GradientTransformation:
    """Adafactor as a documented chain (see module docstring)."""
    step_stage = (scale_by_relative_step(cfg.eps2) if cfg.relative_step
                  else scale_by_schedule(cfg.lr))
    return chain(
        scale_by_factored_rms(cfg),
        add_decayed_weights(cfg.weight_decay, decay_mask),
        step_stage,
        scale(-1.0),
    )
