"""Int8-quantized factor storage for Adapprox (beyond-paper).

The paper's Discussion: "our approach is compatible with other memory
optimization techniques such as quantization" — this module implements it.
The stored factors Q (m, r) / U (n, r) are kept as int8 with per-column
fp32 scales (symmetric absmax); they are dequantised transiently at the
start of the update.  Factor memory drops 4x vs fp32 (Table-2 extension:
Adapprox(k_max, int8) ~ 16.9% -> ~4.4% of AdamW at beta1=0).

Error analysis: per-column absmax int8 adds relative error <= 1/127 ~ 0.8%
per entry of the *approximation* (whose own error is xi ~ 1%); and because
V_t = b2 * deq(Q)deq(U)^T + (1-b2) G^2 re-factorises every step, the
quantisation error does not compound — it behaves like a slightly larger
xi (validated in tests/test_quantized.py against the fp32 trajectory).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantizedMatrix(NamedTuple):
    q8: jnp.ndarray        # (..., m, r) int8
    scale: jnp.ndarray     # (..., 1, r) float32 per-column absmax / 127


def quantize(x: jnp.ndarray) -> QuantizedMatrix:
    """Symmetric per-column absmax int8."""
    absmax = jnp.max(jnp.abs(x), axis=-2, keepdims=True)
    scale = (absmax / 127.0 + 1e-30).astype(jnp.float32)
    q8 = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QuantizedMatrix(q8=q8, scale=scale)


def dequantize(qm: QuantizedMatrix) -> jnp.ndarray:
    return qm.q8.astype(jnp.float32) * qm.scale


def quantize_tree_factors(leaf_q: jnp.ndarray, leaf_u: jnp.ndarray):
    return quantize(leaf_q), quantize(leaf_u)
