"""Int8-quantized factor storage for Adapprox (beyond-paper).

The paper's Discussion: "our approach is compatible with other memory
optimization techniques such as quantization" — this module implements it.
The stored factors Q (m, r) / U (n, r) are kept as int8 with per-tile fp32
scale/zero-point pairs; factor memory drops ~4x vs fp32 (Table-2
extension: Adapprox(k_max, int8) ~ 16.9% -> ~4.4% of AdamW at beta1=0).

Codec: asymmetric affine over row blocks of ``BLOCK_ROWS`` rows per
column.  For each (block, column) cell of the factor:

    scale = (amax - amin) / 254 + tiny
    zero  = amin
    q8    = clip(round((x - zero) / scale), 0, 254) - 127     (int8)
    deq   = (q8 + 127) * scale + zero

The block height deliberately equals the fused kernels' row-tile (bm =
bn = 256), so a pass-1 tile sees exactly ONE (scale, zero) row per factor
block and dequantization fuses into the tile load —
``kernels/fused_update.py`` applies this exact formula in-kernel and the
int8 factors never round-trip through fp32 HBM on the update path
(``ops.fused_precond`` accepts :class:`QuantizedMatrix` directly).  Any
change to the formula here MUST be mirrored in the kernel's ``_deq_tile``
or the fused-vs-unfused bitwise contract breaks.

Error analysis: per-block affine int8 adds relative error <=
(amax - amin)/(254 * colmax) <= 1/127 ~ 0.8% per entry of the
*approximation* (whose own error is xi ~ 1%); and because
V_t = b2 * deq(Q)deq(U)^T + (1-b2) G^2 re-factorises every step, the
quantisation error does not compound — it behaves like a slightly larger
xi (validated in tests/test_quantized.py against the fp32 trajectory).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Quantization block height (rows per scale/zero cell).  MUST match the
# row/column tile (bm = bn) the dequant-fused pass-1 kernels run with —
# kernels/ops.py forces its tile plan to this value on the quantized path.
BLOCK_ROWS = 256


class QuantizedMatrix(NamedTuple):
    q8: jnp.ndarray        # (..., m, r) int8, offset by -127
    scale: jnp.ndarray     # (..., nb, r) f32, nb = ceil(m / BLOCK_ROWS)
    zero: jnp.ndarray      # (..., nb, r) f32 per-block per-column minimum


def _expand(blocked: jnp.ndarray, m: int) -> jnp.ndarray:
    """(..., nb, r) block cells -> (..., m, r) per-row broadcast."""
    return jnp.repeat(blocked, BLOCK_ROWS, axis=-2)[..., :m, :]


def quantize(x: jnp.ndarray) -> QuantizedMatrix:
    """Asymmetric per-(row-block, column) affine int8.

    The trailing ragged block (m % BLOCK_ROWS != 0) computes its range
    over zero-padded rows — including 0 in the range costs <= 1 bit of
    the 254-step budget and keeps the all-zero init exactly
    representable (scale = tiny, zero = 0 => deq == 0).
    """
    m, r = x.shape[-2], x.shape[-1]
    x = x.astype(jnp.float32)
    pad = (-m) % BLOCK_ROWS
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)])
    nb = xp.shape[-2] // BLOCK_ROWS
    blocks = xp.reshape(xp.shape[:-2] + (nb, BLOCK_ROWS, r))
    amin = jnp.min(blocks, axis=-2)
    amax = jnp.max(blocks, axis=-2)
    scale = ((amax - amin) / 254.0 + 1e-30).astype(jnp.float32)
    zero = amin.astype(jnp.float32)
    q = jnp.round((x - _expand(zero, m)) / _expand(scale, m))
    q8 = (jnp.clip(q, 0.0, 254.0) - 127.0).astype(jnp.int8)
    return QuantizedMatrix(q8=q8, scale=scale, zero=zero)


def dequantize(qm: QuantizedMatrix) -> jnp.ndarray:
    """The EXACT formula the fused kernels apply per tile (see module
    docstring) — keep bit-identical with ``fused_update._deq_tile``."""
    m = qm.q8.shape[-2]
    return ((qm.q8.astype(jnp.float32) + 127.0) * _expand(qm.scale, m)
            + _expand(qm.zero, m))


def quantize_tree_factors(leaf_q: jnp.ndarray, leaf_u: jnp.ndarray):
    return quantize(leaf_q), quantize(leaf_u)
