"""Core optimizer API: a minimal, optax-style gradient-transformation protocol.

The framework deliberately avoids external optimizer libraries so the full
state layout (and therefore the memory accounting that the Adapprox paper is
about) is under our control.  A ``GradientTransformation`` is a pair of pure
functions so it composes with ``jax.jit`` / ``pjit`` and with the sharding
rules in :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any  # pytree of jnp.ndarray
Grads = Any
Updates = Any
OptState = Any


class GradientTransformation(NamedTuple):
    """``init(params) -> state`` and ``update(grads, state, params) -> (updates, state)``.

    ``updates`` are *additive*: the caller applies ``params + updates``.
    The named optimizers (adapprox / adamw / adafactor / came) are chains of
    ``scale_by_*`` preconditioners plus weight-decay / schedule / sign stages,
    so the returned updates already carry the step size and descent sign.

    ``state_sharding_spec(state, param_specs) -> state-like tree of
    PartitionSpec`` is an optional protocol hook: given this transformation's
    state (or an ``eval_shape`` struct of it) and a pytree of
    ``PartitionSpec`` mirroring the params, it returns a pytree of
    ``PartitionSpec`` mirroring the state.  ``distributed/sharding.py``
    derives optimizer-state shardings through this hook instead of
    isinstance-sniffing state classes.  ``None`` means "replicate every
    state leaf" (see :func:`state_sharding_spec`).
    """

    init: Callable[[Params], OptState]
    update: Callable[[Grads, OptState, Params], tuple[Updates, OptState]]
    state_sharding_spec: Optional[Callable[[OptState, Any], Any]] = None


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EmptyState:
    """State for stateless transformations."""


def replicate_state_spec(state):
    """Default sharding spec: replicate every array leaf of ``state``."""
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(lambda _: P(), state)


def state_sharding_spec(transform: GradientTransformation, state,
                        param_specs):
    """Resolve a transformation's state shardings via the protocol hook,
    falling back to full replication for transformations without one."""
    if transform.state_sharding_spec is None:
        return replicate_state_spec(state)
    return transform.state_sharding_spec(state, param_specs)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transformations left-to-right (like ``optax.chain``)."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_states = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_states.append(s)
        return grads, tuple(new_states)

    def spec(state, param_specs):
        return tuple(state_sharding_spec(t, s, param_specs)
                     for t, s in zip(transforms, state))

    return GradientTransformation(init, update, spec)


def apply_updates(params: Params, updates: Updates) -> Params:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
                        params, updates,
                        is_leaf=lambda x: x is None)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        del params
        return EmptyState()

    def update(grads, state, params):
        del params
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree.map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)


def tree_nbytes(tree) -> int:
    """Total bytes of every array leaf (used by the Table-2 memory bench)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "dtype") and hasattr(leaf, "shape"):
            size = 1
            for d in leaf.shape:
                size *= int(d)
            total += size * jnp.dtype(leaf.dtype).itemsize
    return total


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Linear warmup followed by cosine decay to ``min_lr`` (Megatron-style)."""

    peak_lr: float
    warmup_steps: int = 1000
    total_steps: int = 100_000
    min_lr: float = 0.0

    def __call__(self, step: jnp.ndarray) -> jnp.ndarray:
        step = jnp.asarray(step, jnp.float32)
        warm = self.peak_lr * step / jnp.maximum(1.0, self.warmup_steps)
        denom = jnp.maximum(1.0, self.total_steps - self.warmup_steps)
        frac = jnp.clip((step - self.warmup_steps) / denom, 0.0, 1.0)
        cos = self.min_lr + 0.5 * (self.peak_lr - self.min_lr) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < self.warmup_steps, warm, cos)


def constant_schedule(lr: float) -> Callable[[jnp.ndarray], jnp.ndarray]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def resolve_schedule(lr: "float | Callable") -> Callable[[jnp.ndarray], jnp.ndarray]:
    if callable(lr):
        return lr
    return constant_schedule(float(lr))
