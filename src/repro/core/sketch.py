"""Count-min sketch second-moment preconditioner — the embedding backend.

Adapprox's low-rank factorization (and Adafactor's rank-1 scheme) is the
wrong compression for embedding tables: rows update sparsely and the
second-moment spectrum is flat, so a rank-k basis wastes memory and S-RSI
refresh FLOPs on mass it cannot capture.  Following the Count-Sketch-
Optimizers line of work, :func:`scale_by_sketch` instead holds the Adam
second moment in a depth-d x width-w count-min sketch per leaf:

    update:  S[j, h_j(i), :] <- b2 * S[j, h_j(i), :] + (1 - b2) * G[i, :]^2
    query:   vhat[i, :] = min_j S[j, h_j(i), :] / (1 - b2^t)

with the dense-Adam first moment kept EXACT (it does not tolerate the
collision over-estimate the way the denominator does).  Memory per
sketched leaf: depth * width * inner f32 for the second moment instead of
rows * inner — independent of the vocabulary size.  The count-min query
never underestimates the exact per-row EMA (all additions are
non-negative, decay is uniform, min-over-depth preserves the bound), so
collisions can only make the preconditioner more conservative.

A leaf is sketched when it is >= 2-D with leading dim >= ``min_rows``
(the ``"embeddings"`` GroupSpec selector applies the same predicate at
routing time); other leaves owned by this transform fall back to exact
dense Adam, bitwise-identical to :func:`repro.core.adamw.scale_by_adam`,
so the transform is total and safe as a catch-all.

Hash seeds are STATIC pytree metadata (universal hashing
``((a*i + b) mod p) mod width`` with p = 2^31 - 1), derived
deterministically from ``cfg.seed`` and the leaf position — bucket
indices are trace-time constants, nothing random happens inside the
update, and a fresh ``init`` rebuilds identical seeds (which is what lets
checkpoint restore re-derive the treedef).

The fused scatter + query goes through ``kernels.ops.sketch_update``
(Pallas on TPU, jnp oracle elsewhere, ``REPRO_KERNEL_MODE`` override).

:func:`sketch` is the documented chain

    chain(scale_by_sketch(cfg),
          add_decayed_weights(wd),
          scale_by_schedule(lr),
          scale(-1.0))
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.transform import (add_decayed_weights, scale,
                                  scale_by_schedule)
from repro.core.types import GradientTransformation, chain
from repro.kernels import ops
from repro.telemetry.snapshot import (SketchSnapshot, init_sketch_snapshot,
                                      snapshot_spec)

_PRIME = (1 << 31) - 1          # Mersenne prime for universal hashing
_MASK64 = (1 << 64) - 1


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    lr: "float | Callable" = 1e-3          # used by the sketch() chain only
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0              # used by the sketch() chain only
    depth: int = 4                         # hash functions (min-over-depth)
    width: int = 2048                      # buckets per hash
    min_rows: int = 1024                   # leading-dim threshold to sketch
    seed: int = 0                          # hash-seed derivation root
    telemetry: bool = False                # carry SketchSnapshot in state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SketchLeaf:
    """Sketched second moment for one >= 2-D leaf of shape (rows, *inner).

    table: (depth, width, prod(inner)) f32 — the count-min EMA.
    m:     exact first moment, param shape f32; None when b1 = 0.
    seeds: static ((a, b), ...) per depth — universal hash coefficients.
    shape: static param shape (the table flattens the inner dims away).
    """
    table: jnp.ndarray
    m: Optional[jnp.ndarray]
    seeds: tuple = dataclasses.field(default=(), metadata=dict(static=True))
    shape: tuple = dataclasses.field(default=(), metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SketchDense:
    """Exact dense-Adam fallback for leaves below the sketch threshold.
    The first moment is allocated even at b1 = 0, matching scale_by_adam
    (the paper's memory accounting)."""
    m: jnp.ndarray
    v: jnp.ndarray


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SketchState:
    step: jnp.ndarray                 # int32 scalar, counts from 0
    leaves: tuple                     # per-param SketchLeaf | SketchDense,
                                      # in jax.tree.flatten(params) order
    telemetry: Optional[SketchSnapshot] = None
                                      # cfg.telemetry: fixed-shape occupancy
                                      # / collision snapshot (None => state
                                      # pytree unchanged vs telemetry off)


def should_sketch(shape, min_rows: int) -> bool:
    """The ``"embeddings"`` predicate: >= 2-D with leading dim >= min_rows."""
    return len(shape) >= 2 and shape[0] >= min_rows


def _leaf_seeds(seed: int, leaf_idx: int, depth: int) -> tuple:
    """Deterministic (a, b) universal-hash pairs per depth — plain python
    ints (splitmix-style), stable across platforms and numpy versions."""
    x = (seed * 0x9E3779B97F4A7C15
         + (leaf_idx + 1) * 0xBF58476D1CE4E5B9) & _MASK64
    out = []
    for _ in range(depth):
        x = (x * 6364136223846793005 + 1442695040888963407) & _MASK64
        a = int((x >> 16) % (_PRIME - 1)) + 1          # a in [1, p)
        x = (x * 6364136223846793005 + 1442695040888963407) & _MASK64
        b = int((x >> 16) % _PRIME)                    # b in [0, p)
        out.append((a, b))
    return tuple(out)


def bucket_indices(n_rows: int, width: int, seeds: tuple) -> np.ndarray:
    """(depth, n_rows) int32 bucket per row per hash — computed with numpy
    at trace time (rows, width and seeds are all static), so the indices
    are constants in the jaxpr, not state."""
    i = np.arange(n_rows, dtype=np.int64)
    rows = [((a * i + b) % _PRIME) % width for (a, b) in seeds]
    return np.stack(rows).astype(np.int32)


def scale_by_sketch(cfg: SketchConfig) -> GradientTransformation:
    """Bias-corrected Adam direction with a count-min second moment on
    every >= 2-D leaf whose leading dim reaches ``cfg.min_rows``; exact
    dense Adam on the rest.  Learning rate / weight decay / descent sign
    are NOT applied — chain like the other preconditioners (see
    :func:`sketch`)."""

    def init(params):
        flat, _ = jax.tree.flatten(params)
        leaves = []
        for i, p in enumerate(flat):
            if should_sketch(p.shape, cfg.min_rows):
                inner = int(np.prod(p.shape[1:]))
                leaves.append(SketchLeaf(
                    table=jnp.zeros((cfg.depth, cfg.width, inner),
                                    jnp.float32),
                    m=(jnp.zeros(p.shape, jnp.float32)
                       if cfg.b1 > 0 else None),
                    seeds=_leaf_seeds(cfg.seed, i, cfg.depth),
                    shape=tuple(p.shape)))
            else:
                z = jnp.zeros(p.shape, jnp.float32)
                leaves.append(SketchDense(m=z, v=z))
        tel = None
        if cfg.telemetry:
            sidx = tuple(i for i, l in enumerate(leaves)
                         if isinstance(l, SketchLeaf))
            tel = init_sketch_snapshot(len(sidx), leaf_indices=sidx)
        return SketchState(step=jnp.zeros((), jnp.int32),
                           leaves=tuple(leaves), telemetry=tel)

    def update(grads, state: SketchState, params):
        del params
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        flat_g, treedef = jax.tree.flatten(grads)
        dirs, new_leaves, occs, overs = [], [], [], []
        for g, leaf in zip(flat_g, state.leaves):
            g32 = g.astype(jnp.float32)
            if isinstance(leaf, SketchLeaf):
                rows, inner = g.shape[0], leaf.table.shape[-1]
                idx = jnp.asarray(
                    bucket_indices(rows, leaf.table.shape[1], leaf.seeds))
                table_new, q = ops.sketch_update(
                    leaf.table, g.reshape(rows, inner), idx, cfg.b2)
                vhat = (q / bc2).reshape(g.shape)
                if leaf.m is not None:
                    m_new = cfg.b1 * leaf.m + (1.0 - cfg.b1) * g32
                    mhat = m_new / bc1
                else:
                    m_new, mhat = None, g32
                dirs.append(mhat / (jnp.sqrt(vhat) + cfg.eps))
                new_leaves.append(SketchLeaf(table=table_new, m=m_new,
                                             seeds=leaf.seeds,
                                             shape=leaf.shape))
                if state.telemetry is not None:
                    # occupancy: fraction of buckets holding any mass;
                    # overestimate proxy: total queried mass over total
                    # table mass (one depth row carries the whole EMA'd
                    # gsq mass), >= 1 and == 1 with zero collisions.
                    hit = (jnp.max(table_new, axis=-1) > 0.0)
                    occs.append(jnp.mean(hit.astype(jnp.float32)))
                    overs.append(jnp.sum(q)
                                 / jnp.maximum(jnp.sum(table_new[0]), 1e-30))
            else:
                m = cfg.b1 * leaf.m + (1.0 - cfg.b1) * g32
                v = cfg.b2 * leaf.v + (1.0 - cfg.b2) * jnp.square(g32)
                mhat = m / bc1
                vhat = v / bc2
                dirs.append(mhat / (jnp.sqrt(vhat) + cfg.eps))
                new_leaves.append(SketchDense(m=m, v=v))

        tel = state.telemetry
        if tel is not None:
            tel = SketchSnapshot(
                step=step,
                occupancy=(jnp.stack(occs) if occs
                           else jnp.zeros((0,), jnp.float32)),
                overestimate=(jnp.stack(overs) if overs
                              else jnp.zeros((0,), jnp.float32)),
                leaf_indices=tel.leaf_indices)
        return (jax.tree.unflatten(treedef, dirs),
                SketchState(step=step, leaves=tuple(new_leaves),
                            telemetry=tel))

    def spec(state: SketchState, param_specs):
        flat_specs = jax.tree.leaves(param_specs,
                                     is_leaf=lambda x: isinstance(x, P))
        leaves = []
        for pspec, leaf in zip(flat_specs, state.leaves):
            if isinstance(leaf, SketchLeaf):
                parts = list(pspec)
                parts += [None] * (len(leaf.shape) - len(parts))
                # the hashed row axis is gone from the table; the inner
                # axis maps to param axis 1 only when nothing was
                # flattened into it (2-D leaf), else replicate it.
                inner = parts[1] if len(leaf.shape) == 2 else None
                leaves.append(SketchLeaf(
                    table=P(None, None, inner),
                    m=P(*parts) if leaf.m is not None else None,
                    seeds=leaf.seeds, shape=leaf.shape))
            else:
                leaves.append(SketchDense(m=pspec, v=pspec))
        tel = (snapshot_spec(state.telemetry)
               if state.telemetry is not None else None)
        return SketchState(step=P(), leaves=tuple(leaves), telemetry=tel)

    return GradientTransformation(init, update, spec)


def sketch(cfg: SketchConfig,
           decay_mask: Optional[Callable] = None) -> GradientTransformation:
    """Sketch-Adam as a documented chain (see module docstring)."""
    return chain(
        scale_by_sketch(cfg),
        add_decayed_weights(cfg.weight_decay, decay_mask),
        scale_by_schedule(cfg.lr),
        scale(-1.0),
    )


def sketch_state(state) -> SketchState:
    """Extract the ``SketchState`` from a (possibly chained/partitioned)
    optimizer state — convenience for tests and metric probes."""
    from repro.core.adapprox import _find_states
    for sub in _find_states(state, SketchState):
        return sub
    raise ValueError("no SketchState found in optimizer state")
