"""Per-leaf state layout and factorisation policy for Adapprox.

A parameter leaf with >= 2 trailing dims whose smaller trailing dim is at
least ``min_dim`` gets a *factored* second moment (Q, U, k); everything else
(biases, norms, scalars) keeps a dense second moment — the same policy
Adafactor uses.  Leading dims (scan-stacked layers ``(L, m, n)``, MoE expert
stacks ``(L, E, m, n)``) are treated as batch dims and vmapped over.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FactoredLeaf:
    """Optimizer state for one factored parameter.

    q:  (*batch, m, r_store) float32 — left feature matrix (cols > k zeroed)
    u:  (*batch, n, r_store) float32 — right feature matrix
    k:  (*batch,) int32 — current effective rank (adaptive mode)
    xi: (*batch,) float32 — last approximation error rate.  Metrics, plus
        one control use: the warm-start drift guard compares it against
        ``warm_drift_xi`` (never feeds the update arithmetic itself; note
        xi can differ by 1 ulp between bucketed and per-leaf execution —
        see tests/test_refresh.py — so that threshold compare is the one
        place the two modes could in principle diverge, at an exact-
        boundary measure-zero event)
    m1: (*batch, m, n) float32 | None — running average of *updates*
        (Adapprox replaces Adam's gradient EMA with an update EMA).
    """

    q: jnp.ndarray
    u: jnp.ndarray
    k: jnp.ndarray
    xi: jnp.ndarray
    m1: Optional[jnp.ndarray]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DenseLeaf:
    """Dense (non-factored) fallback state: full second moment."""

    v: jnp.ndarray                 # same shape as param, float32
    m1: Optional[jnp.ndarray]      # same shape as param, float32 | None


def should_factor(shape: tuple[int, ...], min_dim: int) -> bool:
    if len(shape) < 2:
        return False
    return min(shape[-2], shape[-1]) >= min_dim


def batch_dims(shape: tuple[int, ...]) -> tuple[int, ...]:
    return tuple(shape[:-2])


def vmap_over_batch(fn, n_batch_dims: int, key_arg: bool = False):
    """vmap ``fn`` over ``n_batch_dims`` leading axes of all its array args."""
    for _ in range(n_batch_dims):
        fn = jax.vmap(fn)
    return fn


def leaf_signature(shape: tuple[int, ...], g_dtype) -> tuple:
    """Bucketing key for factored leaves: two leaves can share one vmapped
    S-RSI + update trace iff their full param shape (batch dims included)
    and gradient dtype agree — ``r_store``, oversample and ``k_max`` are
    all deterministic functions of (shape, config), so the shape subsumes
    them."""
    return (tuple(shape), jnp.dtype(g_dtype).name)


def batched_keys(key: jax.Array, bdims: tuple[int, ...]) -> jax.Array:
    """A key array with shape ``bdims`` so each matrix in a stack gets an
    independent sketch."""
    if not bdims:
        return key
    total = 1
    for d in bdims:
        total *= d
    keys = jax.random.split(key, total)
    return keys.reshape(bdims + key.shape)
