"""repro.core — the paper's contribution: Adapprox and its substrate,
exposed as composable optax-style gradient transformations.

Layers, bottom to top:

  Primitives (transform.py, types.py)
      ``GradientTransformation(init, update, state_sharding_spec)`` is the
      protocol; ``chain(*ts)`` composes stages; ``partition(labeler,
      {label: t})`` routes parameter groups through different transforms
      (e.g. dense Adam on 1-D leaves, Adapprox on matrices, no decay on
      norms).  Reusable stages: ``add_decayed_weights(wd, mask)``,
      ``clip_update_rms(d)``, ``scale_by_schedule(sched)``,
      ``scale_by_relative_step(eps2)``, ``scale(c)``,
      ``clip_by_global_norm(n)``.

  Preconditioners (pure: gradients -> update direction, no lr/wd/sign)
      scale_by_adapprox(AdapproxConfig)   — Algorithm 3's second moment
      scale_by_adam(b1, b2, eps)          — bias-corrected Adam
      scale_by_factored_rms(AdafactorConfig) — Shazeer & Stern rank-1
      scale_by_came(CAMEConfig)           — CAME confidence guidance
      scale_by_sketch(SketchConfig)       — count-min sketch second moment
          for embedding tables (depth x width hashed buckets, min-over-
          depth query that never underestimates the exact EMA; exact
          first moment; dense-Adam fallback below ``min_rows``)

  Named optimizers (documented chains, bit-identical to the former
  monoliths):  every one is
      chain(scale_by_<X>(cfg), add_decayed_weights(cfg.weight_decay),
            scale_by_schedule(cfg.lr), scale(-1.0))
      adapprox(AdapproxConfig)   — the paper's optimizer (Algorithm 3)
      adamw / adafactor / came   — baselines the paper compares against
      (adafactor swaps the schedule stage for ``scale_by_relative_step``
      when cfg.relative_step is set)
      sketch(SketchConfig)       — the count-min embedding backend

  Construction surface
      build_optimizer(OptimizerConfig)  — THE entry point for launchers /
          benchmarks / examples: lowers the declarative config to a chain,
          or — with ``OptimizerConfig.groups`` — to a ``partition`` of
          per-group chains.  Each ``(label, GroupSpec)`` pair owns the
          leaves its ``select`` rule matches (first hit wins) and builds
          its own full chain from its optimizer family; ``lr_scale`` is a
          per-group LR multiplier via the labeled
          ``scale_by_schedule(sched, lr_scale=)`` stage.
          ``repro.config.default_mixed_groups()`` is the production
          default the launcher uses for adapprox (``--mixed-groups``),
          three state families: the count-min sketch on embedding tables
          (``"embeddings"`` selector — >= 2-D leaves with at least
          ``embedding_min_rows`` rows, listed first so first-hit-wins
          routes them before ``"factored"``), Adapprox on factorable
          matrices, dense bias-corrected Adam on 1-D/small leaves —
          per-layer sensitivity without blanket factorization.
      make_optimizer(name, **kw)        — kwargs-level registry for tests
          and ad-hoc experimentation; same chains underneath.

  Substrate
      srsi_dense / srsi_implicit — Streamlined Randomized Subspace Iteration
          (both accept ``u0``/``use_warm`` to warm-start the sketch from a
          previous right factor)
      RankConfig                 — adaptive rank selection (Algorithm 2)

Amortized refresh (perf; AdapproxConfig / OptimizerConfig knobs, all
default-off so the default chain stays bit-exact vs the paper-faithful
baseline):

  * ``warm_start=True, n_iter_warm=l'`` — seed each S-RSI from the stored
    U instead of a fresh Gaussian sketch.  V_t is a b2~0.999 EMA, so the
    previous subspace is near-converged and l' = 1-2 power iterations
    match the cold l = 5 accuracy; ``warm_drift_xi`` cold-restarts the
    sketch when the stored approximation error regresses past it.
    Accuracy cost: none measurable once the run is past the first few
    steps (power iterations accumulate ACROSS steps on the slowly-moving
    operator).
  * ``refresh_every=T`` — run full S-RSI every T-th step only; in between,
    fold the fresh gradient into the factors under the frozen basis
    (``U <- b2*U + (1-b2)(G^2)^T Q``, rank-projected — exactly V_t^T Q).
    The elementwise update stays exact w.r.t. the implicit operator every
    step; only the basis Q ages (bounded by the T-step refresh).  Cost:
    the O(l m n r) factorization amortizes over T steps.
  * ``bucketed=True`` — group factored leaves with identical
    (batch_dims, m, n, dtype) and run ONE vmapped S-RSI + update per
    bucket instead of N sequential per-leaf traces: same math bit-for-bit,
    ~N-fold smaller HLO / fewer kernel launches for transformer stacks.
    On the pallas dispatch path, ``kernels/ops.py`` additionally buckets
    MIXED shapes: raw dims round up a coarse ladder before tiling, so
    near-miss signatures share compiled kernel instances (default on;
    ``REPRO_KERNEL_BUCKETS=off`` or ``ops.set_bucketing(False)``).
  * ``fused_update=True`` + ``refresh_every>1`` — fold-fused pass 1:
    the fused pipeline's first pass also emits the fold projection
    ``(G^2)^T Q`` from its already-resident G tiles, so fold steps skip
    the standalone ``sq_matmul_t`` pass over G entirely (>= 1.3x fewer
    fold-step bytes by the roofline model; automatic, no extra knob).
  * ``factor_dtype="int8"`` (or ``OptimizerConfig.quantize_factors`` /
    the launcher's ``--quantize-factors``) — int8 factor storage with
    per-(row-block, column) affine scale/zero (core/quantized.py), ~4x
    smaller factor state.  With ``fused_update=True`` the dequant is
    LAZY: pass 1 decodes int8 tiles in VMEM (kernels/fused_update.py)
    and fp32 factors never materialize in HBM on the update path; only
    the skinny O((m+n) r) refresh/fold inputs are decoded per step.

  Measured (benchmarks/bench_step_time.py -> BENCH_step_time.json, CPU,
  GPT-2-shaped 4-layer stack): refresh_every=5 + warm_start(l'=1) is
  3.3x faster per step than the PR-1 default adapprox config (warm-start
  alone: 2.5x) — the step-time gap to AdamW's elementwise update shrinks
  from ~4.8x to ~1.5x while the factored memory savings are kept.
  Bucketing's win is HLO size / launch count, which CPU wall-time barely
  sees (~1.05x there); it targets many-leaf TPU stacks.

Telemetry & closed-loop refresh control (repro.telemetry; AdapproxConfig /
OptimizerConfig knobs, default-off => the default chain stays bitwise
identical):

  * ``telemetry=True`` — ``scale_by_adapprox`` assembles a fixed-shape
    ``TelemetrySnapshot`` (per-leaf xi / rank / occupancy, clip activation
    rate, refresh-vs-fold counters) inside the jitted update, from values
    it already computes: updates stay BITWISE identical to telemetry-off.
    The snapshot is optimizer state — replicated under sharding,
    checkpointed, fetched host-side by ``telemetry.TelemetryRuntime``
    (JSONL sink, per-group metric aggregates in the train-step metrics).
  * ``dynamic_refresh=True`` — ``refresh_every`` becomes a TRACED int32
    state scalar: ``telemetry.set_refresh_every`` (or the closed-loop
    controller, ``--auto-refresh``) retunes the S-RSI cadence per
    parameter group at runtime with zero recompilation.  The controller
    tightens the cadence when observed xi drifts toward ``warm_drift_xi``
    and relaxes it after sustained calm (hysteresis; deterministic and
    checkpointable, so restarts replay the same decisions).

Resilience (repro.resilience; OptimizerConfig knobs, default-off => the
default chain stays bitwise identical and the state pytree gains no
leaves):

  * ``guards=True`` — two in-jit enforcement levels, both contained
    without a host round-trip.  ``build_optimizer`` wraps the WHOLE
    chain in ``resilience.guards.guard_updates``: any non-finite
    gradient or final-update leaf zeroes the step and reverts the inner
    state wholesale (weight decay included — params and every EMA are
    exactly their pre-step values; only the skip counters advance).
    Inside ``scale_by_adapprox``, a per-factored-leaf xi watchdog
    (``guard_xi_trip``) treats an approximation-error blow-up as a sick
    factorization: the leaf gets a FORCED full S-RSI refresh next step,
    overriding the fold cadence.
  * ``max_demotions=N`` — graceful degradation budget: after N
    CONSECUTIVE xi trips a leaf is demoted to the exact dense second
    moment (per-leaf ``lax.cond``; the dense buffer is seeded from the
    factored reconstruction ``max(Q U^T, 0)`` at demotion time, so the
    EMA continues without a cold restart).  0 disables demotion and the
    dense shadow buffers it would need.

  Guard activity surfaces as ``kind="fault"`` telemetry events and
  pauses the closed-loop controller's cadence relaxation; checkpoint
  I/O is hardened independently (atomic rename-commit, per-leaf sha256,
  retry-with-backoff, restore fallback past corrupt checkpoints — see
  ``checkpoint/serialization.py``).  The deterministic fault-injection
  harness (``resilience.chaos`` + ``tools/chaos.py`` +
  tests/test_chaos.py) drives all of it through the real train loop.

Sharding: every stateful transformation carries a ``state_sharding_spec``
hook mapping param PartitionSpecs to state PartitionSpecs;
``distributed/sharding.py`` consumes it without knowing any state class.
The production path runs through it end to end: ``launch/train.py --mesh``
-> ``distributed.sharding.train_shardings`` (param + opt-state + batch
shardings, ``partition`` chains included) -> the mesh-jitted step inside
``train_loop.train`` -> sharded checkpoint save / resharding restore
(``checkpoint/serialization.py`` keeps logical arrays + per-leaf spec
metadata, so a run saved on one mesh resumes on any other).
"""
import dataclasses as _dc

from repro.core.types import (EmptyState, GradientTransformation, Schedule,
                              apply_updates, chain, clip_by_global_norm,
                              constant_schedule, global_norm,
                              replicate_state_spec, state_sharding_spec,
                              tree_nbytes)
from repro.core.transform import (CountState, PartitionState,
                                  add_decayed_weights, clip_update_rms,
                                  mask_nd, partition, scale,
                                  scale_by_relative_step, scale_by_schedule)
from repro.core.srsi import (ImplicitV, SRSIResult, cholesky_qr2,
                             make_implicit_v, reconstruct, srsi_dense,
                             srsi_implicit)
from repro.core.rank import RankConfig, f_increment, resolve_k_max
from repro.core.factored import DenseLeaf, FactoredLeaf
from repro.core.adapprox import (AdapproxConfig, AdapproxState, adapprox,
                                 adapprox_state, rank_metrics,
                                 scale_by_adapprox)
from repro.core.adamw import AdamWConfig, AdamWState, adamw, scale_by_adam
from repro.core.adafactor import (AdafactorConfig, AdafactorState, adafactor,
                                  scale_by_factored_rms)
from repro.core.came import CAMEConfig, CAMEState, came, scale_by_came
from repro.core.sketch import (SketchConfig, SketchDense, SketchLeaf,
                               SketchState, scale_by_sketch, should_sketch,
                               sketch, sketch_state)
from repro.core.build import build_optimizer

_REGISTRY = {}


def register(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def make_optimizer(name: str, **kwargs) -> GradientTransformation:
    """Build an optimizer by name. kwargs override the config defaults.

    This is the kwargs-level registry (tests, notebooks, ablations); config
    files and launchers go through :func:`build_optimizer` instead.  Both
    produce the same chains.
    """
    if name in _REGISTRY:
        # registry factories see every kwarg untouched (incl. decay_mask)
        return _REGISTRY[name](**kwargs)
    decay_mask = kwargs.pop("decay_mask", None)
    if name == "adapprox":
        rank_keys = {f.name for f in _dc.fields(RankConfig)}
        rank_kw = {k: kwargs.pop(k) for k in list(kwargs) if k in rank_keys}
        rank = RankConfig(**rank_kw)
        return adapprox(AdapproxConfig(rank=rank, **kwargs),
                        decay_mask=decay_mask)
    if name == "adamw":
        return adamw(AdamWConfig(**kwargs), decay_mask=decay_mask)
    if name == "adafactor":
        return adafactor(AdafactorConfig(**kwargs), decay_mask=decay_mask)
    if name == "came":
        return came(CAMEConfig(**kwargs), decay_mask=decay_mask)
    if name == "sketch":
        return sketch(SketchConfig(**kwargs), decay_mask=decay_mask)
    raise ValueError(f"unknown optimizer {name!r}; "
                     f"available: adapprox, adamw, adafactor, came, "
                     f"sketch, {sorted(_REGISTRY)}")
