"""repro.core — the paper's contribution: Adapprox and its substrate.

Public API:
    adapprox(AdapproxConfig)   — the paper's optimizer (Algorithm 3)
    adamw / adafactor / came   — baselines the paper compares against
    srsi_dense / srsi_implicit — Streamlined Randomized Subspace Iteration
    RankConfig                 — adaptive rank selection (Algorithm 2)
    make_optimizer(name, **kw) — registry used by configs / launcher
"""
import dataclasses as _dc

from repro.core.types import (GradientTransformation, Schedule, apply_updates,
                              chain, clip_by_global_norm, constant_schedule,
                              global_norm, tree_nbytes)
from repro.core.srsi import (ImplicitV, SRSIResult, cholesky_qr2,
                             make_implicit_v, reconstruct, srsi_dense,
                             srsi_implicit)
from repro.core.rank import RankConfig, f_increment, resolve_k_max
from repro.core.factored import DenseLeaf, FactoredLeaf
from repro.core.adapprox import (AdapproxConfig, AdapproxState, adapprox,
                                 rank_metrics)
from repro.core.adamw import AdamWConfig, adamw
from repro.core.adafactor import AdafactorConfig, adafactor
from repro.core.came import CAMEConfig, came

_REGISTRY = {}


def register(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def make_optimizer(name: str, **kwargs) -> GradientTransformation:
    """Build an optimizer by name. kwargs override the config defaults."""
    if name == "adapprox":
        rank_keys = {f.name for f in _dc.fields(RankConfig)}
        rank_kw = {k: kwargs.pop(k) for k in list(kwargs) if k in rank_keys}
        rank = RankConfig(**rank_kw)
        return adapprox(AdapproxConfig(rank=rank, **kwargs))
    if name == "adamw":
        return adamw(AdamWConfig(**kwargs))
    if name == "adafactor":
        return adafactor(AdafactorConfig(**kwargs))
    if name == "came":
        return came(CAMEConfig(**kwargs))
    if name in _REGISTRY:
        return _REGISTRY[name](**kwargs)
    raise ValueError(f"unknown optimizer {name!r}; "
                     f"available: adapprox, adamw, adafactor, came, "
                     f"{sorted(_REGISTRY)}")
