"""AdamW baseline (Loshchilov & Hutter) — the paper's reference optimizer.

Note: following the paper's memory accounting (Table 2), the first moment is
allocated even when ``b1 = 0`` ("AdamW still allocates memory for the first
moment"), matching the PyTorch implementation the paper measured.

:func:`scale_by_adam` is the pure bias-corrected preconditioner;
:func:`adamw` is the documented chain

    chain(scale_by_adam(b1, b2, eps),
          add_decayed_weights(wd),
          scale_by_schedule(lr),
          scale(-1.0))

bit-identical to the former monolithic implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.transform import (add_decayed_weights, scale,
                                  scale_by_schedule)
from repro.core.types import GradientTransformation, chain


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: "float | Callable" = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray
    m: object          # pytree like params, float32
    v: object          # pytree like params, float32


def scale_by_adam(b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-8) -> GradientTransformation:
    """Bias-corrected Adam direction ``m_hat / (sqrt(v_hat) + eps)``.

    Both moments shard exactly like the params they mirror (the
    ``state_sharding_spec`` hook forwards the param specs verbatim).
    """

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(z, params),
                          v=jax.tree.map(z, params))

    def update(grads, state: AdamWState, params):
        del params
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1.0 - b1) * g32
            v = b2 * v + (1.0 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            return mhat / (jnp.sqrt(vhat) + eps), m, v

        out = jax.tree.map(upd, grads, state.m, state.v)
        # tree-of-tuples -> tuple-of-trees
        treedef = jax.tree.structure(grads)
        flat = treedef.flatten_up_to(out)
        dirs = jax.tree.unflatten(treedef, [o[0] for o in flat])
        ms = jax.tree.unflatten(treedef, [o[1] for o in flat])
        vs = jax.tree.unflatten(treedef, [o[2] for o in flat])
        return dirs, AdamWState(step=step, m=ms, v=vs)

    def spec(state: AdamWState, param_specs):
        del state
        return AdamWState(step=P(), m=param_specs, v=param_specs)

    return GradientTransformation(init, update, spec)


def adamw(cfg: AdamWConfig,
          decay_mask: Optional[Callable] = None) -> GradientTransformation:
    """AdamW as a documented chain (see module docstring)."""
    return chain(
        scale_by_adam(cfg.b1, cfg.b2, cfg.eps),
        add_decayed_weights(cfg.weight_decay, decay_mask),
        scale_by_schedule(cfg.lr),
        scale(-1.0),
    )
