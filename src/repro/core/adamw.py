"""AdamW baseline (Loshchilov & Hutter) — the paper's reference optimizer.

Note: following the paper's memory accounting (Table 2), the first moment is
allocated even when ``b1 = 0`` ("AdamW still allocates memory for the first
moment"), matching the PyTorch implementation the paper measured.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.types import GradientTransformation, resolve_schedule


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: "float | Callable" = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray
    m: object          # pytree like params, float32
    v: object          # pytree like params, float32


def adamw(cfg: AdamWConfig) -> GradientTransformation:
    schedule = resolve_schedule(cfg.lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(z, params),
                          v=jax.tree.map(z, params))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr = schedule(step)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(g, m, v, w):
            g32 = g.astype(jnp.float32)
            m = cfg.b1 * m + (1.0 - cfg.b1) * g32
            v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            delta = -(lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                            + cfg.weight_decay * w.astype(jnp.float32)))
            return delta, m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        # tree-of-tuples -> tuple-of-trees
        treedef = jax.tree.structure(grads)
        flat = treedef.flatten_up_to(out)
        deltas = jax.tree.unflatten(treedef, [o[0] for o in flat])
        ms = jax.tree.unflatten(treedef, [o[1] for o in flat])
        vs = jax.tree.unflatten(treedef, [o[2] for o in flat])
        return deltas, AdamWState(step=step, m=ms, v=vs)

    return GradientTransformation(init, update)
