"""``build_optimizer(OptimizerConfig)`` — the single optimizer-construction
path used by launchers, benchmarks and examples.

Lowers the declarative :class:`repro.config.OptimizerConfig` to the
documented transformation chains in this package:

    adapprox : scale_by_adapprox    -> +wd*W -> *lr_t -> *(-1)
    adamw    : scale_by_adam        -> +wd*W -> *lr_t -> *(-1)
    adafactor: scale_by_factored_rms-> +wd*W -> *lr_t | *alpha_t -> *(-1)
    came     : scale_by_came        -> +wd*W -> *lr_t -> *(-1)
    sketch   : scale_by_sketch      -> +wd*W -> *lr_t -> *(-1)

``cfg.decay_mask = "no_1d"`` swaps the decay stage's mask so 1-D leaves
(biases, norm scales) are exempt from weight decay — the standard
production configuration — without forking any optimizer.

``cfg.groups`` lowers to :func:`repro.core.partition`: each ``(label,
GroupSpec)`` pair becomes its own full chain (the group's family
preconditioner, the shared decay mask, the shared schedule scaled by
``lr_scale``, the descent sign), and a shape-based labeler routes every
parameter leaf to the first group whose ``select`` rule matches.  The
production default, :func:`repro.config.default_mixed_groups`, runs three
state families: the count-min sketch on embedding tables, the parent
family (Adapprox) on factorable matrices, and dense bias-corrected Adam
on 1-D/small leaves — per-layer sensitivity without blanket
factorization.  ``PartitionState`` keeps the labels as static metadata, so
the partitioned optimizer jits, checkpoints and shards like any chain.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from repro.config import GroupSpec, OptimizerConfig
from repro.core.adafactor import AdafactorConfig, scale_by_factored_rms
from repro.core.adamw import AdamWConfig, scale_by_adam
from repro.core.adapprox import AdapproxConfig, scale_by_adapprox
from repro.core.came import CAMEConfig, scale_by_came
from repro.core.factored import should_factor
from repro.core.rank import RankConfig
from repro.core.sketch import SketchConfig, scale_by_sketch, should_sketch
from repro.core.transform import (add_decayed_weights, partition,
                                  resolve_decay_mask, scale,
                                  scale_by_relative_step, scale_by_schedule)
from repro.core.types import GradientTransformation, Schedule, chain, \
    constant_schedule
from repro.resilience.guards import GuardConfig, guard_updates


def _schedule_of(cfg: OptimizerConfig) -> Callable:
    if cfg.schedule == "constant":
        return constant_schedule(cfg.lr)
    if cfg.schedule == "cosine":
        return Schedule(cfg.lr, warmup_steps=cfg.warmup_steps,
                        total_steps=cfg.total_steps, min_lr=cfg.min_lr)
    raise ValueError(f"unknown schedule {cfg.schedule!r} "
                     f"(expected 'cosine' or 'constant')")


def _decay_mask_of(cfg: OptimizerConfig) -> Optional[Callable]:
    return resolve_decay_mask(cfg.decay_mask)


def _preconditioner(cfg: OptimizerConfig, name: str,
                    sched: Callable) -> GradientTransformation:
    """The pure ``scale_by_*`` stage for one optimizer family, configured
    from the shared declarative config."""
    if name == "adapprox":
        acfg = AdapproxConfig(
            lr=sched, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, clip_d=cfg.clip_d,
            weight_decay=cfg.weight_decay,
            rank=RankConfig(k_init=cfg.k, k_max=cfg.k_max,
                            xi_thresh=cfg.xi_thresh, delta_s=cfg.delta_s,
                            mode=cfg.rank_mode),
            oversample=cfg.oversample, n_iter=cfg.n_iter,
            min_dim_factor=cfg.min_dim_factor, guidance=cfg.guidance,
            implicit=cfg.implicit, use_kernels=cfg.use_kernels,
            factor_dtype=("int8" if cfg.quantize_factors
                          else cfg.factor_dtype),
            seed=cfg.seed,
            refresh_every=cfg.refresh_every, warm_start=cfg.warm_start,
            n_iter_warm=cfg.n_iter_warm, warm_drift_xi=cfg.warm_drift_xi,
            bucketed=cfg.bucketed, fused_update=cfg.fused_update,
            telemetry=cfg.telemetry, dynamic_refresh=cfg.dynamic_refresh,
            guards=(GuardConfig(xi_trip=cfg.guard_xi_trip,
                                max_demotions=cfg.max_demotions)
                    if cfg.guards else None))
        return scale_by_adapprox(acfg)
    if name == "adamw":
        return scale_by_adam(cfg.b1, cfg.b2, cfg.eps)
    if name == "adafactor":
        return scale_by_factored_rms(AdafactorConfig(
            lr=sched, b1=cfg.b1, b2=cfg.b2, b2_schedule=cfg.b2_schedule,
            clip_d=cfg.clip_d, weight_decay=cfg.weight_decay,
            relative_step=cfg.relative_step,
            min_dim_factor=cfg.min_dim_factor))
    if name == "came":
        return scale_by_came(CAMEConfig(
            lr=sched, b1=cfg.b1, b2=cfg.b2, b3=cfg.b3, clip_d=cfg.clip_d,
            weight_decay=cfg.weight_decay,
            min_dim_factor=cfg.min_dim_factor))
    if name == "sketch":
        return scale_by_sketch(SketchConfig(
            lr=sched, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, depth=cfg.sketch_depth,
            width=cfg.sketch_width, min_rows=cfg.embedding_min_rows,
            seed=cfg.seed, telemetry=cfg.telemetry))
    raise ValueError(f"unknown optimizer {name!r}; "
                     f"available: adapprox, adamw, adafactor, came, sketch")


def _chain_for(cfg: OptimizerConfig, name: str, sched: Callable,
               mask, lr_scale: float = 1.0) -> GradientTransformation:
    """One documented chain: preconditioner -> +wd*W -> *lr_t -> *(-1).
    Identical to the named ``adapprox()`` / ``adamw()`` / ... factories
    (``lr_scale=1.0`` compiles to the same HLO)."""
    if name == "adafactor" and cfg.relative_step:
        step_stage = scale_by_relative_step(lr_scale=lr_scale)
    else:
        step_stage = scale_by_schedule(sched, lr_scale=lr_scale)
    return chain(
        _preconditioner(cfg, name, sched),
        add_decayed_weights(cfg.weight_decay, mask),
        step_stage,
        scale(-1.0),
    )


# ---------------------------------------------------------------------------
# Parameter groups -> partition
# ---------------------------------------------------------------------------

def _select_matches(select: str, shape: tuple, min_dim_factor: int,
                    embedding_min_rows: int) -> bool:
    if select == "embeddings":
        return should_sketch(tuple(shape), embedding_min_rows)
    if select == "factored":
        return should_factor(tuple(shape), min_dim_factor)
    if select == "matrices":
        return len(shape) >= 2
    if select == "vectors":
        return len(shape) < 2
    if select == "rest":
        return True
    raise ValueError(f"unknown GroupSpec.select {select!r} (expected "
                     f"'embeddings', 'factored', 'matrices', 'vectors' "
                     f"or 'rest')")


def group_labeler(groups: tuple, min_dim_factor: int,
                  embedding_min_rows: int = 1024) -> Callable:
    """params -> label pytree, first matching group (declaration order)
    wins.  Only inspects leaf shapes, so it is safe under tracing."""

    def label_of(p):
        for label, g in groups:
            if _select_matches(g.select, p.shape, min_dim_factor,
                               embedding_min_rows):
                return label
        raise ValueError(
            f"no group matches leaf of shape {tuple(p.shape)}; add a "
            f"catch-all (label, GroupSpec(select='rest')) group")

    return lambda params: jax.tree.map(label_of, params)


def _build_partitioned(cfg: OptimizerConfig, sched: Callable,
                       mask) -> GradientTransformation:
    groups = tuple(cfg.groups)
    if not groups:
        raise ValueError("cfg.groups is empty")
    seen = set()
    for label, g in groups:
        if not isinstance(g, GroupSpec):
            raise TypeError(f"group {label!r}: expected GroupSpec, got "
                            f"{type(g).__name__}")
        if label in seen:
            raise ValueError(f"duplicate group label {label!r}")
        seen.add(label)
    if groups[-1][1].select != "rest":
        raise ValueError("the last group must be a catch-all "
                         "GroupSpec(select='rest') so every leaf is owned")
    transforms = {
        label: _chain_for(cfg, g.name or cfg.name, sched, mask, g.lr_scale)
        for label, g in groups}
    return partition(group_labeler(groups, cfg.min_dim_factor,
                                   cfg.embedding_min_rows), transforms)


def build_optimizer(cfg: OptimizerConfig) -> GradientTransformation:
    """Build the configured optimizer chain (or, with ``cfg.groups``, the
    partitioned per-group chains).  See module docstring.

    ``cfg.guards`` wraps the OUTERMOST transform — chain or partition —
    in the non-finite skip-step guard, so a tripped step freezes params
    through every stage INCLUDING weight decay (guarding only the
    preconditioner would still let ``add_decayed_weights`` move params on
    a poisoned step)."""
    sched = _schedule_of(cfg)
    mask = _decay_mask_of(cfg)
    if cfg.groups:
        opt = _build_partitioned(cfg, sched, mask)
    else:
        opt = _chain_for(cfg, cfg.name, sched, mask)
    if cfg.guards:
        opt = guard_updates(opt, GuardConfig(
            xi_trip=cfg.guard_xi_trip, max_demotions=cfg.max_demotions))
    return opt
