"""``build_optimizer(OptimizerConfig)`` — the single optimizer-construction
path used by launchers, benchmarks and examples.

Lowers the declarative :class:`repro.config.OptimizerConfig` to the
documented transformation chains in this package:

    adapprox : scale_by_adapprox    -> +wd*W -> *lr_t -> *(-1)
    adamw    : scale_by_adam        -> +wd*W -> *lr_t -> *(-1)
    adafactor: scale_by_factored_rms-> +wd*W -> *lr_t | *alpha_t -> *(-1)
    came     : scale_by_came        -> +wd*W -> *lr_t -> *(-1)

``cfg.decay_mask = "no_1d"`` swaps the decay stage's mask so 1-D leaves
(biases, norm scales) are exempt from weight decay — the standard
production configuration — without forking any optimizer.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.config import OptimizerConfig
from repro.core.adafactor import AdafactorConfig, adafactor
from repro.core.adamw import AdamWConfig, adamw
from repro.core.adapprox import AdapproxConfig, adapprox
from repro.core.came import CAMEConfig, came
from repro.core.rank import RankConfig
from repro.core.transform import resolve_decay_mask
from repro.core.types import GradientTransformation, Schedule, \
    constant_schedule


def _schedule_of(cfg: OptimizerConfig) -> Callable:
    if cfg.schedule == "constant":
        return constant_schedule(cfg.lr)
    if cfg.schedule == "cosine":
        return Schedule(cfg.lr, warmup_steps=cfg.warmup_steps,
                        total_steps=cfg.total_steps, min_lr=cfg.min_lr)
    raise ValueError(f"unknown schedule {cfg.schedule!r} "
                     f"(expected 'cosine' or 'constant')")


def _decay_mask_of(cfg: OptimizerConfig) -> Optional[Callable]:
    return resolve_decay_mask(cfg.decay_mask)


def build_optimizer(cfg: OptimizerConfig) -> GradientTransformation:
    """Build the configured optimizer chain.  See module docstring."""
    sched = _schedule_of(cfg)
    mask = _decay_mask_of(cfg)
    if cfg.name == "adapprox":
        acfg = AdapproxConfig(
            lr=sched, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, clip_d=cfg.clip_d,
            weight_decay=cfg.weight_decay,
            rank=RankConfig(k_init=cfg.k, k_max=cfg.k_max,
                            xi_thresh=cfg.xi_thresh, delta_s=cfg.delta_s,
                            mode=cfg.rank_mode),
            oversample=cfg.oversample, n_iter=cfg.n_iter,
            min_dim_factor=cfg.min_dim_factor, guidance=cfg.guidance,
            implicit=cfg.implicit, use_kernels=cfg.use_kernels,
            factor_dtype=cfg.factor_dtype, seed=cfg.seed,
            refresh_every=cfg.refresh_every, warm_start=cfg.warm_start,
            n_iter_warm=cfg.n_iter_warm, warm_drift_xi=cfg.warm_drift_xi,
            bucketed=cfg.bucketed)
        return adapprox(acfg, decay_mask=mask)
    if cfg.name == "adamw":
        return adamw(AdamWConfig(lr=sched, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
                                 weight_decay=cfg.weight_decay),
                     decay_mask=mask)
    if cfg.name == "adafactor":
        return adafactor(
            AdafactorConfig(lr=sched, b1=cfg.b1, b2=cfg.b2,
                            b2_schedule=cfg.b2_schedule, clip_d=cfg.clip_d,
                            weight_decay=cfg.weight_decay,
                            relative_step=cfg.relative_step,
                            min_dim_factor=cfg.min_dim_factor),
            decay_mask=mask)
    if cfg.name == "came":
        return came(CAMEConfig(lr=sched, b1=cfg.b1, b2=cfg.b2, b3=cfg.b3,
                               clip_d=cfg.clip_d,
                               weight_decay=cfg.weight_decay,
                               min_dim_factor=cfg.min_dim_factor),
                    decay_mask=mask)
    raise ValueError(f"unknown optimizer {cfg.name!r}; "
                     f"available: adapprox, adamw, adafactor, came")
