"""Adaptive rank selection (Algorithm 2 of Adapprox, "AS-RSI").

The paper re-runs S-RSI with a growing rank ``k_t <- k_t + f(xi)`` until the
relative Frobenius error ``xi`` drops below ``xi_thresh``.  Re-running the
sketch is wasteful on TPU (and impossible under jit with dynamic shapes), so
we use an exactly equivalent formulation:

  * S-RSI is run ONCE at the full stored width ``r_store = k_max`` (plus
    oversampling).  Algorithm 1 itself computes ``k + p`` columns and returns
    the first ``k`` — i.e. truncating an oversampled basis IS the paper's own
    truncation scheme, so evaluating ``xi`` at different ``k`` under one
    basis matches the algorithm's semantics with effective oversampling
    ``p' = k_max + p - k_t >= p``.

  * ``xi(k)`` for every ``k`` at once comes from the projection identity
    ``||A - Q_k Q_k^T A||_F^2 = ||A||_F^2 - cum_energy[k]`` (srsi.py), so the
    paper's repeat-loop becomes a scalar ``lax.while_loop`` over a
    precomputed cumulative-energy vector — O(k_max) work instead of a fresh
    O(l m n k) sketch per probe.

The increment function f (Eq. 14) and the stopping rule are reproduced
verbatim; ``select_rank_paper_iteration`` follows the paper's incremental
probe (which can overshoot the minimal k), ``select_rank_exact`` returns the
minimal feasible k (beyond-paper variant, selectable via config).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RankConfig:
    k_init: int = 1
    k_max: int = 128          # resolved per-matrix: min(k_max, 0.25*min(m,n))
    xi_thresh: float = 0.01
    delta_s: int = 10         # re-selection interval (steps)
    # f(xi) = | eta / (exp(omega*xi + phi) + tau) |   (Eq. 14)
    eta: float = 200.0
    omega: float = -10.0
    phi: float = -2.5
    tau: float = -9.0
    mode: str = "paper"       # "paper" | "exact" | "static"


def f_increment(xi: jnp.ndarray, cfg: RankConfig) -> jnp.ndarray:
    """Eq. (14).  With the paper's hyperparameters this is ~22 for all
    xi in (0, 1] — the rank grows in near-constant increments."""
    val = cfg.eta / (jnp.exp(cfg.omega * xi + cfg.phi) + cfg.tau)
    return jnp.abs(val)


def xi_of_k(cum_energy: jnp.ndarray, frob_sq: jnp.ndarray,
            k: jnp.ndarray) -> jnp.ndarray:
    r = cum_energy.shape[0]
    idx = jnp.clip(k - 1, 0, r - 1)
    captured = jnp.where(k > 0, cum_energy[idx], 0.0)
    resid = jnp.maximum(frob_sq - captured, 0.0)
    return jnp.sqrt(resid / (frob_sq + 1e-30))


def select_rank_paper_iteration(cum_energy: jnp.ndarray,
                                frob_sq: jnp.ndarray,
                                cfg: RankConfig,
                                k_max: int) -> jnp.ndarray:
    """Algorithm 2's repeat-loop:  k <- k_init;
    while xi(k) > thresh and k < k_max:  k <- min(k + f(xi), k_max)."""

    def cond(state):
        k, xi = state
        return jnp.logical_and(xi > cfg.xi_thresh, k < k_max)

    def body(state):
        k, xi = state
        inc = jnp.maximum(jnp.round(f_increment(xi, cfg)).astype(jnp.int32), 1)
        k = jnp.minimum(k + inc, k_max)
        return k, xi_of_k(cum_energy, frob_sq, k)

    k0 = jnp.asarray(min(cfg.k_init, k_max), jnp.int32)
    xi0 = xi_of_k(cum_energy, frob_sq, k0)
    k, _ = jax.lax.while_loop(cond, body, (k0, xi0))
    return k


def select_rank_exact(cum_energy: jnp.ndarray, frob_sq: jnp.ndarray,
                      cfg: RankConfig, k_max: int) -> jnp.ndarray:
    """Minimal k with xi(k) <= thresh (searchsorted on the monotone cumsum).

    xi(k) <= t  <=>  cum_energy[k-1] >= ||A||^2 (1 - t^2).
    """
    target = frob_sq * (1.0 - cfg.xi_thresh ** 2)
    k = jnp.searchsorted(cum_energy, target, side="left") + 1
    return jnp.clip(k.astype(jnp.int32), min(cfg.k_init, k_max), k_max)


def select_rank(cum_energy: jnp.ndarray, frob_sq: jnp.ndarray,
                cfg: RankConfig, k_max: int, step: jnp.ndarray,
                k_prev: jnp.ndarray,
                refresh_every: "int | jnp.ndarray" = 1) -> jnp.ndarray:
    """Dispatch on mode; only re-selects when ``step % delta_s == 1``
    (paper: "if (t mod Delta_s) = 1"), otherwise keeps ``k_prev``.

    ``refresh_every``: S-RSI refresh interval of the caller (adapprox's
    amortized-refresh mode).  When > 1, this function is only invoked on
    refresh steps (t = 1, 1+T, 1+2T, ...), so the paper's step-modulo
    condition could desync from the refresh grid and never fire (e.g.
    delta_s = 10, T = 7).  Instead the re-selection cadence is expressed in
    *refresh indices*: re-select every ceil(delta_s / T)-th refresh, which
    preserves delta_s's wall-step meaning.  ``refresh_every = 1`` is
    bit-identical to the paper rule.

    May be a TRACED int32 scalar (adapprox's ``dynamic_refresh`` mode,
    where the closed-loop controller retunes the cadence at runtime): the
    Python two-way dispatch then becomes a ``jnp.where`` select over the
    same two rules, so cadence changes never retrigger compilation.
    """
    if cfg.mode == "static":
        return k_prev
    if cfg.mode == "exact":
        k_new = select_rank_exact(cum_energy, frob_sq, cfg, k_max)
    else:
        k_new = select_rank_paper_iteration(cum_energy, frob_sq, cfg, k_max)
    if isinstance(refresh_every, int):
        if refresh_every <= 1:
            # Paper: refresh when (t mod Delta_s) = 1; the modulo keeps
            # delta_s = 1 meaning "every step".
            refresh = (step % cfg.delta_s) == (1 % cfg.delta_s)
        else:
            period = max(1, -(-cfg.delta_s // refresh_every))   # ceil
            ridx = (step - 1) // refresh_every                   # 0 at t = 1
            refresh = (ridx % period) == 0
    else:
        t = refresh_every
        # ceil(delta_s / T) with traced T; clamp T >= 1 so the amortized
        # rule's divisions stay defined on the (never-taken) T <= 1 side.
        t_safe = jnp.maximum(t, 1)
        period = jnp.maximum(1, -(-cfg.delta_s // t_safe))
        ridx = (step - 1) // t_safe
        refresh = jnp.where(t <= 1,
                            (step % cfg.delta_s) == (1 % cfg.delta_s),
                            (ridx % period) == 0)
    return jnp.where(refresh, k_new, k_prev)


def resolve_k_max(shape: tuple[int, ...], cfg: RankConfig,
                  frac: float = 0.25) -> int:
    """Paper: k_max = 0.25 * min(m, n), further capped by the configured
    storage width."""
    m, n = shape[-2], shape[-1]
    return max(1, min(cfg.k_max, int(frac * min(m, n))))
