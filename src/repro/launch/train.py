"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-117m --smoke \
        --steps 200 --optimizer adapprox --ckpt-dir /tmp/ckpt

``--smoke`` trains the reduced config on CPU; without it the full config is
built (requires real accelerators + the production mesh).  All the
fault-tolerance machinery (atomic async checkpoints, preemption flush,
restart-resume, straggler monitor) is active either way.

Sharded runs: ``--mesh 4,2`` builds a (data=4, model=2) device mesh (three
numbers add a leading DCN ``pod`` axis, one number is pure data
parallelism) and derives param / optimizer-state / batch shardings through
``distributed.sharding.train_shardings`` — optimizer state is sharded
alongside FSDP params (``--fsdp``, default on), which is where Adapprox's
factored-state memory savings actually materialise per device.  On a CPU
host, set ``REPRO_TRAIN_DEVICES=8`` (or export the matching ``XLA_FLAGS``)
to get virtual devices for the mesh.

``--mixed-groups`` (default for adapprox) makes the optimizer a
``partition`` chain with three state families: the count-min sketch on
embedding tables (>= ``--embedding-min-rows`` rows; ``--sketch-width`` /
``--sketch-depth`` size the hashed second moment), Adapprox on factorable
matrices, dense bias-corrected Adam on 1-D/small leaves — per-layer
sensitivity without blanket factorization (Kalra et al., 2025 / Shazeer &
Stern, 2018).

Telemetry: ``--telemetry-dir DIR`` streams per-group optimizer snapshots
(xi / rank / clip activation / refresh counters) and straggler events as
schema-validated JSONL (``repro.telemetry``); ``--auto-refresh`` adds the
closed-loop controller, which adapts each group's S-RSI refresh cadence
from observed xi drift at runtime — the cadence is a traced state scalar,
so retunes never recompile the step.

Tracing: ``--trace-dir DIR`` records host-side span events (data-wait /
dispatch / device-sync / checkpoint phases of every train step, with
refresh-vs-fold attribution) for ``tools/traceview.py``;
``--metrics-every N`` adds periodic counter/histogram snapshots and a
Prometheus text dump at exit.
"""
from __future__ import annotations

import os

if os.environ.get("REPRO_TRAIN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_TRAIN_DEVICES"]
                               + " " + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede the jax import: jax locks the device count on first init.

import argparse
import logging
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig
from repro.config import (OptimizerConfig, TelemetryConfig,
                          default_mixed_groups)
from repro.configs import get_config, get_smoke_config
from repro.core import build_optimizer
from repro.data import DataConfig
from repro.distributed import sharding as SH
from repro.models import build_model
from repro.train import LoopConfig, train

log = logging.getLogger(__name__)


def optimizer_config(name: str, steps: int, lr: float,
                     refresh_every: int = 1, warm_start: bool = False,
                     bucketed: bool = False, fused_update: bool = False,
                     quantize_factors: bool = False,
                     mixed_groups: bool = False, telemetry: bool = False,
                     dynamic_refresh: bool = False,
                     sketch_width: int = 2048, sketch_depth: int = 4,
                     embedding_min_rows: int = 1024,
                     guards: bool = False, guard_xi_trip: float = 0.75,
                     max_demotions: int = 0) -> OptimizerConfig:
    """The launcher's OptimizerConfig: cosine schedule derived from the run
    length, paper-faithful Adapprox adaptive-rank settings.  The amortized-
    refresh knobs (refresh_every / warm_start / bucketed, adapprox only)
    trade a bounded amount of factorization freshness for step time — see
    repro.core's module docstring for the measured curve.  With
    ``mixed_groups`` the adapprox config becomes the production partition
    chain (dense Adam on 1-D/small leaves, Adapprox on matrices)."""
    common = dict(name=name, lr=lr, schedule="cosine",
                  warmup_steps=max(steps // 20, 5), total_steps=steps,
                  min_lr=lr / 6, weight_decay=0.1,
                  groups=default_mixed_groups() if mixed_groups else (),
                  sketch_width=sketch_width, sketch_depth=sketch_depth,
                  embedding_min_rows=embedding_min_rows)
    if name == "adapprox":
        return OptimizerConfig(**common, rank_mode="paper", k=1, k_max=128,
                               xi_thresh=0.01, delta_s=10,
                               min_dim_factor=64, implicit=False,
                               refresh_every=refresh_every,
                               warm_start=warm_start, bucketed=bucketed,
                               fused_update=fused_update,
                               quantize_factors=quantize_factors,
                               telemetry=telemetry,
                               dynamic_refresh=dynamic_refresh,
                               guards=guards, guard_xi_trip=guard_xi_trip,
                               max_demotions=max_demotions)
    if name in ("adamw", "adafactor", "came"):
        # the factored group inherits the family, so --mixed-groups is a
        # matrices/rest split of the SAME optimizer here (dense Adam on
        # the rest group either way)
        return OptimizerConfig(**common)
    raise ValueError(name)


def parse_mesh(spec: str):
    """``"4,2"`` -> (data=4, model=2) mesh; one number -> pure DP
    ``(data,)``; three -> ``(pod, data, model)``."""
    shape = tuple(int(s) for s in spec.split(",") if s)
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}.get(len(shape))
    if axes is None:
        raise ValueError(f"--mesh takes 1-3 comma-separated sizes, "
                         f"got {spec!r}")
    n_dev = len(jax.devices())
    need = 1
    for s in shape:
        need *= s
    if need > n_dev:
        raise ValueError(
            f"--mesh {spec} needs {need} devices but only {n_dev} are "
            f"visible; set REPRO_TRAIN_DEVICES={need} for virtual CPU "
            f"devices")
    return jax.make_mesh(shape, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-117m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adapprox")
    ap.add_argument("--refresh-every", type=int, default=1,
                    help="adapprox: full S-RSI every T steps (fold between)")
    ap.add_argument("--warm-start", action="store_true",
                    help="adapprox: warm-start S-RSI from the stored U")
    ap.add_argument("--bucketed", action="store_true",
                    help="adapprox: one vmapped trace per same-shape bucket")
    ap.add_argument("--fused-update", action="store_true",
                    help="adapprox: two-pass fused elementwise tail "
                         "(kernels/fused_update.py on TPU)")
    ap.add_argument("--quantize-factors", action="store_true",
                    help="adapprox: store the (Q, U) factors as int8 with "
                         "per-block scale/zero (core/quantized.py, ~4x "
                         "smaller factor state); with --fused-update the "
                         "dequant fuses into the pass-1 tile loads")
    ap.add_argument("--mesh", default=None,
                    help="device mesh sizes, e.g. '4,2' = (data=4, model=2);"
                         " omit for the single-device path")
    fsdp = ap.add_mutually_exclusive_group()
    fsdp.add_argument("--fsdp", dest="fsdp", action="store_true",
                      default=True,
                      help="shard params + optimizer state over the data "
                           "axis (default)")
    fsdp.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    mg = ap.add_mutually_exclusive_group()
    mg.add_argument("--mixed-groups", dest="mixed_groups",
                    action="store_true", default=None,
                    help="partition chain: dense Adam on 1-D/small leaves, "
                         "adapprox on matrices (default for adapprox)")
    mg.add_argument("--no-mixed-groups", dest="mixed_groups",
                    action="store_false")
    ap.add_argument("--sketch-width", type=int, default=2048,
                    help="count-min sketch buckets per hash for the "
                         "embeddings group (--mixed-groups)")
    ap.add_argument("--sketch-depth", type=int, default=4,
                    help="count-min sketch hash functions (min-over-depth)")
    ap.add_argument("--embedding-min-rows", type=int, default=1024,
                    help="leading-dim threshold for the embeddings group: "
                         ">= 2-D leaves with at least this many rows take "
                         "the sketch second moment")
    ap.add_argument("--telemetry-dir", default=None,
                    help="stream optimizer/straggler telemetry as JSONL "
                         "events here (repro.telemetry schema)")
    ap.add_argument("--telemetry-every", type=int, default=1,
                    help="emit optimizer events every N steps")
    ap.add_argument("--auto-refresh", action="store_true",
                    help="adapprox: closed-loop controller retunes "
                         "refresh_every per group from observed xi drift "
                         "(implies in-jit telemetry + dynamic cadence)")
    ap.add_argument("--guards", action="store_true",
                    help="resilience: wrap the chain in the non-finite "
                         "skip-step guard and arm the per-leaf xi watchdog "
                         "(repro.resilience; default off — guards-off runs "
                         "are bitwise identical to builds without them)")
    ap.add_argument("--guard-skip-threshold", type=float, default=0.75,
                    help="xi level that counts as a factorization blow-up "
                         "(forces a full S-RSI refresh for that leaf)")
    ap.add_argument("--max-demotions", type=int, default=0,
                    help="consecutive xi trips before a leaf is demoted to "
                         "the exact dense second moment (0 = never demote, "
                         "forced refreshes only)")
    ap.add_argument("--trace-dir", default=None,
                    help="record host-side kind=\"span\" timing events "
                         "(train-step phases, checkpoint IO) here as "
                         "JSONL — analyse with tools/traceview.py; may "
                         "equal --telemetry-dir to share one stream")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="emit a kind=\"metric\" registry snapshot every "
                         "N steps (0 = off); a Prometheus text dump is "
                         "written to <trace-dir>/metrics.prom at exit")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(levelname)s %(message)s")
    mixed = (args.optimizer == "adapprox" if args.mixed_groups is None
             else args.mixed_groups)
    cfg = (get_smoke_config(args.arch, max_seq_len=args.seq)
           if args.smoke else get_config(args.arch))
    mesh = parse_mesh(args.mesh) if args.mesh else None
    model = build_model(cfg, mesh)
    telemetry_on = args.telemetry_dir is not None or args.auto_refresh
    opt = build_optimizer(optimizer_config(
        args.optimizer, args.steps, args.lr,
        refresh_every=args.refresh_every, warm_start=args.warm_start,
        bucketed=args.bucketed, fused_update=args.fused_update,
        quantize_factors=args.quantize_factors,
        mixed_groups=mixed, telemetry=telemetry_on,
        dynamic_refresh=args.auto_refresh,
        sketch_width=args.sketch_width, sketch_depth=args.sketch_depth,
        embedding_min_rows=args.embedding_min_rows,
        guards=args.guards, guard_xi_trip=args.guard_skip_threshold,
        max_demotions=args.max_demotions))
    runtime = None
    if telemetry_on:
        from repro.telemetry import TelemetryRuntime
        runtime = TelemetryRuntime(TelemetryConfig(
            enabled=True, dir=args.telemetry_dir,
            emit_every=args.telemetry_every,
            auto_refresh=args.auto_refresh))
        log.info("telemetry on (dir=%s, auto_refresh=%s)",
                 args.telemetry_dir, args.auto_refresh)
    tracer = None
    trace_sink = None        # sink this launcher owns (closed at exit)
    reg = None
    if args.trace_dir is not None:
        from repro.telemetry import MetricsRegistry, SinkConfig, \
            TelemetrySink, Tracer
        reg = MetricsRegistry()
        if runtime is not None and args.trace_dir == args.telemetry_dir:
            span_sink = runtime.sink   # one dir -> one shared stream
        else:
            trace_sink = span_sink = TelemetrySink(
                SinkConfig(directory=args.trace_dir))
        tracer = Tracer(sink=span_sink, registry=reg)
        log.info("tracing on (dir=%s, metrics_every=%d)",
                 args.trace_dir, args.metrics_every)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)

    state_shardings = batch_shardings = None
    if mesh is not None:
        model.constrain = SH.make_act_constrainer(mesh, "train")
        batch_struct = {"tokens": jax.ShapeDtypeStruct(
            (args.batch, args.seq), jnp.int32)}
        state_shardings, batch_shardings = SH.train_shardings(
            model, opt, mesh, batch_struct, fsdp=args.fsdp)
        log.info("mesh %s, fsdp=%s, mixed_groups=%s",
                 dict(mesh.shape), args.fsdp, mixed)

    ckpt = (CheckpointConfig(directory=args.ckpt_dir,
                             save_every=args.ckpt_every)
            if args.ckpt_dir else None)
    try:
        state, history = train(
            model, opt, data_cfg,
            LoopConfig(total_steps=args.steps, log_every=args.log_every,
                       ckpt=ckpt),
            state_shardings=state_shardings,
            batch_shardings=batch_shardings,
            telemetry=runtime,
            tracer=tracer,
            metrics_every=args.metrics_every,
            install_signal_handler=ckpt is not None)
    finally:
        if runtime is not None:
            runtime.close()
        if tracer is not None:
            tracer.flush()
            if trace_sink is not None:
                trace_sink.close()
            prom = Path(args.trace_dir) / "metrics.prom"
            prom.write_text(reg.render())
            log.info("trace events + %s written under %s",
                     prom.name, args.trace_dir)
    if history:
        print(f"final loss: {history[-1]['loss']:.4f} "
              f"({history[-1]['step_time_s'] * 1e3:.0f} ms/step)")
    return state


if __name__ == "__main__":
    main()
