"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-117m --smoke \
        --steps 200 --optimizer adapprox --ckpt-dir /tmp/ckpt

``--smoke`` trains the reduced config on CPU; without it the full config is
built (requires real accelerators + the production mesh).  All the
fault-tolerance machinery (atomic async checkpoints, preemption flush,
restart-resume, straggler monitor) is active either way.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.checkpoint import CheckpointConfig
from repro.config import OptimizerConfig
from repro.configs import get_config, get_smoke_config
from repro.core import build_optimizer
from repro.data import DataConfig
from repro.models import build_model
from repro.train import LoopConfig, train


def optimizer_config(name: str, steps: int, lr: float,
                     refresh_every: int = 1, warm_start: bool = False,
                     bucketed: bool = False) -> OptimizerConfig:
    """The launcher's OptimizerConfig: cosine schedule derived from the run
    length, paper-faithful Adapprox adaptive-rank settings.  The amortized-
    refresh knobs (refresh_every / warm_start / bucketed, adapprox only)
    trade a bounded amount of factorization freshness for step time — see
    repro.core's module docstring for the measured curve."""
    common = dict(name=name, lr=lr, schedule="cosine",
                  warmup_steps=max(steps // 20, 5), total_steps=steps,
                  min_lr=lr / 6, weight_decay=0.1)
    if name == "adapprox":
        return OptimizerConfig(**common, rank_mode="paper", k=1, k_max=128,
                               xi_thresh=0.01, delta_s=10,
                               min_dim_factor=64, implicit=False,
                               refresh_every=refresh_every,
                               warm_start=warm_start, bucketed=bucketed)
    if name in ("adamw", "adafactor", "came"):
        return OptimizerConfig(**common)
    raise ValueError(name)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-117m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adapprox")
    ap.add_argument("--refresh-every", type=int, default=1,
                    help="adapprox: full S-RSI every T steps (fold between)")
    ap.add_argument("--warm-start", action="store_true",
                    help="adapprox: warm-start S-RSI from the stored U")
    ap.add_argument("--bucketed", action="store_true",
                    help="adapprox: one vmapped trace per same-shape bucket")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(levelname)s %(message)s")
    cfg = (get_smoke_config(args.arch, max_seq_len=args.seq)
           if args.smoke else get_config(args.arch))
    model = build_model(cfg)
    opt = build_optimizer(optimizer_config(
        args.optimizer, args.steps, args.lr,
        refresh_every=args.refresh_every, warm_start=args.warm_start,
        bucketed=args.bucketed))
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    ckpt = (CheckpointConfig(directory=args.ckpt_dir,
                             save_every=args.ckpt_every)
            if args.ckpt_dir else None)
    state, history = train(
        model, opt, data_cfg,
        LoopConfig(total_steps=args.steps, log_every=args.log_every,
                   ckpt=ckpt),
        install_signal_handler=ckpt is not None)
    if history:
        print(f"final loss: {history[-1]['loss']:.4f} "
              f"({history[-1]['step_time_s'] * 1e3:.0f} ms/step)")
    return state


if __name__ == "__main__":
    main()
