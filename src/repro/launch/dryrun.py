import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           + " " + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax locks the device count on first
#   init.  Tests/benches never import this module, so they keep 1 device.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x shape-cell x mesh) combination:
  * build the full-size config, production mesh and sharded step function,
  * ``jax.jit(step).lower(*ShapeDtypeStructs).compile()``  — proving the
    distribution config is coherent (sharding consistency, collective
    legality, padding) without allocating a single array,
  * record ``memory_analysis()`` (fits-or-not per chip),
    ``cost_analysis()`` (FLOPs / bytes for the roofline) and the collective
    mix parsed from the post-SPMD HLO,
  * write one JSON artifact per cell under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --cell train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  REPRO_DRYRUN_DEVICES=8 python -m repro.launch.dryrun --preset test

``--telemetry-dir DIR`` additionally streams one ``kind="dryrun_cell"``
JSONL event per compiled cell (plus a ``run_meta`` header) through the
shared ``repro.telemetry`` sink — the same stream/schema the training
telemetry uses, so CI can validate the event pipeline without running a
training step (``python -m repro.telemetry.validate DIR``).
``--trace-dir DIR`` adds per-cell ``compile_cell``/``lower``/``compile``
spans and (with ``--metrics-every``) registry snapshots for
``tools/traceview.py``.
"""
import argparse
import collections
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.config import CELLS, OptimizerConfig, applicable_cells
from repro.configs import ASSIGNED, get_config, get_smoke_config, input_specs
from repro.core import build_optimizer
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import build_model
from repro.telemetry.trace import NULL_TRACER
from repro.train.steps import TrainState, build_train_step

DEFAULT_OUT = Path("experiments/dryrun")


# --------------------------------------------------------------------------
# Optimizer used for train cells (the paper's technique, production config)
# --------------------------------------------------------------------------

def dryrun_opt_config(arch: str) -> OptimizerConfig:
    # b1=0 for the 1T model: the full first moment alone would be 2-4 TB
    # (paper Table 2's beta1=0 row is exactly this regime).
    b1 = 0.0 if arch.startswith("kimi") else 0.9
    return OptimizerConfig(
        name="adapprox", lr=3e-4, schedule="cosine", warmup_steps=1000,
        total_steps=100_000, min_lr=0.0, b1=b1, b2=0.999, weight_decay=0.1,
        k=64, rank_mode="static", oversample=5, n_iter=5,
        min_dim_factor=128, implicit=True)


def dryrun_optimizer(arch: str):
    return build_optimizer(dryrun_opt_config(arch))


def microbatches_for(arch: str, cell: str, mesh=None,
                     global_batch: int = 256) -> int:
    if cell != "train_4k":
        return 1
    # activation-memory control: global batch 256 -> per-chip microbatch
    if arch in FSDP_TRAIN_ARCHS:
        return 1          # B == device count: 1 sequence per chip
    mb = {"deepseek-67b": 16, "kimi-k2-1t-a32b": 16,
          "qwen3-14b": 8}.get(arch, 4)
    if mesh is not None:
        # each microbatch must still cover every data shard
        dp = 1
        for a in ("pod", "data"):
            dp *= mesh.shape.get(a, 1)
        mb = min(mb, max(global_batch // dp, 1))
    return mb


# --------------------------------------------------------------------------
# Collective parsing from post-SPMD HLO
# --------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^(]*\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device link-byte estimate per collective kind.

    all-gather: receives ~out_bytes; all-reduce: ~2x bytes (ring);
    reduce-scatter: receives ~out_bytes * group_size (ring reduce);
    all-to-all / collective-permute: ~out_bytes.
    """
    out = collections.defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.groups()
        nbytes = _shape_bytes(type_str)
        gsize = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            gsize = mg.group(1).count(",") + 1
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                gsize = int(mi.group(2))
        if kind == "all-reduce":
            link = 2 * nbytes
        elif kind == "reduce-scatter":
            link = nbytes * max(gsize - 1, 1)
        else:
            link = nbytes
        out[kind]["count"] += 1
        out[kind]["bytes"] += link
    return dict(out)


# --------------------------------------------------------------------------
# Cell lowering
# --------------------------------------------------------------------------

# Hillclimbed per-arch parallel strategy for train cells (EXPERIMENTS.md
# §Perf): pure FSDP (ZeRO-3) eliminates Megatron activation all-reduces and
# cut the dominant roofline term 4-6x on these dense archs while fitting
# 16 GB HBM.  deepseek-67b / qwen3-14b peak >16 GiB under FSDP at 1 seq/chip
# (31 / 29 GiB) so they keep the TP x FSDP hybrid (fits, slower) — the
# FSDP-optimal variants are recorded separately in experiments/perf/.
FSDP_TRAIN_ARCHS = {"qwen2-7b", "minitron-4b", "llava-next-mistral-7b",
                    "mamba2-370m", "zamba2-2.7b", "whisper-large-v3"}


def build_cell(arch: str, cell_name: str, mesh, smoke: bool = False):
    """Returns (jitted_fn, arg_structs) ready to lower."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    cell = CELLS[cell_name]
    if (not smoke and cell.kind == "train" and arch in FSDP_TRAIN_ARCHS):
        import dataclasses as _dc
        cfg = _dc.replace(cfg, parallel_strategy="fsdp")
    if smoke:
        import dataclasses as _dc
        cell = _dc.replace(cell, seq_len=64,
                           global_batch=max(4, len(mesh.devices.flat) // 2))
    model = build_model(cfg, mesh)
    kind = cell.kind
    if kind == "decode" and cfg.moe is not None:
        model.moe_mode = "decode"
    model.constrain = SH.make_act_constrainer(
        mesh, kind, long_context=(cell_name == "long_500k"),
        all_axes_batch=(getattr(cfg, "parallel_strategy", "tp") == "fsdp"))

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = SH.param_shardings(model, mesh, kind)
    pspecs = SH.param_pspecs(model, mesh, kind)
    batch_struct = input_specs(cfg, cell) if not smoke else input_specs(
        cfg, cell)
    bshard = SH.batch_shardings(cfg, kind, mesh, batch_struct)

    if kind == "train":
        opt = dryrun_optimizer(arch)
        state_struct = jax.eval_shape(
            lambda p: TrainState.create(p, opt), params_struct)
        oshard = SH.opt_state_shardings(opt, state_struct.opt_state,
                                        pspecs, mesh)
        sshard = TrainState(params=pshard, opt_state=oshard,
                            step=jax.sharding.NamedSharding(
                                mesh, jax.sharding.PartitionSpec()))
        step = build_train_step(model, opt,
                                microbatches=microbatches_for(
                                    arch, cell_name, mesh,
                                    cell.global_batch) if not smoke else 1)
        fn = jax.jit(step, in_shardings=(sshard, bshard),
                     donate_argnums=(0,))
        return fn, (state_struct, batch_struct), cfg, cell

    long_ctx = cell_name == "long_500k"
    cache_struct = jax.eval_shape(
        lambda: model.init_cache(cell.global_batch, cell.seq_len))
    cshard = SH.cache_shardings(cfg, mesh, cache_struct, long_ctx)

    if kind == "prefill":
        if cfg.family in ("encdec",):
            def step(params, cache, batch):
                return model.prefill(params, batch["tokens"], cache,
                                     embeds=batch["embeds"])
        elif cfg.family == "vlm":
            def step(params, cache, batch):
                return model.prefill(params, batch["tokens"], cache,
                                     embeds=batch["embeds"])
        else:
            def step(params, cache, batch):
                return model.prefill(params, batch["tokens"], cache)
        fn = jax.jit(step, in_shardings=(pshard, cshard, bshard),
                     donate_argnums=(1,))
        return fn, (params_struct, cache_struct, batch_struct), cfg, cell

    # decode
    def step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    tok_struct = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
    tshard = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(
            SH.dp_axes(mesh) if not long_ctx else None, None))
    fn = jax.jit(step, in_shardings=(pshard, cshard, tshard),
                 donate_argnums=(1,))
    return fn, (params_struct, cache_struct, tok_struct), cfg, cell


def run_cell(arch: str, cell_name: str, multi_pod: bool, out_dir: Path,
             smoke: bool = False, force: bool = False,
             mesh_override=None, tracer=None) -> dict:
    mesh_tag = "multipod" if multi_pod else "pod"
    out_path = out_dir / f"{arch}__{cell_name}__{mesh_tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    tr = tracer if tracer is not None else NULL_TRACER
    with tr.span("compile_cell", arch=arch, cell=cell_name, mesh=mesh_tag):
        t0 = time.time()
        mesh = mesh_override or make_production_mesh(multi_pod=multi_pod)
        n_dev = len(mesh.devices.flat)
        fn, structs, cfg, cell = build_cell(arch, cell_name, mesh,
                                            smoke=smoke)

        with tr.span("lower"):
            lowered = fn.lower(*structs)
        t_lower = time.time() - t0
        with tr.span("compile"):
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # jax moved from list[dict] (one per program) to a flat dict; accept both
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    # Loop-aware accounting: XLA's cost_analysis counts while bodies once
    # (scan-over-layers would be undercounted ~L x microbatches times).
    from repro.launch.hlo_cost import parse_hlo_costs
    walker = parse_hlo_costs(hlo_text)
    colls = {k: dict(v) for k, v in walker.coll.items()}

    rec = {
        "arch": arch, "cell": cell_name, "mesh": mesh_tag,
        "devices": n_dev,
        "mesh_shape": dict(mesh.shape),
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "kind": cell.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "flops": float(walker.flops),
        "bytes_accessed": float(walker.bytes),
        "flops_xla_raw": float(cost.get("flops", 0.0)),
        "bytes_xla_raw": float(cost.get("bytes accessed", 0.0)),
        "collectives": colls,
        "collective_bytes": sum(v["bytes"] for v in colls.values()),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            # args + temps - aliased(donated): resident per-chip bytes.
            # (peak_memory_in_bytes covers temps only on the CPU backend.)
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "output_size_in_bytes", 0) or 0)
            - (getattr(mem, "alias_size_in_bytes", 0) or 0),
        },
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


SKIPS = {}  # (arch, cell) -> reason, filled below


def plan(archs, cells):
    for arch in archs:
        cfg = get_config(arch)
        ok = applicable_cells(cfg)
        for cell in cells:
            if cell not in ok:
                SKIPS[(arch, cell)] = ("full-attention arch: long_500k "
                                       "needs sub-quadratic attention")
                continue
            yield arch, cell


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multi", "both"])
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--preset", default=None, choices=[None, "test"])
    ap.add_argument("--telemetry-dir", default=None,
                    help="emit one dryrun_cell JSONL event per compiled "
                         "cell (repro.telemetry schema)")
    ap.add_argument("--trace-dir", default=None,
                    help="record per-cell compile_cell/lower/compile "
                         "spans as kind=\"span\" JSONL for "
                         "tools/traceview.py; may equal --telemetry-dir "
                         "to share one stream")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="with --trace-dir: emit a kind=\"metric\" "
                         "registry snapshot every N compiled cells "
                         "(0 = only the final snapshot)")
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    smoke = args.preset == "test"

    sink = None
    if args.telemetry_dir:
        from repro.telemetry import SinkConfig, TelemetrySink
        sink = TelemetrySink(SinkConfig(directory=args.telemetry_dir))
        sink.emit({"kind": "run_meta", "source": "launch.dryrun",
                   "argv": list(argv) if argv is not None else sys.argv[1:]})

    tracer = None
    trace_sink = None        # sink this driver owns (closed at exit)
    reg = None
    run_t0 = time.time()
    if args.trace_dir:
        from repro.telemetry import (MetricsRegistry, SinkConfig,
                                     TelemetrySink, Tracer)
        reg = MetricsRegistry()
        if sink is not None and args.trace_dir == args.telemetry_dir:
            span_sink = sink     # one dir -> one shared stream
        else:
            trace_sink = span_sink = TelemetrySink(
                SinkConfig(directory=args.trace_dir))
        tracer = Tracer(sink=span_sink, registry=reg)

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    cells = list(CELLS) if args.cell == "all" else args.cell.split(",")
    meshes = {"pod": [False], "multi": [True], "both": [False, True]}[
        args.mesh]

    mesh_override = None
    if smoke:
        n = len(jax.devices())
        mesh_override = make_test_mesh((max(n // 4, 1), 2, 2),
                                       ("pod", "data", "model"))

    failures = []
    compiled_cells = 0
    for arch, cell in plan(archs, cells):
        for mp in meshes:
            tag = f"{arch} x {cell} x {'multipod' if mp else 'pod'}"
            try:
                rec = run_cell(arch, cell, mp, out_dir, smoke=smoke,
                               force=args.force,
                               mesh_override=mesh_override, tracer=tracer)
                compiled_cells += 1
                if reg is not None:
                    reg.counter("dryrun_cells_total",
                                help="compiled dry-run cells").inc(
                                    1, cell=rec["cell"], mesh=rec["mesh"])
                    reg.histogram("dryrun_compile_seconds",
                                  help="per-cell compile time").observe(
                                      float(rec.get("compile_s", 0.0)))
                    if (sink is not None or trace_sink is not None) and \
                            args.metrics_every > 0 and \
                            compiled_cells % args.metrics_every == 0:
                        (trace_sink or sink).emit(reg.snapshot(
                            t_s=time.time() - run_t0))
                peak = rec["memory"]["peak_bytes"] or 0
                if sink is not None:
                    sink.emit({
                        "kind": "dryrun_cell", "arch": rec["arch"],
                        "cell": rec["cell"], "mesh": rec["mesh"],
                        "devices": rec["devices"],
                        "flops": float(rec["flops"]),
                        "bytes_accessed": float(rec["bytes_accessed"]),
                        "peak_bytes": float(peak),
                        "collective_bytes": float(rec["collective_bytes"]),
                        "compile_s": float(rec.get("compile_s", 0.0)),
                        "params": float(rec["params"])})
                print(f"OK   {tag}: flops/dev={rec['flops']:.3g} "
                      f"coll={rec['collective_bytes']:.3g}B "
                      f"peak={peak / 2**30:.2f}GiB "
                      f"(compile {rec.get('compile_s', 0)}s)", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((tag, e))
                traceback.print_exc()
                print(f"FAIL {tag}: {e}", flush=True)
    if tracer is not None:
        final_sink = trace_sink if trace_sink is not None else sink
        if final_sink is not None:
            final_sink.emit(reg.snapshot(t_s=time.time() - run_t0))
        tracer.flush()
        (Path(args.trace_dir) / "metrics.prom").write_text(reg.render())
    if sink is not None:
        sink.close()
        print(f"telemetry: {len(sink.paths())} event file(s) under "
              f"{args.telemetry_dir}")
    if trace_sink is not None:
        trace_sink.close()
        print(f"trace: {len(trace_sink.paths())} event file(s) under "
              f"{args.trace_dir}")
    for (a, c), why in SKIPS.items():
        if a in archs and c in cells:
            print(f"SKIP {a} x {c}: {why}")
    if failures:
        print(f"\n{len(failures)} FAILURES")
        sys.exit(1)
    print("\nall dry-run cells compiled")


if __name__ == "__main__":
    main()
