"""Serving driver CLI: batched greedy generation, wave or continuous.

    # wave (lock-step) baseline
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --requests 6 --max-new 16

    # continuous batching over the paged KV cache, with telemetry
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-117m --smoke \
        --continuous --block-size 16 --slots 4 --telemetry-dir /tmp/serve
"""
from __future__ import annotations

import argparse
import statistics
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.serve import (ContinuousConfig, ContinuousEngine, Engine,
                         Request, ServeConfig)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching + paged KV cache (instead "
                         "of the lock-step wave scheduler)")
    ap.add_argument("--paged", action="store_true",
                    help="alias for --continuous (the paged cache only "
                         "exists under the continuous scheduler)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV block size (tokens) for the paged cache")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size in blocks (default: full span "
                         "for every slot)")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="max prompt tokens prefilled per engine step")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue (0 = unbounded); "
                         "arrivals past it are load-shed")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop decoding a sequence at this token id")
    ap.add_argument("--seed", type=int, default=0,
                    help="prompt RNG seed")
    ap.add_argument("--telemetry-dir", default=None,
                    help="stream kind=\"serve\" JSONL events here")
    ap.add_argument("--trace-dir", default=None,
                    help="record per-request span waterfalls "
                         "(queued/admitted/prefill/decode) as "
                         "kind=\"span\" JSONL for tools/traceview.py; "
                         "may equal --telemetry-dir to share one stream")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="with --trace-dir: emit a kind=\"metric\" "
                         "registry snapshot every N engine steps (waves "
                         "for the wave scheduler; 0 = only the "
                         "metrics.prom dump at exit)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    sink = None
    if args.telemetry_dir is not None:
        from repro.telemetry import SinkConfig, TelemetrySink
        sink = TelemetrySink(SinkConfig(directory=args.telemetry_dir))

    tracer = None
    trace_sink = None        # sink this launcher owns (closed at exit)
    reg = None
    if args.trace_dir is not None:
        from repro.telemetry import (MetricsRegistry, SinkConfig,
                                     TelemetrySink, Tracer)
        reg = MetricsRegistry()
        if sink is not None and args.trace_dir == args.telemetry_dir:
            span_sink = sink     # one dir -> one shared stream
        else:
            trace_sink = span_sink = TelemetrySink(
                SinkConfig(directory=args.trace_dir))
        tracer = Tracer(sink=span_sink, registry=reg)
        if sink is None:
            sink = span_sink     # serve events join the span stream

    continuous = args.continuous or args.paged
    if continuous:
        engine = ContinuousEngine(model, params, ContinuousConfig(
            slots=args.slots, cache_len=args.cache_len,
            block_size=args.block_size, num_blocks=args.num_blocks,
            prefill_chunk=args.prefill_chunk, eos_id=args.eos_id,
            max_queue=args.max_queue), sink=sink, tracer=tracer)
    else:
        engine = Engine(model, params, ServeConfig(
            slots=args.slots, cache_len=args.cache_len,
            eos_id=args.eos_id), sink=sink, tracer=tracer)
    engine.metrics_every = args.metrics_every

    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=args.prompt_len)
                    .astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    if tracer is not None:
        tracer.flush()
    if sink is not None:
        sink.flush()
        sink.close()
    if trace_sink is not None and trace_sink is not sink:
        trace_sink.close()
    if reg is not None:
        (Path(args.trace_dir) / "metrics.prom").write_text(reg.render())
    total_tokens = sum(len(r.out_tokens) for r in reqs)
    ttfts = [r.first_token_s - r.arrival_s for r in reqs
             if r.first_token_s is not None]
    sched = (f"{engine.steps} steps" if continuous
             else f"{engine.waves} waves")
    print(f"{len(reqs)} requests ({'continuous' if continuous else 'wave'},"
          f" {sched}), {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s), "
          f"mean ttft {statistics.mean(ttfts) * 1e3:.1f}ms"
          if ttfts else f"{len(reqs)} requests, no tokens emitted")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}")


if __name__ == "__main__":
    main()
