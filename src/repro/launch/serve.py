"""Serving driver CLI: batched greedy generation with the wave engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(slots=args.slots,
                                               cache_len=args.cache_len))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in reqs)
    print(f"{len(reqs)} requests in {engine.waves} waves, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}")


if __name__ == "__main__":
    main()
