"""HLO cost walker: loop-aware FLOPs / HBM-bytes / collective-bytes.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
a scan of 10 matmuls reports exactly 1/10 of the unrolled flops), which
breaks roofline math for scan-over-layers programs.  This walker parses the
post-SPMD HLO text, recursively evaluates per-computation costs, and
multiplies while bodies by their trip counts (recovered from the loop
condition's ``compare(..., constant(N))`` pattern — the canonical scan
lowering).

Costs counted:
  * flops: dot / convolution 2*M*N*K; elementwise ops 1 flop/elem (cheap
    relative to dots; included for completeness);
  * bytes: operands + outputs of dots, elementwise fusions and
    copies/transposes — an upper-ish proxy for HBM traffic;
  * collectives: link-byte estimates per kind x trip count.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[\w]+\[[^\]]*\][^\s]*))\s+"
    r"([\w\-]+)\(")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(type_str: str):
    """-> (total_bytes, total_elems) over all array shapes in the string."""
    nbytes = elems = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes, elems


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0, "bytes": 0}))
    # byte attribution per (opcode, out-type) — the dry-run "profile"
    by_site: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k]["count"] += v["count"] * mult
            self.coll[k]["bytes"] += v["bytes"] * mult
        for k, v in other.by_site.items():
            self.by_site[k] += v * mult

    def top_sites(self, n: int = 12):
        return sorted(self.by_site.items(), key=lambda kv: -kv[1])[:n]


_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _operand_bytes(line: str, symtab: dict) -> int:
    paren = line.index("(")
    end = line.find("), ", paren)
    args = line[paren:end if end > 0 else None]
    total = 0
    for nm in _OPERANDS_RE.findall(args):
        ent = symtab.get(nm)
        if ent is not None:
            dims, dtb = ent
            n = 1
            for d in dims:
                n *= d
            total += n * dtb
    return total


def _dot_flops(line: str, out_elems: int, symtab: dict) -> float:
    """2 * prod(out dims) * prod(contracting dims of lhs).  Operand shapes
    come from the symbol table (scheduled HLO does not inline them)."""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    paren = line.index("(")
    names = _OPERANDS_RE.findall(line[paren:])
    if not names or names[0] not in symtab:
        return 2.0 * out_elems  # unknown contraction; floor at elementwise
    lhs_dims = symtab[names[0]][0]
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def parse_hlo_costs(hlo_text: str) -> Cost:
    # 1. split into computations
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            current = hdr.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
                continue
            comps[current].append(line)

    # symbol table: op name -> output dims (arrays only)
    symtab: dict[str, list[int]] = {}
    for lines in comps.values():
        for ln in lines:
            m = _OP_RE.match(ln)
            if not m:
                continue
            name, type_str, _ = m.groups()
            shapes = _SHAPE_RE.findall(type_str)
            if len(shapes) == 1:
                dt, dims = shapes[0]
                symtab[name] = ([int(d) for d in dims.split(",") if d],
                                _DTYPE_BYTES.get(dt, 4))

    # constants per computation (for trip counts)
    def trip_count(cond_name: str) -> float:
        lines = comps.get(cond_name, [])
        for ln in lines:
            mc = _CONST_RE.search(ln)
            if mc:
                return float(mc.group(1))
            cm = _CALLS_RE.search(ln)
            if cm:
                sub = trip_count(cm.group(1))
                if sub > 1:
                    return sub
        return 1.0

    memo: dict[str, Cost] = {}
    visiting: set = set()

    def eval_comp(name: str) -> Cost:
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps:
            return Cost()
        visiting.add(name)
        total = Cost()
        for ln in comps[name]:
            m = _OP_RE.match(ln)
            if not m:
                continue
            _, type_str, opcode = m.groups()
            out_bytes, out_elems = _shape_info(type_str)

            if opcode == "while":
                mb, mc = _BODY_RE.search(ln), _COND_RE.search(ln)
                if mb:
                    trips = trip_count(mc.group(1)) if mc else 1.0
                    total.add(eval_comp(mb.group(1)), trips)
                    if mc:
                        total.add(eval_comp(mc.group(1)), trips)
                continue
            if opcode in ("fusion", "call", "conditional", "map",
                          "custom-call", "reduce", "sort", "scatter"):
                # Inner ops of a fusion never touch HBM: take their flops
                # and collectives, but bill bytes as operands + output only.
                for sub in _CALLS_RE.findall(ln):
                    sub_cost = eval_comp(sub)
                    total.flops += sub_cost.flops
                    for k, v in sub_cost.coll.items():
                        total.coll[k]["count"] += v["count"]
                        total.coll[k]["bytes"] += v["bytes"]
                fb = out_bytes + _operand_bytes(ln, symtab)
                total.bytes += fb
                total.by_site[f"fusion {type_str[:48]}"] += fb
                continue
            if opcode in COLLECTIVES:
                gsize = 1
                mg = _GROUPS_RE.search(ln)
                if mg:
                    gsize = mg.group(1).count(",") + 1
                else:
                    mi = _GROUPS_IOTA_RE.search(ln)
                    if mi:
                        gsize = int(mi.group(2))
                if opcode == "all-reduce":
                    link = 2 * out_bytes
                elif opcode == "reduce-scatter":
                    link = out_bytes * max(gsize - 1, 1)
                else:
                    link = out_bytes
                total.coll[opcode]["count"] += 1
                total.coll[opcode]["bytes"] += link
                total.bytes += out_bytes
                continue
            if opcode in ("dot", "convolution"):
                total.flops += _dot_flops(ln, out_elems, symtab)
                db = out_bytes + _operand_bytes(ln, symtab)
                total.bytes += db
                total.by_site[f"dot {type_str[:48]}"] += db
                continue
            if opcode in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "iota"):
                continue
            # generic elementwise / copy / transpose / select etc.
            total.flops += out_elems
            total.bytes += out_bytes
        visiting.discard(name)
        memo[name] = total
        return total

    # entry computation: the one named like ENTRY — find via text
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    if m:
        entry = m.group(1)
    if entry and entry in comps:
        return eval_comp(entry)
    # fallback: max-cost computation
    best = Cost()
    for name in comps:
        c = eval_comp(name)
        if c.flops > best.flops:
            best = c
    return best
