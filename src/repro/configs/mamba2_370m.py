"""Config module for mamba2-370m (see archs.py for the exact assignment spec)."""
from repro.configs.archs import MAMBA2_370M as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("mamba2-370m", **over)
