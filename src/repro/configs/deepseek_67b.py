"""Config module for deepseek-67b (see archs.py for the exact assignment spec)."""
from repro.configs.archs import DEEPSEEK_67B as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("deepseek-67b", **over)
