"""Config module for qwen2-7b (see archs.py for the exact assignment spec)."""
from repro.configs.archs import QWEN2_7B as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("qwen2-7b", **over)
