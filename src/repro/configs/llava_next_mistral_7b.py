"""Config module for llava-next-mistral-7b (see archs.py for the exact assignment spec)."""
from repro.configs.archs import LLAVA_NEXT_MISTRAL_7B as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("llava-next-mistral-7b", **over)
