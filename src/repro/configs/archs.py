"""The 10 assigned architectures (+ the paper's own GPT-2 configs), exact
per the assignment sheet.  Every entry has a ``smoke`` reduced config of the
same family for CPU tests; the FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).

Sources per assignment: [arXiv/hf references in each entry docstring].
"""
from __future__ import annotations

import dataclasses

from repro.config import ModelConfig, MoESpec, SSMSpec


def _smoke(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced config of the same family: few layers, thin width, tiny
    vocab; keeps every structural feature (GQA ratio, qk_norm, MoE top-k,
    SSD, hybrid period...) so smoke tests exercise the real code paths."""
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab=512, head_dim=16, max_seq_len=256, remat="none")
    if cfg.moe is not None:
        base["moe"] = dataclasses.replace(cfg.moe, n_experts=8, top_k=2,
                                          d_ff_expert=32)
    if cfg.ssm is not None:
        base["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                          chunk=16)
    if cfg.family == "hybrid":
        base["n_layers"] = 4
        base["hybrid_attn_every"] = 2
    if cfg.family == "encdec":
        base["enc_layers"] = 2
        base["enc_seq"] = 32
    if cfg.family == "vlm":
        base["frontend_tokens"] = 8
    base.update(over)
    return dataclasses.replace(cfg, **base)


# --- hybrid: Mamba2 + shared attention blocks [arXiv:2411.15242; hf] -------
ZAMBA2_2P7B = ModelConfig(
    arch="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
    ssm=SSMSpec(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                chunk=256),
    hybrid_attn_every=6, n_shared_blocks=2, act="gelu",
    sub_quadratic=True, max_seq_len=524_288)

# --- dense: pruned nemotron [arXiv:2407.14679; hf] --------------------------
MINITRON_4B = ModelConfig(
    arch="minitron-4b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=9216, vocab=256_000, act="relu2",
    head_dim=128, max_seq_len=32_768)

# --- dense: GQA, QKV bias [arXiv:2407.10671; hf] ----------------------------
QWEN2_7B = ModelConfig(
    arch="qwen2-7b", family="dense", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18_944, vocab=152_064, qkv_bias=True,
    max_seq_len=32_768)

# --- dense: llama-arch [arXiv:2401.02954; hf] -------------------------------
DEEPSEEK_67B = ModelConfig(
    arch="deepseek-67b", family="dense", n_layers=95, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22_016, vocab=102_400,
    max_seq_len=32_768)

# --- dense: qk_norm, GQA [hf:Qwen/Qwen3-8B; hf] -----------------------------
QWEN3_14B = ModelConfig(
    arch="qwen3-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=17_408, vocab=151_936, qk_norm=True,
    head_dim=128, max_seq_len=32_768)

# --- moe: 64 experts top-8 [arXiv:2409.02060; hf] ---------------------------
OLMOE_1B_7B = ModelConfig(
    arch="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50_304,
    moe=MoESpec(n_experts=64, top_k=8, d_ff_expert=1024),
    max_seq_len=32_768)

# --- moe: Kimi K2 trillion-param MoE (paper-table) [arXiv:2501.kimi2] -------
KIMI_K2 = ModelConfig(
    arch="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163_840, head_dim=112,
    moe=MoESpec(n_experts=384, top_k=8, d_ff_expert=2048),
    param_dtype="bfloat16",       # 1T params: fp32 master cannot fit a pod
    max_seq_len=32_768)

# --- audio: enc-dec, conv frontend STUB [arXiv:2212.04356] ------------------
WHISPER_LARGE_V3 = ModelConfig(
    arch="whisper-large-v3", family="encdec", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51_866,
    enc_layers=32, enc_seq=1500, act="gelu", norm="layernorm",
    pos_embedding="learned", tie_embeddings=True, frontend="audio",
    max_seq_len=32_768)

# --- ssm: SSD (state-space duality) [arXiv:2405.21060] ----------------------
MAMBA2_370M = ModelConfig(
    arch="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50_280,
    ssm=SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                chunk=256),
    sub_quadratic=True, max_seq_len=524_288)

# --- vlm: anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf] --------------
LLAVA_NEXT_MISTRAL_7B = ModelConfig(
    arch="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14_336, vocab=32_000,
    frontend="vision", frontend_tokens=576, max_seq_len=32_768)

# --- the paper's own models (Table 1) ---------------------------------------
GPT2_117M = ModelConfig(
    arch="gpt2-117m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=50_257, act="gelu",
    norm="layernorm", pos_embedding="learned", tie_embeddings=True,
    mlp_bias=True, max_seq_len=1024)

# --- synthetic: embedding-dominated probe for the sketch backend ------------
# Large multilingual-style vocab over a thin trunk: ~134M of ~147M params
# sit in the (tied) token embedding, so optimizer-state memory is decided
# by what happens to that one leaf — the workload the count-min sketch
# second moment (repro.core.sketch) targets.  Bench-only; not ASSIGNED.
EMBED_HEAVY_256K = ModelConfig(
    arch="embed-heavy-256k", family="dense", n_layers=4, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab=262_144,
    tie_embeddings=True, max_seq_len=2048)

GPT2_345M = ModelConfig(
    arch="gpt2-345m", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=50_257, act="gelu",
    norm="layernorm", pos_embedding="learned", tie_embeddings=True,
    mlp_bias=True, max_seq_len=1024)


ARCHS: dict[str, ModelConfig] = {
    c.arch: c for c in [
        ZAMBA2_2P7B, MINITRON_4B, QWEN2_7B, DEEPSEEK_67B, QWEN3_14B,
        OLMOE_1B_7B, KIMI_K2, WHISPER_LARGE_V3, MAMBA2_370M,
        LLAVA_NEXT_MISTRAL_7B, GPT2_117M, GPT2_345M, EMBED_HEAVY_256K,
    ]
}

# The ten assigned dry-run architectures (GPT-2 is the paper's own model,
# exercised by the benches rather than the 40-cell matrix).
ASSIGNED = [
    "zamba2-2.7b", "minitron-4b", "qwen2-7b", "deepseek-67b", "qwen3-14b",
    "olmoe-1b-7b", "kimi-k2-1t-a32b", "whisper-large-v3", "mamba2-370m",
    "llava-next-mistral-7b",
]


def get_config(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; available: "
                         f"{sorted(ARCHS)}") from None


def get_smoke_config(arch: str, **over) -> ModelConfig:
    return _smoke(get_config(arch), **over)
