"""Config module for olmoe-1b-7b (see archs.py for the exact assignment spec)."""
from repro.configs.archs import OLMOE_1B_7B as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("olmoe-1b-7b", **over)
