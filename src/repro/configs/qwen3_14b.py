"""Config module for qwen3-14b (see archs.py for the exact assignment spec)."""
from repro.configs.archs import QWEN3_14B as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("qwen3-14b", **over)
