"""Config module for kimi-k2-1t-a32b (see archs.py for the exact assignment spec)."""
from repro.configs.archs import KIMI_K2 as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("kimi-k2-1t-a32b", **over)
