"""Config module for zamba2-2.7b (see archs.py for the exact assignment spec)."""
from repro.configs.archs import ZAMBA2_2P7B as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("zamba2-2.7b", **over)
