"""Config module for whisper-large-v3 (see archs.py for the exact assignment spec)."""
from repro.configs.archs import WHISPER_LARGE_V3 as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("whisper-large-v3", **over)
