"""Architecture configs (assigned pool + paper's GPT-2) and input specs."""
from repro.configs.archs import (ARCHS, ASSIGNED, get_config,
                                 get_smoke_config)
from repro.configs.base import input_specs, make_batch
