"""Shared helpers for architecture configs: input specs per shape cell.

``input_specs(cfg, cell)`` returns ShapeDtypeStruct stand-ins for every
model input of that cell — weak-type-correct, shardable, and allocation
free — exactly what ``jax.jit(...).lower(...)`` needs for the dry-run.
``make_batch`` materialises small real arrays for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import CELLS, ModelConfig, ShapeCell


def _token_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens = cell seq_len minus stub-frontend tokens (VLM)."""
    if cfg.family == "vlm":
        return seq_len - cfg.frontend_tokens
    return seq_len


def input_specs(cfg: ModelConfig, cell: "ShapeCell | str") -> dict:
    """Batch inputs for train/prefill lowering (decode adds the cache,
    built separately via ``jax.eval_shape`` of ``model.init_cache``)."""
    if isinstance(cell, str):
        cell = CELLS[cell]
    b = cell.global_batch
    dt = jnp.dtype(cfg.dtype)

    if cell.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    s_txt = _token_len(cfg, cell.seq_len)
    specs = {"tokens": jax.ShapeDtypeStruct((b, s_txt), jnp.int32)}
    if cfg.family == "vlm":
        specs["embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), dt)
    elif cfg.family == "encdec":
        specs["embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), dt)
    return specs


def make_batch(cfg: ModelConfig, batch: int, seq_len: int,
               key=None) -> dict:
    """Small concrete batch for smoke tests (same structure as specs)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    dt = jnp.dtype(cfg.dtype)
    s_txt = _token_len(cfg, seq_len)
    out = {"tokens": jax.random.randint(key, (batch, s_txt), 0, cfg.vocab)}
    if cfg.family == "vlm":
        out["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (batch, cfg.frontend_tokens, cfg.d_model)).astype(dt)
    elif cfg.family == "encdec":
        out["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (batch, cfg.enc_seq, cfg.d_model)).astype(dt)
    return out
