"""Config module for gpt2-117m (see archs.py for the exact assignment spec)."""
from repro.configs.archs import GPT2_117M as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("gpt2-117m", **over)
