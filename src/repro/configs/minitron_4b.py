"""Config module for minitron-4b (see archs.py for the exact assignment spec)."""
from repro.configs.archs import MINITRON_4B as CONFIG
from repro.configs.archs import get_smoke_config


def model_config():
    return CONFIG


def smoke_config(**over):
    return get_smoke_config("minitron-4b", **over)
