from repro.train.steps import TrainState, build_train_step
from repro.train.train_loop import LoopConfig, train
