"""Fault-tolerant training loop.

Wires together: deterministic data pipeline, jitted train step, async
atomic checkpointing (+ preemption flush), straggler monitoring, metric
logging.  Restart-safe by construction: on startup it restores the latest
committed checkpoint (if any) and fast-forwards the data stream to the
restored step — a killed job resumes bit-exact (validated in
tests/test_train_integration.py).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import GradientTransformation
from repro.data import DataConfig, DataIterator
from repro.distributed.straggler import StragglerMonitor
from repro.train.steps import TrainState, build_train_step

log = logging.getLogger(__name__)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    log_every: int = 50
    ckpt: Optional[CheckpointConfig] = None
    microbatches: int = 1
    grad_clip_norm: Optional[float] = None


def train(model, opt: GradientTransformation, data_cfg: DataConfig,
          loop_cfg: LoopConfig, *,
          state: Optional[TrainState] = None,
          state_shardings=None,
          metric_hook: Optional[Callable[[int, dict], None]] = None,
          install_signal_handler: bool = False) -> tuple[TrainState, list]:
    """Returns (final_state, history of metric dicts)."""
    ckpt = CheckpointManager(loop_cfg.ckpt) if loop_cfg.ckpt else None

    if state is None:
        params = model.init(jax.random.PRNGKey(0))
        state = TrainState.create(params, opt)

    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(state, state_shardings)
        log.info("restored checkpoint at step %d", start_step)

    step_fn = jax.jit(build_train_step(
        model, opt, microbatches=loop_cfg.microbatches,
        grad_clip_norm=loop_cfg.grad_clip_norm))

    data = DataIterator(data_cfg, start_step=start_step)
    monitor = StragglerMonitor()
    history = []

    if ckpt is not None and install_signal_handler:
        latest = {"state": state, "step": start_step}
        ckpt.install_preemption_handler(
            lambda: (latest["state"], latest["step"]))

    try:
        for step in range(start_step, loop_cfg.total_steps):
            batch = next(data)
            batch.pop("step", None)
            monitor.start()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = monitor.stop()

            if ckpt is not None and install_signal_handler:
                latest["state"], latest["step"] = state, step + 1

            if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step_time_s"] = dt
                m["step"] = step + 1
                history.append(m)
                if metric_hook:
                    metric_hook(step + 1, m)
                log.info("step %d loss %.4f (%.3fs)", step + 1,
                         m.get("loss", float("nan")), dt)

            if ckpt is not None and ckpt.should_save(step + 1):
                ckpt.save(state, step + 1)
    finally:
        data.close()
        if ckpt is not None:
            ckpt.wait()

    if ckpt is not None:
        ckpt.save(state, loop_cfg.total_steps, blocking=True)
    return state, history
