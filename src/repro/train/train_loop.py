"""Fault-tolerant training loop, single-device or mesh-sharded.

Wires together: deterministic data pipeline, jitted train step, async
atomic checkpointing (+ preemption flush), straggler monitoring, metric
logging.  Restart-safe by construction: on startup it restores the latest
committed checkpoint (if any) and fast-forwards the data stream to the
restored step — a killed job resumes bit-exact (validated in
tests/test_train_integration.py).

Sharded path: pass ``state_shardings`` (a ``TrainState``-shaped tree of
``NamedSharding``, e.g. from ``distributed.sharding.train_shardings``) and
optionally ``batch_shardings``.  The step function is then jitted with
``in_shardings`` / ``out_shardings`` (and donated state buffers when no
preemption handler needs to keep a host-reachable copy), fresh state is
initialised eagerly and re-placed under the shardings (jit-init with
``out_shardings`` — state born sharded, never resident on one device — is
planned for when partitioned RNG is mesh-invariant on our jax version;
see the inline note), host batches are placed under ``batch_shardings``,
and checkpoint restore re-places saved logical arrays under the current
shardings — which is exactly what makes save-on-mesh-A / resume-on-mesh-B
elastic restarts work (tests/test_sharded_train.py).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import GradientTransformation
from repro.data import DataConfig, DataIterator
from repro.distributed.straggler import StragglerMonitor
from repro.train.steps import TrainState, build_train_step

log = logging.getLogger(__name__)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    log_every: int = 50
    ckpt: Optional[CheckpointConfig] = None
    microbatches: int = 1
    grad_clip_norm: Optional[float] = None


def train(model, opt: GradientTransformation, data_cfg: DataConfig,
          loop_cfg: LoopConfig, *,
          state: Optional[TrainState] = None,
          state_shardings=None,
          batch_shardings=None,
          metric_hook: Optional[Callable[[int, dict], None]] = None,
          install_signal_handler: bool = False) -> tuple[TrainState, list]:
    """Returns (final_state, history of metric dicts)."""
    ckpt = CheckpointManager(loop_cfg.ckpt) if loop_cfg.ckpt else None

    if state is None:
        params = model.init(jax.random.PRNGKey(0))
        state = TrainState.create(params, opt)
        if state_shardings is not None:
            # Init eagerly, then re-place under the shardings.  (Jitting
            # the init with out_shardings would avoid materialising the
            # full state on one device, but on this jax version partitioned
            # RNG draws different init values per mesh — breaking the
            # any-mesh bitwise-continuation contract the resharding tests
            # pin down.  Flip to jit-init once jax_threefry_partitionable
            # is the default.)
            state = jax.device_put(state, state_shardings)

    # A caller-provided mid-run state resumes at its own step counter
    # (elastic_restore hands back exactly such a state); fresh states
    # carry step 0.  A committed checkpoint below overrides both.
    start_step = int(np.asarray(state.step))
    if ckpt is not None and ckpt.latest_step() is not None:
        # restore reshards: saved logical arrays re-placed under the
        # CURRENT shardings, whatever mesh the checkpoint was written on
        state, start_step = ckpt.restore(state, state_shardings)
        log.info("restored checkpoint at step %d", start_step)

    step_fn = build_train_step(model, opt, microbatches=loop_cfg.microbatches,
                               grad_clip_norm=loop_cfg.grad_clip_norm)
    if state_shardings is not None:
        # Donating the input state halves optimizer-state residency, but a
        # preemption flush must be able to device_get the PRE-step state at
        # any instant — donation would leave it pointing at freed buffers —
        # so the flush path trades the alias away.
        donate = () if install_signal_handler else (0,)
        step_fn = jax.jit(step_fn,
                          in_shardings=(state_shardings, batch_shardings),
                          out_shardings=(state_shardings, None),
                          donate_argnums=donate)
    else:
        step_fn = jax.jit(step_fn)

    data = DataIterator(data_cfg, start_step=start_step)
    monitor = StragglerMonitor()
    history = []

    if ckpt is not None and install_signal_handler:
        latest = {"state": state, "step": start_step}
        ckpt.install_preemption_handler(
            lambda: (latest["state"], latest["step"]))

    try:
        for step in range(start_step, loop_cfg.total_steps):
            batch = next(data)
            batch.pop("step", None)
            if batch_shardings is not None:
                batch = jax.device_put(batch, batch_shardings)
            monitor.start()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = monitor.stop()

            if ckpt is not None and install_signal_handler:
                latest["state"], latest["step"] = state, step + 1

            if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step_time_s"] = dt
                m["step"] = step + 1
                history.append(m)
                if metric_hook:
                    metric_hook(step + 1, m)
                log.info("step %d loss %.4f (%.3fs)", step + 1,
                         m.get("loss", float("nan")), dt)

            if ckpt is not None and ckpt.should_save(step + 1):
                ckpt.save(state, step + 1)
    finally:
        data.close()
        if ckpt is not None:
            if install_signal_handler:
                # before wait(): a failed async save re-raises there, and
                # the handler must not outlive this loop's state capture
                ckpt.uninstall_preemption_handler()
            ckpt.wait()

    if ckpt is not None:
        ckpt.save(state, loop_cfg.total_steps, blocking=True)
    return state, history
