"""Fault-tolerant training loop, single-device or mesh-sharded.

Wires together: deterministic data pipeline, jitted train step, async
atomic checkpointing (+ preemption flush), straggler monitoring, metric
logging, and the telemetry subsystem (``repro.telemetry``): pass a
``TelemetryRuntime`` and the loop streams per-group optimizer snapshots
to its JSONL sink after every step, lets its closed-loop controller
retune the (traced) S-RSI refresh cadence in place, saves its controller
state into every checkpoint manifest, and flushes its sink on preemption
— the straggler monitor shares the same event stream.  Restart-safe by construction: on startup it restores the latest
committed checkpoint (if any) and fast-forwards the data stream to the
restored step — a killed job resumes bit-exact (validated in
tests/test_train_integration.py).

Sharded path: pass ``state_shardings`` (a ``TrainState``-shaped tree of
``NamedSharding``, e.g. from ``distributed.sharding.train_shardings``) and
optionally ``batch_shardings``.  The step function is then jitted with
``in_shardings`` / ``out_shardings`` (and donated state buffers when no
preemption handler needs to keep a host-reachable copy), fresh state is
initialised eagerly and re-placed under the shardings (jit-init with
``out_shardings`` — state born sharded, never resident on one device — is
planned for when partitioned RNG is mesh-invariant on our jax version;
see the inline note), host batches are placed under ``batch_shardings``,
and checkpoint restore re-places saved logical arrays under the current
shardings — which is exactly what makes save-on-mesh-A / resume-on-mesh-B
elastic restarts work (tests/test_sharded_train.py).
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import GradientTransformation
from repro.data import DataConfig, DataIterator
from repro.distributed.straggler import StragglerMonitor
from repro.telemetry.trace import NULL_TRACER
from repro.train.steps import TrainState, build_train_step

log = logging.getLogger(__name__)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    log_every: int = 50
    ckpt: Optional[CheckpointConfig] = None
    microbatches: int = 1
    grad_clip_norm: Optional[float] = None
    # Cap on the in-memory metric history (a bounded deque of the most
    # recent entries).  None keeps every logged entry — the historical
    # behavior — which on a long production run grows host memory without
    # bound; set a cap and consume the full stream via metric_hook or the
    # telemetry sink instead.
    history_cap: Optional[int] = None


def train(model, opt: GradientTransformation, data_cfg: DataConfig,
          loop_cfg: LoopConfig, *,
          state: Optional[TrainState] = None,
          state_shardings=None,
          batch_shardings=None,
          metric_hook: Optional[Callable[[int, dict], None]] = None,
          telemetry=None,
          tracer=None,
          metrics_every: int = 0,
          registry=None,
          install_signal_handler: bool = False) -> tuple[TrainState, list]:
    """Returns (final_state, history of metric dicts).

    ``telemetry``: optional :class:`repro.telemetry.TelemetryRuntime`.
    Each step, after the existing loss sync, the runtime fetches the
    (scalar-sized) optimizer snapshots from the returned state, streams
    events to its JSONL sink, and — with the closed-loop controller
    enabled — writes retuned refresh cadences back into the state (a
    traced scalar: no recompilation).  Its controller state rides the
    checkpoint manifests (saved with every checkpoint, restored on
    resume), and its sink is flushed by the preemption handler chain and
    at loop exit.  The caller owns the runtime and closes it.

    ``tracer``: optional :class:`repro.telemetry.Tracer`.  Each step
    emits a host-side ``train_step`` span with ``data_wait`` /
    ``step_dispatch`` / ``device_sync`` children, attributed
    refresh-vs-fold from the in-jit snapshot counters when the optimizer
    collects them; checkpoint saves/restores get their own spans.  Spans
    never enter jit — the step function is untouched, so the
    bitwise-default-chain contract holds with tracing on.  The
    preemption handler chain drains open spans (``"truncated": true``)
    before the final checkpoint.  The caller owns the tracer's sink.

    ``metrics_every``: > 0 emits a ``kind="metric"`` registry snapshot
    (train_steps_total, train_step_seconds, train_loss) every N steps to
    the tracer's sink (or the telemetry runtime's).  ``registry``
    defaults to the tracer's, else the process-wide default.
    """
    ckpt = CheckpointManager(loop_cfg.ckpt) if loop_cfg.ckpt else None
    tr = tracer if tracer is not None else NULL_TRACER
    if ckpt is not None and tracer is not None:
        ckpt.tracer = tracer
    reg = None
    metric_sink = None
    if metrics_every > 0:
        from repro.telemetry import metrics as metrics_mod
        reg = registry if registry is not None else (
            tracer.registry if tracer is not None
            and tracer.registry is not None
            else metrics_mod.default_registry())
        metric_sink = (tracer.sink if tracer is not None
                       and tracer.sink is not None
                       else telemetry.sink if telemetry is not None
                       else None)

    if state is None:
        params = model.init(jax.random.PRNGKey(0))
        state = TrainState.create(params, opt)
        if state_shardings is not None:
            # Init eagerly, then re-place under the shardings.  (Jitting
            # the init with out_shardings would avoid materialising the
            # full state on one device, but on this jax version partitioned
            # RNG draws different init values per mesh — breaking the
            # any-mesh bitwise-continuation contract the resharding tests
            # pin down.  Flip to jit-init once jax_threefry_partitionable
            # is the default.)
            state = jax.device_put(state, state_shardings)

    # A caller-provided mid-run state resumes at its own step counter
    # (elastic_restore hands back exactly such a state); fresh states
    # carry step 0.  A committed checkpoint below overrides both.
    start_step = int(np.asarray(state.step))
    if ckpt is not None and ckpt.latest_step() is not None:
        # restore reshards: saved logical arrays re-placed under the
        # CURRENT shardings, whatever mesh the checkpoint was written on
        state, start_step = ckpt.restore(state, state_shardings)
        log.info("restored checkpoint at step %d", start_step)
        if telemetry is not None:
            # controller accumulators + cadence log resume from the
            # manifest, so the cadence-change sequence replays exactly
            # (the cadence scalar itself is optimizer state and was just
            # restored with it).  Keyed by the step restore actually
            # landed on — if it fell back past a corrupt latest
            # checkpoint, the meta must come from the same fallback.
            telemetry.restore_meta(ckpt.read_meta(start_step))

    step_fn = build_train_step(model, opt, microbatches=loop_cfg.microbatches,
                               grad_clip_norm=loop_cfg.grad_clip_norm)
    if state_shardings is not None:
        # Donating the input state halves optimizer-state residency, but a
        # preemption flush must be able to device_get the PRE-step state at
        # any instant — donation would leave it pointing at freed buffers —
        # so the flush path trades the alias away.
        donate = () if install_signal_handler else (0,)
        step_fn = jax.jit(step_fn,
                          in_shardings=(state_shardings, batch_shardings),
                          out_shardings=(state_shardings, None),
                          donate_argnums=donate)
    else:
        step_fn = jax.jit(step_fn)

    data = DataIterator(data_cfg, start_step=start_step)
    monitor = StragglerMonitor(
        sink=telemetry.sink if telemetry is not None else None)
    history = (collections.deque(maxlen=loop_cfg.history_cap)
               if loop_cfg.history_cap is not None else [])

    def _meta():
        return telemetry.manifest_meta() if telemetry is not None else None

    if ckpt is not None and install_signal_handler:
        # (state, step, controller-meta) captured as ONE tuple assigned in
        # ONE bytecode: a signal between separate assignments could pair a
        # step-N state with step-N+1 controller accumulators, and the
        # restored run would double-observe a step and diverge from the
        # cadence sequence the determinism tests pin.
        latest = {"snap": (state, start_step, _meta())}

        def _flush_state():
            # rides the preemption handler chain: drain open spans as
            # truncated events and the telemetry sink to disk, then hand
            # the state + controller meta to the blocking checkpoint
            # flush.  Best-effort: a sick sink (disk full on the
            # telemetry volume) must never cost the preemption
            # CHECKPOINT.  Both drains are lock-free (dict ops + counter
            # spins), so a SIGTERM that interrupted emit can't deadlock.
            if tracer is not None:
                try:
                    tracer.drain_open()
                    tracer.flush()
                except Exception:  # noqa: BLE001 — checkpoint comes first
                    log.exception("span drain failed during preemption; "
                                  "saving checkpoint anyway")
            if telemetry is not None:
                try:
                    telemetry.flush()
                except Exception:  # noqa: BLE001 — checkpoint comes first
                    log.exception("telemetry flush failed during "
                                  "preemption; saving checkpoint anyway")
            return latest["snap"]

        ckpt.install_preemption_handler(_flush_state)

    run_trace = tr.new_trace("train") if tracer is not None else None
    loop_t0 = time.monotonic()
    try:
        for step in range(start_step, loop_cfg.total_steps):
            with tr.span("train_step", trace=run_trace,
                         step=step + 1) as step_span:
                with tr.span("data_wait"):
                    batch = next(data)
                    batch.pop("step", None)
                    if batch_shardings is not None:
                        batch = jax.device_put(batch, batch_shardings)
                monitor.start()
                with tr.span("step_dispatch"):
                    state, metrics = step_fn(state, batch)
                with tr.span("device_sync"):
                    jax.block_until_ready(metrics["loss"])
                dt = monitor.stop()
                if tracer is not None:
                    phase = _refresh_phase(metrics)
                    if phase is not None:
                        step_span.set(phase=phase)

            if telemetry is not None:
                # fetch snapshots / emit events / retune cadences; the
                # loop already synced on the loss, so this adds no device
                # round-trip beyond the scalar fetch
                state = telemetry.on_step(step + 1, state)

            if ckpt is not None and install_signal_handler:
                latest["snap"] = (state, step + 1, _meta())

            if reg is not None:
                reg.counter("train_steps_total",
                            help="train steps completed").inc()
                reg.histogram("train_step_seconds",
                              help="wall time per train step").observe(dt)
                if (step + 1) % metrics_every == 0:
                    reg.gauge("train_loss",
                              help="loss at the last snapshot").set(
                                  float(np.asarray(metrics["loss"])))
                    if metric_sink is not None:
                        metric_sink.emit(reg.snapshot(
                            t_s=time.monotonic() - loop_t0, step=step + 1))

            if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step_time_s"] = dt
                m["step"] = step + 1
                history.append(m)
                if metric_hook:
                    metric_hook(step + 1, m)
                log.info("step %d loss %.4f (%.3fs)", step + 1,
                         m.get("loss", float("nan")), dt)

            if ckpt is not None and ckpt.should_save(step + 1):
                with tr.span("checkpoint_save", trace=run_trace,
                             step=step + 1):
                    ckpt.save(state, step + 1, extra_meta=_meta())
    finally:
        data.close()
        if ckpt is not None:
            if install_signal_handler:
                # before wait(): a failed async save re-raises there, and
                # the handler must not outlive this loop's state capture
                ckpt.uninstall_preemption_handler()
            ckpt.wait()
        if telemetry is not None:
            try:
                telemetry.flush()
            except Exception:  # noqa: BLE001 — same rule as the
                # preemption path: a sick sink must neither mask an
                # in-flight exception nor cost the final checkpoint
                log.exception("telemetry flush failed at loop exit")
        if tracer is not None:
            try:
                tracer.flush()
            except Exception:  # noqa: BLE001 — same rule
                log.exception("tracer flush failed at loop exit")

    if ckpt is not None:
        with tr.span("checkpoint_save", trace=run_trace,
                     step=loop_cfg.total_steps):
            ckpt.save(state, loop_cfg.total_steps, blocking=True,
                      extra_meta=_meta())
        if tracer is not None:
            try:
                tracer.flush()
            except Exception:  # noqa: BLE001
                log.exception("tracer flush failed after final save")
    return state, list(history)


def _refresh_phase(metrics: dict) -> Optional[str]:
    """Refresh-vs-fold attribution for the step span, read from the
    in-jit snapshot counters the optimizer already computes
    (``telemetry/<group>/did_refresh`` in the step metrics; absent when
    the optimizer collects no telemetry).  Host-side read of an
    already-synced scalar — nothing is added inside jit."""
    flags = [v for k, v in metrics.items() if k.endswith("/did_refresh")]
    if not flags:
        return None
    return ("refresh" if any(bool(np.asarray(f)) for f in flags)
            else "fold")
