"""Train-step construction: loss -> grad -> optimizer, with gradient
accumulation (microbatching) and mixed precision (fp32 master params, model
casts to cfg.dtype internally).

``opt`` may be a built ``GradientTransformation`` (any chain / partition)
or a declarative ``repro.config.OptimizerConfig`` — the latter is lowered
through ``repro.core.build_optimizer`` so call sites can stay config-only.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.core import (GradientTransformation, apply_updates,
                        build_optimizer, global_norm)
from repro.telemetry import collect as telemetry_collect


def _as_transform(opt) -> GradientTransformation:
    if isinstance(opt, OptimizerConfig):
        return build_optimizer(opt)
    return opt


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray

    @staticmethod
    def create(params, opt) -> "TrainState":
        opt = _as_transform(opt)
        return TrainState(params=params, opt_state=opt.init(params),
                          step=jnp.zeros((), jnp.int32))


def build_train_step(model, opt,
                     microbatches: int = 1,
                     grad_clip_norm: Optional[float] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1: the global batch splits on the leading axis and
    gradients accumulate in fp32 across a lax.scan — peak activation memory
    drops by ~microbatches at the cost of re-running the forward.
    """
    opt = _as_transform(opt)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, loss, metrics

        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(acc, mb):
            grads_acc, loss_acc = acc
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (grads_acc, loss_acc + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = loss_sum * inv
        return grads, loss, {"loss": loss}

    def train_step(state: TrainState, batch):
        grads, loss, metrics = compute_grads(state.params, batch)
        if grad_clip_norm is not None:
            norm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip_norm / (norm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
            metrics = dict(metrics, grad_norm=norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        metrics = dict(metrics, loss=loss, step=state.step)
        # Optimizer telemetry rides out of the jitted step alongside the
        # metrics: per-group scalar aggregates of the in-state snapshots
        # (repro.telemetry).  Empty dict — the metrics pytree is unchanged
        # — unless the optimizer was built with telemetry enabled.
        metrics.update(telemetry_collect.telemetry_metrics(opt_state))
        return new_state, metrics

    return train_step
