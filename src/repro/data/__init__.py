from repro.data.pipeline import DataConfig, DataIterator, make_source
