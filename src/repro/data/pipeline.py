"""Deterministic, shard-aware token data pipeline.

Design (what a real cluster needs, scaled to this repo):
  * deterministic: batch t is a pure function of (seed, step) — a restarted
    job resumes mid-epoch with zero coordination, and elastic re-scaling
    re-partitions the same global stream;
  * shard-aware: each data-parallel host materialises only its slice
    (``host_slice``), the global batch is never built on one host;
  * double-buffered: a background thread keeps ``prefetch`` batches ahead
    so step time never blocks on host-side generation;
  * sources: synthetic LM streams (zipf-distributed tokens with local
    structure — enough signal for the convergence benches) and a repeatable
    corpus wrapper for real token files.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"      # synthetic | corpus
    corpus_path: Optional[str] = None
    prefetch: int = 2


class SyntheticLM:
    """Zipf unigrams + a copy/induction pattern so models can actually
    learn (loss drops well below the unigram entropy)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self.probs = probs / probs.sum()

    def batch_at(self, step: int, start: int = 0,
                 count: Optional[int] = None) -> dict:
        """Rows [start, start+count) of the global batch for ``step``."""
        cfg = self.cfg
        count = cfg.global_batch if count is None else count
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        # generate the full batch indices lazily per row for determinism
        tokens = np.empty((count, cfg.seq_len), np.int32)
        for i in range(count):
            row_rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, start + i]))
            row = row_rng.choice(cfg.vocab, size=cfg.seq_len, p=self.probs)
            # induction pattern: second half repeats the first half shifted
            half = cfg.seq_len // 2
            row[half:half * 2] = row[:half]
            tokens[i] = row
        return {"tokens": tokens}


class CorpusLM:
    """Fixed token corpus (npy int32 file) sliced into (step, row) windows
    — same determinism contract as SyntheticLM."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.load(cfg.corpus_path, mmap_mode="r")
        self.n_windows = (self.data.size - 1) // cfg.seq_len

    def batch_at(self, step: int, start: int = 0,
                 count: Optional[int] = None) -> dict:
        cfg = self.cfg
        count = cfg.global_batch if count is None else count
        tokens = np.empty((count, cfg.seq_len), np.int32)
        for i in range(count):
            idx = (step * cfg.global_batch + start + i) % self.n_windows
            off = idx * cfg.seq_len
            tokens[i] = self.data[off:off + cfg.seq_len]
        return {"tokens": tokens}


def make_source(cfg: DataConfig):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg)
    if cfg.kind == "corpus":
        return CorpusLM(cfg)
    raise ValueError(cfg.kind)


class DataIterator:
    """Prefetching iterator over (optionally host-sliced) batches.

    host_slice=(host_index, host_count): this host materialises rows
    [i*B/H, (i+1)*B/H) of the global batch.
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 host_slice: tuple[int, int] = (0, 1)):
        self.cfg = cfg
        self.source = make_source(cfg)
        self.step = start_step
        hi, hc = host_slice
        assert cfg.global_batch % hc == 0
        self._start = hi * (cfg.global_batch // hc)
        self._count = cfg.global_batch // hc
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step, self._start, self._count)
            batch["step"] = step
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        batch = self._q.get()
        self.step = batch["step"] + 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
