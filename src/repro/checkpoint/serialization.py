"""Sharded-array (de)serialization with resharding on restore.

Format (directory per checkpoint step):
    step_000000123/
      manifest.json     — pytree structure, per-leaf shape/dtype/spec,
                          saving-mesh axis sizes, step, meta
      leaf_00000.npy    — one file per leaf (host-gathered logical array)
      _COMMITTED        — atomic commit marker (written LAST)

Restore never requires the saving mesh: arrays are stored as logical
(global) values and re-placed under the restoring mesh's NamedShardings —
this is what makes elastic re-scaling (checkpoint on a (4, 2) mesh, resume
on (2, 4), (8,) or a single host) work, and it covers every optimizer
state shape including ``PartitionState`` (whose group labels are *static*
pytree metadata: they live in the restore target's treedef, not in any
array file) and mid-``refresh_every`` factored Adapprox state (the step
counter is an array leaf, so the refresh cadence resumes exactly where it
left off).  Each manifest leaf records the ``PartitionSpec`` it was saved
under plus the saving mesh's axis sizes — pure metadata today (restore
reads the logical array), but it is what a multi-host writer keys
per-shard files on, and it makes checkpoints self-describing for
placement-debugging tools.

For the single-host container the save is a plain host gather; on a real
multi-host cluster the same manifest format extends to per-shard files
keyed by shard index.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

COMMIT_MARKER = "_COMMITTED"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint directory failed integrity verification."""


def _fsync_path(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _tree_paths(tree) -> list[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def leaf_spec_meta(tree: Any) -> tuple[list, dict]:
    """Per-leaf sharding-spec strings + saving-mesh axis sizes for ``tree``
    (device arrays; call BEFORE any host gather strips the placement).
    Host/numpy leaves record ``None``; the mesh dict is empty when nothing
    is sharded."""
    specs, mesh_axes = [], {}
    for leaf in jax.tree.leaves(tree):
        sharding = getattr(leaf, "sharding", None)
        spec = getattr(sharding, "spec", None)
        specs.append(str(spec) if spec is not None else None)
        mesh = getattr(sharding, "mesh", None)
        if mesh is not None and not mesh_axes:
            mesh_axes = {str(k): int(v) for k, v in dict(mesh.shape).items()}
    return specs, mesh_axes


def save_pytree(tree: Any, directory: "str | Path", step: int,
                extra_meta: Optional[dict] = None,
                leaf_specs: Optional[list] = None,
                mesh_axes: Optional[dict] = None) -> Path:
    """Write atomically: tmp dir -> files -> rename -> commit marker.

    ``leaf_specs`` / ``mesh_axes`` (from :func:`leaf_spec_meta`) record how
    each leaf was sharded when saved — metadata only; the files always
    hold the logical (global) array, so restore is mesh-independent."""
    directory = Path(directory)
    final = directory / f"step_{step:09d}"
    tmp = directory / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = jax.tree.flatten(tree)
    if leaf_specs is None:
        leaf_specs, inferred = leaf_spec_meta(tree)
        mesh_axes = mesh_axes or inferred
    if len(leaf_specs) != len(leaves):
        # a silent zip truncation here would commit an incomplete
        # checkpoint; fail at save time instead
        raise ValueError(f"leaf_specs has {len(leaf_specs)} entries for "
                         f"{len(leaves)} leaves")
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "paths": _tree_paths(tree),
        "leaves": [],
        "meta": extra_meta or {},
        "mesh_axes": mesh_axes or {},
        "format": "sharded-v2",
    }
    for i, (leaf, spec) in enumerate(zip(leaves, leaf_specs)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "spec": spec,
            "bytes": os.path.getsize(tmp / fname),
            "sha256": _sha256(tmp / fname),
        })
        _fsync_path(tmp / fname)
    mpath = tmp / "manifest.json"
    mpath.write_text(json.dumps(manifest, indent=1))
    _fsync_path(mpath)
    # The marker goes into the tmp dir BEFORE the rename: the rename is
    # then the single commit point, so a kill anywhere mid-save leaves
    # either the old step or nothing visible — never a half-written dir
    # that looks committed.  (A marker touched after the rename — the
    # old scheme — had a crash window where step_N existed uncommitted.)
    (tmp / COMMIT_MARKER).touch()
    _fsync_path(tmp)

    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                   # commit point
    _fsync_path(directory)
    return final


def is_committed(ckpt_dir: "str | Path") -> bool:
    return (Path(ckpt_dir) / COMMIT_MARKER).exists()


def verify_checkpoint(ckpt_dir: "str | Path", deep: bool = False) -> bool:
    """Integrity check for one step directory.

    Structural (always): commit marker present, manifest parses, every
    leaf file exists with the byte size the manifest recorded.  Cheap —
    safe on the ``latest_step()`` path.  Manifests from before checksums
    were recorded (no ``bytes`` field) pass the size check vacuously.

    deep=True additionally re-hashes every leaf file against the
    manifest sha256 — catches bit flips that leave sizes intact.  Only
    the restore path pays for this.
    """
    ckpt_dir = Path(ckpt_dir)
    if not is_committed(ckpt_dir):
        return False
    try:
        manifest = json.loads((ckpt_dir / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError):
        return False
    for entry in manifest.get("leaves", []):
        fpath = ckpt_dir / entry["file"]
        if not fpath.exists():
            return False
        want = entry.get("bytes")
        if want is not None and os.path.getsize(fpath) != want:
            return False
        if deep:
            digest = entry.get("sha256")
            if digest is not None and _sha256(fpath) != digest:
                return False
    return True


def list_checkpoints(directory: "str | Path") -> list[Path]:
    """Committed, structurally-valid step dirs, oldest first.  Incomplete
    or manifest-less directories (an interrupted save, a crash between
    mkdir and rename under the pre-hardening format) are skipped, not
    raised on."""
    directory = Path(directory)
    if not directory.exists():
        return []
    out = [p for p in sorted(directory.glob("step_*"))
           if p.is_dir() and verify_checkpoint(p)]
    return out


def latest_checkpoint(directory: "str | Path") -> Optional[Path]:
    cks = list_checkpoints(directory)
    return cks[-1] if cks else None


def restore_pytree(ckpt_dir: "str | Path", like: Any,
                   shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``like``; re-place under ``shardings``
    (pytree of NamedSharding or None for host arrays).  Shapes must match —
    resharding is free, reshaping is an error surfaced loudly.

    verify=True (default) deep-verifies checksums first and raises
    :class:`CheckpointCorruptError` on any mismatch — loading a silently
    bit-flipped second moment is strictly worse than failing over to the
    previous checkpoint (which ``CheckpointManager.restore`` does)."""
    ckpt_dir = Path(ckpt_dir)
    if verify:
        if not verify_checkpoint(ckpt_dir, deep=True):
            raise CheckpointCorruptError(
                f"checkpoint failed integrity verification: {ckpt_dir}")
    elif not is_committed(ckpt_dir):
        raise CheckpointCorruptError(f"uncommitted checkpoint: {ckpt_dir}")
    manifest = json.loads((ckpt_dir / "manifest.json").read_text())

    like_leaves, treedef = jax.tree.flatten(like)
    if len(like_leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target "
            f"structure has {len(like_leaves)} — structures diverged")

    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(like_leaves))

    out = []
    for i, (entry, ref, shd) in enumerate(
            zip(manifest["leaves"], like_leaves, shard_leaves)):
        arr = np.load(ckpt_dir / entry["file"])
        ref_shape = tuple(getattr(ref, "shape", arr.shape))
        if tuple(arr.shape) != ref_shape:
            raise ValueError(
                f"leaf {i} shape mismatch: ckpt {arr.shape} vs {ref_shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def checkpoint_step(ckpt_dir: "str | Path") -> int:
    manifest = json.loads((Path(ckpt_dir) / "manifest.json").read_text())
    return int(manifest["step"])


def read_meta(ckpt_dir: "str | Path") -> dict:
    """The ``extra_meta`` dict recorded in the manifest (empty if none)."""
    manifest = json.loads((Path(ckpt_dir) / "manifest.json").read_text())
    return dict(manifest.get("meta") or {})
