from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.checkpoint.serialization import (latest_checkpoint, list_checkpoints,
                                            restore_pytree, save_pytree)
