"""CheckpointManager: async save, retention, preemption handling, restore.

Fault-tolerance contract:
  * saves are ATOMIC (tmp dir + rename + commit marker) — a job killed
    mid-save never corrupts the latest checkpoint;
  * saves are ASYNC — the train loop hands off host copies of the arrays
    and continues; a background thread serialises (device->host transfer is
    the only synchronous part);
  * retention keeps the last ``keep`` checkpoints (+ every ``keep_every``th
    permanently);
  * ``install_preemption_handler`` flushes a final checkpoint on
    SIGTERM/SIGINT — the TPU-pod eviction path — then CHAINS to whatever
    handler was installed before it (elastic-restart teardown and the
    flush compose; originals are restored after the flush / on
    ``uninstall_preemption_handler``).
"""
from __future__ import annotations

import dataclasses
import logging
import shutil
import signal
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import serialization as SER
from repro.telemetry.trace import NULL_TRACER

log = logging.getLogger(__name__)


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    save_every: int = 500
    keep: int = 3
    keep_every: int = 0          # 0 = disabled
    async_save: bool = True
    # Transient-I/O retry policy (NFS blips, throttled object stores).
    # io_retries is the number of RE-tries after the first attempt;
    # backoff doubles per attempt from retry_backoff_s, no jitter —
    # chaos tests count attempts deterministically.
    io_retries: int = 2
    retry_backoff_s: float = 0.05


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.directory = Path(cfg.directory)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._prev_handlers: Optional[dict] = None
        # optional repro.telemetry Tracer (the train loop wires its own
        # in): ckpt_gather spans the synchronous device->host snapshot,
        # ckpt_write the async file IO, ckpt_restore the load
        self.tracer = None

    def _with_retries(self, fn, what: str):
        """Run ``fn`` retrying OSErrors with exponential backoff.

        Only OSError (the transient-I/O class) is retried; corruption and
        programming errors propagate immediately — retrying those just
        hides the bug for io_retries * backoff seconds."""
        delay = self.cfg.retry_backoff_s
        for attempt in range(self.cfg.io_retries + 1):
            try:
                return fn()
            except OSError as e:
                if attempt == self.cfg.io_retries:
                    raise
                log.warning("%s failed (%s); retry %d/%d in %.2fs",
                            what, e, attempt + 1, self.cfg.io_retries, delay)
                time.sleep(delay)
                delay *= 2

    # -- save ------------------------------------------------------------
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.cfg.save_every == 0

    def save(self, tree: Any, step: int, blocking: bool = False,
             extra_meta: Optional[dict] = None) -> None:
        tr = self.tracer if self.tracer is not None else NULL_TRACER
        with tr.span("ckpt_gather", step=step):
            self.wait()                 # one in-flight save at a time
            # Capture per-leaf sharding specs BEFORE the host gather
            # strips placement — the manifest records how the state was
            # sharded.
            leaf_specs, mesh_axes = SER.leaf_spec_meta(tree)
            # Device->host is synchronous (consistent snapshot); file IO
            # is not.
            host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)

        def work():
            try:
                # worker thread: its own span stack, so this span starts
                # a fresh trace rather than nesting under the caller's
                with tr.span("ckpt_write", step=step):
                    self._with_retries(
                        lambda: SER.save_pytree(
                            host_tree, self.directory, step,
                            extra_meta=extra_meta,
                            leaf_specs=leaf_specs, mesh_axes=mesh_axes),
                        what=f"checkpoint save step {step}")
                    self._retain()
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self._error = e

        if self.cfg.async_save and not blocking:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    def _retain(self):
        cks = SER.list_checkpoints(self.directory)
        if self.cfg.keep <= 0 or len(cks) <= self.cfg.keep:
            return
        for p in cks[:-self.cfg.keep]:
            step = SER.checkpoint_step(p)
            if self.cfg.keep_every and step % self.cfg.keep_every == 0:
                continue
            shutil.rmtree(p, ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        p = SER.latest_checkpoint(self.directory)
        return SER.checkpoint_step(p) if p else None

    def read_meta(self, step: Optional[int] = None) -> dict:
        """The ``extra_meta`` dict a checkpoint was saved with (empty when
        none / no checkpoint exists).  Telemetry uses it to restore the
        closed-loop controller's accumulators alongside the state."""
        if step is None:
            p = SER.latest_checkpoint(self.directory)
        else:
            p = self.directory / f"step_{step:09d}"
            if not (p / "manifest.json").exists():
                p = None               # never saved, or pruned by retention
        return SER.read_meta(p) if p is not None else {}

    def restore(self, like: Any, shardings: Any = None,
                step: Optional[int] = None) -> tuple[Any, int]:
        """Restore into the structure of ``like``, re-placed under
        ``shardings`` — a pytree of NamedSharding for the CURRENT mesh,
        which need not resemble the saving mesh (resharding happens at
        load; save on (4, 2), restore on (2, 4), (8,) or one device).

        With no explicit ``step``, candidates are tried newest-first with
        full checksum verification; a truncated or bit-flipped latest
        checkpoint logs a warning and falls back to the previous GOOD one
        instead of crashing the restart loop.  An explicit ``step`` is a
        user decision: corruption there raises CheckpointCorruptError."""
        tr = self.tracer if self.tracer is not None else NULL_TRACER
        with tr.span("ckpt_restore"):
            return self._restore(like, shardings, step)

    def _restore(self, like: Any, shardings: Any,
                 step: Optional[int]) -> tuple[Any, int]:
        if step is not None:
            p = self.directory / f"step_{step:09d}"
            tree = self._with_retries(
                lambda: SER.restore_pytree(p, like, shardings),
                what=f"checkpoint restore step {step}")
            return tree, SER.checkpoint_step(p)
        candidates = SER.list_checkpoints(self.directory)
        if not candidates:
            raise FileNotFoundError(
                f"no committed checkpoint under {self.directory}")
        last_err: Optional[Exception] = None
        for p in reversed(candidates):
            try:
                tree = self._with_retries(
                    lambda p=p: SER.restore_pytree(p, like, shardings),
                    what=f"checkpoint restore {p.name}")
                return tree, SER.checkpoint_step(p)
            except SER.CheckpointCorruptError as e:
                log.warning("skipping corrupt checkpoint %s: %s", p.name, e)
                last_err = e
        raise SER.CheckpointCorruptError(
            f"all {len(candidates)} checkpoints under {self.directory} "
            f"failed verification") from last_err

    # -- preemption -----------------------------------------------------------
    def install_preemption_handler(self, get_state: Callable[[], tuple]):
        """get_state() -> (tree, step) or (tree, step, extra_meta).  On
        SIGTERM/SIGINT: blocking save, then hand the signal on.  The
        optional third element is merged into the checkpoint manifest's
        meta (the train loop uses it for telemetry controller state); the
        callable is also where callers flush side channels — it runs
        BEFORE the save, inside the handler chain.

        Previously-installed handlers are CHAINED, not replaced: after the
        flush, a caller-installed Python handler (e.g. the elastic-restart
        machinery's own teardown) runs with the same (signum, frame);
        SIG_IGN is honoured; otherwise the default disposition is restored
        and the signal re-raised.  The originals are put back once this
        handler fires (one flush per preemption) or on
        :meth:`uninstall_preemption_handler`.

        Re-installing while already installed is idempotent: the previous
        installation is torn down first, so ``prev`` always points at the
        handlers from OUTSIDE this manager — a naive double-install would
        chain the handler to itself and flush (and re-raise) twice per
        signal.
        """
        if self._prev_handlers is not None:
            self.uninstall_preemption_handler()
        prev = {}

        def handler(signum, frame):
            log.warning("signal %s: writing preemption checkpoint", signum)
            try:
                res = get_state()
                tree, step = res[0], res[1]
                extra = dict(res[2]) if len(res) > 2 and res[2] else {}
                extra["preempted"] = True
                self.save(tree, step, blocking=True, extra_meta=extra)
            finally:
                # Even a failed flush (disk full, dead ckpt dir) must hand
                # the signal on: restore the originals and chain, or the
                # elastic-restart teardown never runs and the process
                # lingers until SIGKILL.
                self.uninstall_preemption_handler()
                chained = prev.get(signum)
                if callable(chained):
                    chained(signum, frame)
                elif chained != signal.SIG_IGN:
                    signal.signal(signum, signal.SIG_DFL)
                    signal.raise_signal(signum)

        for sig in (signal.SIGTERM, signal.SIGINT):
            prev[sig] = signal.signal(sig, handler)
        self._prev_handlers = prev

    def uninstall_preemption_handler(self) -> None:
        """Put back whatever SIGTERM/SIGINT handlers were installed before
        :meth:`install_preemption_handler` (no-op if none is active)."""
        prev = getattr(self, "_prev_handlers", None)
        if not prev:
            return
        self._prev_handlers = None
        for sig, old in prev.items():
            # None = handler set outside Python (C level): leave default.
            signal.signal(sig, old if old is not None else signal.SIG_DFL)
