"""repro.serve — batched inference engines over the model zoo's KV cache.

Two schedulers share the ``Request`` contract (greedy decode, per-request
``max_new_tokens``, optional EOS):

  ``Engine`` + ``ServeConfig`` (wave; dense cache)
      The lock-step baseline: admit up to ``slots`` requests, prefill as
      one right-aligned batch, decode until the whole wave drains.  Kept
      as the dense-cache fallback and as the comparison point
      ``benchmarks/bench_serve.py`` measures against.

  ``ContinuousEngine`` + ``ContinuousConfig`` (continuous; paged cache)
      Per-slot cache positions, slot recycling the step a row finishes,
      bucketed chunked prefill interleaved with decode, and admission
      gated on KV-block occupancy with ``kind="serve"`` telemetry
      through ``repro.telemetry``'s JSONL sink.

  ``kv_cache`` — the paged/block KV cache: ``BlockAllocator`` (fixed-size
      blocks, free-list reuse, reservation ledger for OOM-free
      admission), ``SlotTable`` block tables, and ``pool_from_dense``
      for dense->paged cache adoption.  The device pool itself comes
      from ``model.init_paged_cache``; the paged attention read is
      bitwise-identical to the dense cache at equal logical lengths
      (models/attention.py).

Launcher: ``python -m repro.launch.serve`` (``--continuous/--paged``
selects the scheduler); bench: ``benchmarks/bench_serve.py`` (Poisson
open-loop, wave vs continuous -> BENCH_serve.json).
"""
from repro.serve.engine import (ContinuousConfig, ContinuousEngine, Engine,
                                Request, ServeConfig)
from repro.serve.kv_cache import (NULL_BLOCK, BlockAllocator, PoolExhausted,
                                  SlotTable, pool_from_dense)
