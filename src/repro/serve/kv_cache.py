"""Paged KV cache: fixed-size blocks, a free-list allocator, per-slot
block tables.

The device side is one POOL per layer — ``(L, num_blocks, block_size,
KV, dh)``, built by ``model.init_paged_cache`` — shared by every serving
slot.  A sequence owns an ordered list of block ids (its *block table*)
and grows it as its position advances; on completion the blocks return
to the free list and are reused by the next admitted request.  Long
prompts therefore cost exactly ``ceil(len / block_size)`` blocks instead
of the dense cache's ``cache_len`` worst-case reservation per slot.

Block 0 is RESERVED as the null block and never handed out: engine-side
block tables are padded (and idle decode rows parked) with 0, so padding
can never alias a live sequence's blocks.  Null-block contents are
garbage by design — every read of them is position-masked to exact-zero
softmax weight (see models/attention.py).

``BlockAllocator`` also carries a *reservation* ledger so admission can
guarantee a request's worst-case span (prompt + budget) up front while
physically allocating lazily: ``reserve`` at admission, ``alloc`` blocks
against the reservation as the sequence reaches them, ``release`` the
leftovers on completion.  A sequence admitted this way can never hit
pool exhaustion mid-decode, and ``occupancy()`` (allocated + reserved,
over usable blocks) is the watermark signal the engine's admission gate
and ``kind="serve"`` telemetry report.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

NULL_BLOCK = 0


class PoolExhausted(RuntimeError):
    """Raised when an alloc is attempted past the pool's capacity."""


class BlockAllocator:
    """Host-side free-list allocator over ``num_blocks`` blocks of
    ``block_size`` tokens.  Block 0 (the null block) is never allocated."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list => a finished request's blocks are the next ones
        # handed out (cache-warm reuse); ascending ids first.
        self._free = list(range(num_blocks - 1, NULL_BLOCK, -1))
        self._reserved = 0

    # -- capacity ----------------------------------------------------------
    @property
    def usable(self) -> int:
        return self.num_blocks - 1

    def free_blocks(self) -> int:
        return len(self._free)

    def available(self) -> int:
        """Blocks neither allocated nor spoken for by a reservation."""
        return len(self._free) - self._reserved

    def occupancy(self) -> float:
        """(allocated + reserved) / usable — the admission watermark."""
        return 1.0 - self.available() / self.usable

    # -- reservations ------------------------------------------------------
    def reserve(self, n: int) -> bool:
        """Earmark ``n`` blocks for a future ``alloc(reserved=True)``.
        Returns False (reserving nothing) when they are not available."""
        if n > self.available():
            return False
        self._reserved += n
        return True

    def release(self, n: int) -> None:
        """Return ``n`` unused reserved blocks to the available set."""
        if n > self._reserved:
            raise ValueError(f"release({n}) exceeds outstanding "
                             f"reservation {self._reserved}")
        self._reserved -= n

    # -- alloc / free ------------------------------------------------------
    def alloc(self, n: int, *, reserved: bool = False) -> list[int]:
        """Pop ``n`` block ids.  ``reserved=True`` draws against an
        earlier ``reserve`` (and always succeeds if the ledger is
        consistent); otherwise only unreserved blocks are eligible."""
        if reserved:
            if n > self._reserved:
                raise ValueError(f"alloc({n}, reserved=True) exceeds "
                                 f"reservation {self._reserved}")
            self._reserved -= n
        elif n > self.available():
            raise PoolExhausted(f"alloc({n}): only {self.available()} "
                                f"of {self.usable} blocks available")
        return [self._free.pop() for _ in range(n)]

    def free(self, ids: list[int]) -> None:
        for b in ids:
            if not (NULL_BLOCK < b < self.num_blocks):
                raise ValueError(f"free: invalid block id {b}")
            if b in self._free:
                raise ValueError(f"free: double-free of block {b}")
        self._free.extend(ids)

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)


@dataclasses.dataclass
class SlotTable:
    """One slot's view of the pool: its block ids in logical order."""
    blocks: list[int] = dataclasses.field(default_factory=list)

    def capacity(self, block_size: int) -> int:
        return len(self.blocks) * block_size

    def padded(self, nbt: int) -> np.ndarray:
        """(nbt,) int32 table row, null-padded — what the jitted decode
        and prefill functions consume."""
        row = np.full((nbt,), NULL_BLOCK, np.int32)
        row[:len(self.blocks)] = self.blocks
        return row


def pool_from_dense(model, dense_cache: dict, tables: list[SlotTable],
                    lengths: list[int], num_blocks: int,
                    block_size: int) -> dict:
    """Adopt a DENSE cache (``model.init_cache`` layout, (L, B, S, KV,
    dh)) into a fresh block pool: slot b's first ``lengths[b]`` positions
    are scattered into its table's blocks.  Used to migrate a wave
    engine's in-flight state to the paged engine, and by the bitwise
    parity tests to seed both representations identically."""
    import jax.numpy as jnp

    pool = model.init_paged_cache(num_blocks, block_size)
    out = {}
    for name in ("k", "v"):
        dense = np.asarray(dense_cache["kv"]._asdict()[name])
        buf = np.asarray(pool[name]).copy()
        for b, (table, n) in enumerate(zip(tables, lengths)):
            for j in range(math.ceil(n / block_size)):
                lo, hi = j * block_size, min((j + 1) * block_size, n)
                buf[:, table.blocks[j], :hi - lo] = dense[:, b, lo:hi]
        out[name] = jnp.asarray(buf)
    return out
