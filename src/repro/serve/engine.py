"""Batched serving engine: wave-scheduled prefill + decode.

Static (wave) batching: up to ``slots`` requests are admitted per wave,
prompts right-aligned/padded to a common length, prefilled as ONE batch,
then decoded in lock-step until every sequence in the wave finishes.  This
matches the cache design the dry-run cells lower (a single scalar position
per cache — the production low-complexity scheduler); continuous batching
would move to per-row positions, which the roofline cells do not require.

What this exercises end-to-end: batched prefill, jitted single-token
decode, greedy sampling, EOS/budget termination, slot accounting and
multi-wave reuse of the same compiled functions.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: "np.ndarray"          # (S,) int32
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    slots: int = 4                # decode batch per wave
    cache_len: int = 512
    eos_id: Optional[int] = None
    pad_id: int = 0


class Engine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self.waves = 0

    def _pad_prompts(self, reqs) -> jnp.ndarray:
        width = max(len(r.prompt) for r in reqs)
        batch = np.full((self.cfg.slots, width), self.cfg.pad_id, np.int32)
        for i, r in enumerate(reqs):
            batch[i, width - len(r.prompt):] = r.prompt   # right-aligned
        return jnp.asarray(batch)

    def run_wave(self, reqs: list[Request]) -> None:
        assert len(reqs) <= self.cfg.slots
        tokens = self._pad_prompts(reqs)
        cache = self.model.init_cache(self.cfg.slots, self.cfg.cache_len)
        logits, cache = self._prefill(self.params, tokens, cache)
        toks = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        budget = np.zeros((self.cfg.slots,), np.int64)
        for i, r in enumerate(reqs):
            r.out_tokens.append(int(toks[i]))
            budget[i] = r.max_new_tokens - 1

        last = jnp.asarray(toks[:, None].astype(np.int32))
        live = np.array([not r.done for r in reqs]
                        + [False] * (self.cfg.slots - len(reqs)))
        live &= budget > 0
        while live.any():
            logits, cache = self._decode(self.params, cache, last)
            toks = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
            for i, r in enumerate(reqs):
                if not live[i]:
                    continue
                tok = int(toks[i])
                r.out_tokens.append(tok)
                budget[i] -= 1
                if budget[i] <= 0 or (self.cfg.eos_id is not None
                                      and tok == self.cfg.eos_id):
                    live[i] = False
                    r.done = True
            last = jnp.asarray(toks[:, None].astype(np.int32))
        for r in reqs:
            r.done = True
        self.waves += 1

    def run(self, requests: list[Request]) -> list[Request]:
        pending = list(requests)
        while pending:
            wave, pending = (pending[:self.cfg.slots],
                             pending[self.cfg.slots:])
            self.run_wave(wave)
        return requests
