"""Serving engines: lock-step wave batching and continuous batching.

Two schedulers, one ``Request`` contract (greedy decode, per-request
``max_new_tokens`` budget, optional EOS):

``Engine`` (wave)
    The seed scheduler, kept as the baseline and dense-cache fallback:
    up to ``slots`` requests are admitted per wave, prompts
    right-aligned/padded to a common width, prefilled as ONE batch, then
    decoded in lock-step until every sequence finishes.  One slow
    sequence drains the whole batch — head-of-line blocking is the
    behaviour ``benchmarks/bench_serve.py`` quantifies.  Note the
    right-aligned pad tokens are attended to (a single scalar cache
    position forces common alignment), so a request's logits depend on
    its wave-mates' lengths; equal-length prompts are unaffected.

``ContinuousEngine`` (continuous batching + paged KV cache)
    Per-slot cache positions and slot recycling: the step any row
    finishes, its blocks return to the pool and the slot re-admits from
    the queue — no wave drain.  The KV cache is the block pool of
    ``serve/kv_cache.py``: per-slot block tables instead of a
    ``cache_len`` worst-case dense reservation per slot.  Prompts
    prefill in bucketed CHUNKS interleaved with decode (one chunk per
    engine step), so admission never stalls token emission.  Admission
    is gated on pool occupancy (``occupancy_watermark``) and the whole
    loop streams ``kind="serve"`` events (queue depth, TTFT, tokens/s,
    block occupancy) through the PR-5 telemetry sink.

    Compile-once contract: the jitted decode step sees fixed shapes
    (``slots`` rows, ``cache_len // block_size`` table columns) with
    block tables / positions as data, and prefill chunk lengths are
    bucketed to powers of two — request churn never recompiles
    (tests/test_serve.py pins the jit cache sizes).

Cache contract (models/attention.py, models/transformer.py): the paged
read gathers the pool through the block table into the logical dense
layout and runs the same ``_sdpa`` as the dense cache, masking at or
beyond each row's position to exactly-zero softmax weight — with equal
logical lengths, paged decode is BITWISE identical to the dense path.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kv_cache import NULL_BLOCK, BlockAllocator, SlotTable
from repro.telemetry.trace import NULL_TRACER, ROOT_SPAN

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: "np.ndarray"          # (S,) int32
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False        # load-shed by a bounded admission queue
    # engine-relative timestamps (seconds since run() start)
    arrival_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    # span-waterfall identity (set by an engine running under a tracer;
    # stamped into this request's kind="serve" events as the join key)
    trace: Optional[str] = None
    admit_s: Optional[float] = None


def _tr(req: Request) -> dict:
    """``trace`` field for a per-request serve event (empty if untraced)."""
    return {"trace": req.trace} if req.trace else {}


@dataclasses.dataclass
class ServeConfig:
    slots: int = 4                # decode batch per wave
    cache_len: int = 512
    eos_id: Optional[int] = None
    pad_id: int = 0


def _now(t0: float) -> float:
    return time.monotonic() - t0


class Engine:
    """Wave scheduler (see module docstring)."""

    def __init__(self, model, params, cfg: ServeConfig, sink=None,
                 tracer=None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.sink = sink
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self.waves = 0
        self.tokens_emitted = 0
        self.set_tracer(tracer)

    def set_tracer(self, tracer) -> None:
        """Attach (or with ``None`` detach) a repro.telemetry Tracer:
        each request gets a span waterfall (queued / prefill / decode
        under a per-request root) joined to its serve events by trace
        id, and waves become spans on a per-engine trace."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._tracing = tracer is not None
        self._engine_trace = (self.tracer.new_trace("wave")
                              if self._tracing else "")
        self._toff = 0.0     # engine-relative -> tracer-clock offset
        self.metrics_every = 0   # waves between registry snapshots

    def _maybe_snapshot(self, now: float, step: int) -> None:
        reg = self.tracer.registry
        if (self.metrics_every > 0 and reg is not None
                and self.sink is not None
                and step % self.metrics_every == 0):
            self.sink.emit(reg.snapshot(t_s=now, step=step))

    def _emit(self, event: str, t_s: float, **fields) -> None:
        if self.sink is not None:
            self.sink.emit({"kind": "serve", "event": event, "t_s": t_s,
                            "scheduler": "wave", **fields})

    def _pad_prompts(self, reqs) -> jnp.ndarray:
        width = max(len(r.prompt) for r in reqs)
        batch = np.full((self.cfg.slots, width), self.cfg.pad_id, np.int32)
        for i, r in enumerate(reqs):
            batch[i, width - len(r.prompt):] = r.prompt   # right-aligned
        return jnp.asarray(batch)

    def run_wave(self, reqs: list[Request], t0: Optional[float] = None):
        assert len(reqs) <= self.cfg.slots
        t0 = time.monotonic() if t0 is None else t0
        if self._tracing:
            # map engine-relative seconds onto the tracer's clock and
            # stamp a trace id on requests admitted outside run()
            self._toff = self.tracer.now() - _now(t0)
            for r in reqs:
                if r.trace is None:
                    r.trace = self.tracer.new_trace("req")
        with self.tracer.span("wave", trace=self._engine_trace) as wsp:
            wsp.set(wave=self.waves, n=len(reqs))
            self._run_wave(reqs, t0)
        self.waves += 1

    def _run_wave(self, reqs: list[Request], t0: float) -> None:
        wave_s = _now(t0)
        if self._tracing:
            for r in reqs:
                r.admit_s = wave_s
                self.tracer.record(
                    "queued", r.arrival_s + self._toff,
                    max(wave_s - r.arrival_s, 0.0), r.trace,
                    parent=ROOT_SPAN, attrs={"uid": r.uid})
        tokens = self._pad_prompts(reqs)
        cache = self.model.init_cache(self.cfg.slots, self.cfg.cache_len)
        pf0 = _now(t0)
        logits, cache = self._prefill(self.params, tokens, cache)
        toks = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        if self._tracing:
            pf1 = _now(t0)
            for r in reqs:
                self.tracer.record(
                    "prefill", pf0 + self._toff, pf1 - pf0, r.trace,
                    parent=ROOT_SPAN,
                    attrs={"uid": r.uid, "tokens": len(r.prompt)})
        budget = np.zeros((self.cfg.slots,), np.int64)
        for i, r in enumerate(reqs):
            if r.max_new_tokens <= 0:
                # a zero budget emits nothing — not even the
                # prefill-computed token
                r.done = True
                continue
            tok = int(toks[i])
            r.out_tokens.append(tok)
            r.first_token_s = _now(t0)
            self.tokens_emitted += 1
            self._emit("first_token", r.first_token_s, uid=r.uid,
                       ttft_s=r.first_token_s - r.arrival_s, **_tr(r))
            if ((self.cfg.eos_id is not None and tok == self.cfg.eos_id)
                    or r.max_new_tokens == 1):
                # EOS straight out of prefill ends the sequence here —
                # the budget may not keep a finished row decoding
                r.done = True
            else:
                budget[i] = r.max_new_tokens - 1

        live = np.array([not r.done for r in reqs]
                        + [False] * (self.cfg.slots - len(reqs)))
        live &= budget > 0
        for i, r in enumerate(reqs):
            if r.done and r.done_s is None:
                self._finish(r, _now(t0))
        last = jnp.asarray(toks[:, None].astype(np.int32))
        while live.any():
            logits, cache = self._decode(self.params, cache, last)
            toks = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
            for i, r in enumerate(reqs):
                if not live[i]:
                    continue
                tok = int(toks[i])
                r.out_tokens.append(tok)
                self.tokens_emitted += 1
                budget[i] -= 1
                if budget[i] <= 0 or (self.cfg.eos_id is not None
                                      and tok == self.cfg.eos_id):
                    live[i] = False
                    self._finish(r, _now(t0))
            last = jnp.asarray(toks[:, None].astype(np.int32))
        for r in reqs:
            if not r.done:
                self._finish(r, _now(t0))

    def _finish(self, r: Request, t_s: float) -> None:
        r.done = True
        r.done_s = t_s
        self._emit("finish", t_s, uid=r.uid, tokens=len(r.out_tokens),
                   latency_s=t_s - r.arrival_s, **_tr(r))
        if self._tracing and r.trace:
            if len(r.out_tokens) > 1 and r.first_token_s is not None:
                self.tracer.record(
                    "decode", r.first_token_s + self._toff,
                    max(t_s - r.first_token_s, 0.0), r.trace,
                    parent=ROOT_SPAN, attrs={"uid": r.uid})
            self.tracer.record(
                "request", r.arrival_s + self._toff,
                max(t_s - r.arrival_s, 0.0), r.trace, span=ROOT_SPAN,
                attrs={"uid": r.uid, "tokens": len(r.out_tokens)})

    def run(self, requests: list[Request],
            arrivals: Optional[list[float]] = None) -> list[Request]:
        """Serve ``requests``; ``arrivals[i]`` (seconds from start) makes
        the load open-loop — a wave only admits arrived requests, and an
        idle engine sleeps until the next arrival."""
        t0 = time.monotonic()
        if arrivals is None:
            arrivals = [0.0] * len(requests)
        order = sorted(range(len(requests)), key=lambda i: arrivals[i])
        pending = deque((arrivals[i], requests[i]) for i in order)
        for a, r in pending:
            r.arrival_s = a
        while pending:
            now = _now(t0)
            if pending[0][0] > now:
                time.sleep(pending[0][0] - now)
                continue
            wave = []
            while pending and len(wave) < self.cfg.slots \
                    and pending[0][0] <= _now(t0):
                wave.append(pending.popleft()[1])
            self.run_wave(wave, t0=t0)
            self._emit("stats", _now(t0), queue_depth=len(pending),
                       tokens=self.tokens_emitted, slots_active=0)
            self._maybe_snapshot(_now(t0), self.waves)
        return requests


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

_MIN_BUCKET = 8


def _bucket(n: int, cap: int) -> int:
    """Smallest power-of-two >= n (floor _MIN_BUCKET, ceiling cap)."""
    c = _MIN_BUCKET
    while c < n:
        c *= 2
    return min(c, cap)


@dataclasses.dataclass
class ContinuousConfig:
    slots: int = 4                 # concurrent sequences (decode batch)
    cache_len: int = 512           # logical per-slot maximum (tokens)
    block_size: int = 16           # tokens per KV block
    num_blocks: Optional[int] = None   # pool size; None = slots full span
    prefill_chunk: int = 64        # max prompt tokens per engine step
    eos_id: Optional[int] = None
    pad_id: int = 0
    max_queue: int = 0             # >0: load-shed arrivals past this depth
    occupancy_watermark: float = 0.95  # admission backs off above this
    stats_every: int = 32          # engine steps between stats events


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    phase: str = "idle"            # idle | prefill | decode
    table: Optional[SlotTable] = None
    length: int = 0                # tokens currently in the logical cache
    prompt_done: int = 0           # prompt tokens prefilled so far
    budget: int = 0                # generated tokens still allowed
    last_token: int = 0            # next decode input
    reserved_left: int = 0         # admission reservation not yet drawn


class ContinuousEngine:
    """Continuous-batching scheduler over the paged KV cache."""

    def __init__(self, model, params, cfg: ContinuousConfig, sink=None,
                 tracer=None):
        if not hasattr(model, "decode_paged"):
            raise TypeError(f"{type(model).__name__} has no paged decode "
                            f"path; ContinuousEngine needs a KV-cache "
                            f"model (dense/moe/vlm transformer)")
        if cfg.cache_len % cfg.block_size:
            raise ValueError("cache_len must be a multiple of block_size")
        if cfg.prefill_chunk & (cfg.prefill_chunk - 1):
            raise ValueError("prefill_chunk must be a power of two")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.sink = sink
        self.nbt = cfg.cache_len // cfg.block_size  # table width (blocks)
        num_blocks = cfg.num_blocks
        if num_blocks is None:
            num_blocks = cfg.slots * self.nbt + 1   # +1: the null block
        self.alloc = BlockAllocator(num_blocks, cfg.block_size)
        self.pool = model.init_paged_cache(num_blocks, cfg.block_size)
        self.slots = [_Slot() for _ in range(cfg.slots)]
        self.steps = 0
        self.tokens_emitted = 0
        self.completed = 0
        self._ready: "deque[Request]" = deque()
        self._rr = 0                                # prefill round-robin
        self._above_watermark = False
        self.set_tracer(tracer)

        def _decode_fn(params, pool, tokens, tables, positions):
            logits, pool = model.decode_paged(params, pool, tokens,
                                              tables, positions)
            return (jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32),
                    pool)

        def _prefill_fn(params, pool, tokens, table, p0, last_idx):
            logits, pool = model.prefill_paged(params, pool, tokens,
                                               table, p0, last_idx)
            return jnp.argmax(logits[0, -1, :]).astype(jnp.int32), pool

        # pool is donated: the engine only ever holds the latest buffer,
        # so decode/prefill update the blocks in place
        self._decode_jit = jax.jit(_decode_fn, donate_argnums=(1,))
        self._prefill_jit = jax.jit(_prefill_fn, donate_argnums=(1,))

    # -- telemetry ---------------------------------------------------------
    def set_tracer(self, tracer) -> None:
        """Attach (or with ``None`` detach) a repro.telemetry Tracer:
        every request gets a span waterfall (queued / admitted /
        prefill_chunk / decode under a per-request root span) joined to
        its serve events by trace id, engine steps become spans on a
        per-engine trace, and — when the tracer carries a registry —
        request/token counters and a latency histogram are kept."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._tracing = tracer is not None
        self._engine_trace = (self.tracer.new_trace("engine")
                              if self._tracing else "")
        self._toff = 0.0     # engine-relative -> tracer-clock offset
        self.metrics_every = 0   # engine steps between registry snapshots

    def _maybe_snapshot(self, now: float, step: int) -> None:
        reg = self.tracer.registry
        if (self.metrics_every > 0 and reg is not None
                and self.sink is not None
                and step % self.metrics_every == 0):
            self.sink.emit(reg.snapshot(t_s=now, step=step))

    def _emit(self, event: str, t_s: float, **fields) -> None:
        if self.sink is not None:
            self.sink.emit({"kind": "serve", "event": event, "t_s": t_s,
                            "scheduler": "continuous", **fields})

    # -- admission ---------------------------------------------------------
    def _chunk_plan(self, n: int) -> list[tuple[int, int, int]]:
        """(p0, real, padded) prefill chunks covering an n-token prompt."""
        plan, p0 = [], 0
        while p0 < n:
            real = min(self.cfg.prefill_chunk, n - p0)
            plan.append((p0, real, _bucket(real, self.cfg.prefill_chunk)))
            p0 += real
        return plan

    def _span(self, req: Request) -> int:
        """Worst-case logical span a request can touch: the bucket-padded
        prefill frontier or prompt + generation budget, whichever is
        larger (chunk padding writes throwaway k/v past the prompt)."""
        plan = self._chunk_plan(len(req.prompt))
        padded_end = plan[-1][0] + plan[-1][2]
        return max(padded_end, len(req.prompt) + req.max_new_tokens)

    def _validate(self, req: Request) -> None:
        if len(req.prompt) < 1:
            raise ValueError(f"req {req.uid}: empty prompt")
        span = self._span(req)
        if span > self.cfg.cache_len:
            raise ValueError(
                f"req {req.uid}: span {span} (prompt {len(req.prompt)} + "
                f"budget {req.max_new_tokens}, chunk-padded) exceeds "
                f"cache_len {self.cfg.cache_len}")
        if self.alloc.blocks_for(span) > self.alloc.usable:
            raise ValueError(f"req {req.uid}: needs "
                             f"{self.alloc.blocks_for(span)} blocks; pool "
                             f"has {self.alloc.usable}")

    def _admit(self, now: float) -> None:
        while self._ready:
            occ = self.alloc.occupancy()
            if occ >= self.cfg.occupancy_watermark:
                if not self._above_watermark:   # once per crossing
                    self._above_watermark = True
                    self._emit("backoff", now, occupancy=occ,
                               queue_depth=len(self._ready),
                               reason="occupancy_watermark")
                return
            self._above_watermark = False
            try:
                slot = next(s for s in self.slots if s.phase == "idle")
            except StopIteration:
                return
            req = self._ready[0]
            need = self.alloc.blocks_for(self._span(req))
            if not self.alloc.reserve(need):
                self._emit("backoff", now, occupancy=occ,
                           queue_depth=len(self._ready),
                           reason="reservation")
                return
            self._ready.popleft()
            slot.req = req
            slot.phase = "prefill"
            slot.table = SlotTable()
            slot.length = 0
            slot.prompt_done = 0
            slot.budget = req.max_new_tokens
            slot.reserved_left = need
            if self._tracing and req.trace:
                req.admit_s = now
                self.tracer.record(
                    "queued", req.arrival_s + self._toff,
                    max(now - req.arrival_s, 0.0), req.trace,
                    parent=ROOT_SPAN, attrs={"uid": req.uid})
            self._emit("admit", now, uid=req.uid,
                       queue_depth=len(self._ready), occupancy=occ,
                       **_tr(req))

    def _grow(self, slot: _Slot, upto_tokens: int) -> None:
        need = self.alloc.blocks_for(upto_tokens) - len(slot.table.blocks)
        if need > 0:
            n = min(need, slot.reserved_left)
            ids = self.alloc.alloc(n, reserved=True)
            if need > n:                 # past the reservation (shouldn't
                ids += self.alloc.alloc(need - n)   # happen; be safe)
            slot.reserved_left -= n
            slot.table.blocks.extend(ids)

    # -- prefill -----------------------------------------------------------
    def _prefill_one(self, now: float) -> bool:
        """Run ONE bucketed prompt chunk for the next prefilling slot
        (round-robin) — chunked prefill interleaves with decode instead
        of stalling it."""
        n = len(self.slots)
        for off in range(n):
            slot = self.slots[(self._rr + off) % n]
            if slot.phase == "prefill":
                self._rr = (self._rr + off + 1) % n
                break
        else:
            return False
        req = slot.req
        p0 = slot.prompt_done
        traced = self._tracing and req.trace
        if traced and p0 == 0 and req.admit_s is not None:
            # admission-to-first-prefill gap (slot wait + scheduling)
            self.tracer.record(
                "admitted", req.admit_s + self._toff,
                max(now - req.admit_s, 0.0), req.trace,
                parent=ROOT_SPAN, attrs={"uid": req.uid})
        real = min(self.cfg.prefill_chunk, len(req.prompt) - p0)
        padded = _bucket(real, self.cfg.prefill_chunk)
        self._grow(slot, p0 + padded)
        chunk = np.full((1, padded), self.cfg.pad_id, np.int32)
        chunk[0, :real] = req.prompt[p0:p0 + real]
        tw0 = time.monotonic()
        tok, self.pool = self._prefill_jit(
            self.params, self.pool, chunk, slot.table.padded(self.nbt),
            jnp.asarray(p0, jnp.int32), jnp.asarray(real - 1, jnp.int32))
        if traced:
            dur = time.monotonic() - tw0   # host dispatch wall time
            self.tracer.record(
                "prefill_chunk", self.tracer.now() - dur, dur, req.trace,
                parent=ROOT_SPAN,
                attrs={"uid": req.uid, "p0": p0, "tokens": real})
        slot.prompt_done += real
        if slot.prompt_done < len(req.prompt):
            return True
        # prompt complete: the chunk's last real logits give the first
        # generated token
        slot.length = len(req.prompt)
        if req.max_new_tokens <= 0:
            self._finish(slot, now)     # zero budget emits nothing
            return True
        tok = int(tok)
        req.out_tokens.append(tok)
        req.first_token_s = now
        self.tokens_emitted += 1
        self._emit("first_token", now, uid=req.uid,
                   ttft_s=now - req.arrival_s, **_tr(req))
        if ((self.cfg.eos_id is not None and tok == self.cfg.eos_id)
                or req.max_new_tokens == 1):
            self._finish(slot, now)
        else:
            slot.phase = "decode"
            slot.last_token = tok
            slot.budget = req.max_new_tokens - 1
        return True

    # -- decode ------------------------------------------------------------
    def _decode_all(self, now: float) -> bool:
        """One token for every decoding slot; idle/prefilling rows are
        parked on the null block and their outputs dropped."""
        rows = [i for i, s in enumerate(self.slots) if s.phase == "decode"]
        if not rows:
            return False
        n = self.cfg.slots
        tokens = np.zeros((n, 1), np.int32)
        tables = np.full((n, self.nbt), NULL_BLOCK, np.int32)
        positions = np.zeros((n,), np.int32)
        for i in rows:
            slot = self.slots[i]
            self._grow(slot, slot.length + 1)
            tokens[i, 0] = slot.last_token
            tables[i] = slot.table.padded(self.nbt)
            positions[i] = slot.length
        toks, self.pool = self._decode_jit(self.params, self.pool, tokens,
                                           tables, positions)
        toks = np.asarray(toks)
        for i in rows:
            slot = self.slots[i]
            tok = int(toks[i])
            slot.req.out_tokens.append(tok)
            self.tokens_emitted += 1
            slot.length += 1
            slot.budget -= 1
            slot.last_token = tok
            if slot.budget <= 0 or (self.cfg.eos_id is not None
                                    and tok == self.cfg.eos_id):
                self._finish(slot, now)
        return True

    # -- lifecycle ---------------------------------------------------------
    def _record_waterfall(self, req: Request, now: float) -> None:
        """The per-request root span (+ decode phase) at end of life —
        earlier phases (queued/admitted/prefill_chunk) were recorded as
        they happened under the same trace id."""
        if len(req.out_tokens) > 1 and req.first_token_s is not None:
            self.tracer.record(
                "decode", req.first_token_s + self._toff,
                max(now - req.first_token_s, 0.0), req.trace,
                parent=ROOT_SPAN, attrs={"uid": req.uid})
        attrs = {"uid": req.uid, "tokens": len(req.out_tokens)}
        if req.rejected:
            attrs["rejected"] = True
        self.tracer.record(
            "request", req.arrival_s + self._toff,
            max(now - req.arrival_s, 0.0), req.trace, span=ROOT_SPAN,
            attrs=attrs)
        reg = self.tracer.registry
        if reg is not None:
            labels = {"scheduler": "continuous"}
            reg.counter("serve_requests_total",
                        help="finished requests (incl. rejected)").inc(
                            1, **labels)
            reg.counter("serve_tokens_total",
                        help="generated tokens").inc(
                            len(req.out_tokens), **labels)
            reg.histogram("serve_request_latency_seconds",
                          help="arrival-to-finish latency").observe(
                              max(now - req.arrival_s, 0.0), **labels)

    def _finish(self, slot: _Slot, now: float) -> None:
        req = slot.req
        req.done = True
        req.done_s = now
        self.completed += 1
        self._emit("finish", now, uid=req.uid, tokens=len(req.out_tokens),
                   latency_s=now - req.arrival_s,
                   occupancy=self.alloc.occupancy(), **_tr(req))
        if self._tracing and req.trace:
            self._record_waterfall(req, now)
        if slot.table.blocks:
            self.alloc.free(slot.table.blocks)
        if slot.reserved_left:
            self.alloc.release(slot.reserved_left)
        slot.req = None
        slot.phase = "idle"
        slot.table = None
        slot.length = slot.prompt_done = slot.budget = 0
        slot.reserved_left = slot.last_token = 0

    def step(self, now: float) -> bool:
        """One scheduler step: admit, one prefill chunk, one decode step
        for every live row.  Returns whether any work ran."""
        with self.tracer.span("engine_step",
                              trace=self._engine_trace) as sp:
            self._admit(now)
            did = self._prefill_one(now)
            did = self._decode_all(now) or did
            sp.set(step=self.steps + 1)
        self.steps += 1
        if self.sink is not None and self.steps % self.cfg.stats_every == 0:
            self._emit("stats", now, step=self.steps,
                       queue_depth=len(self._ready),
                       occupancy=self.alloc.occupancy(),
                       slots_active=sum(s.phase != "idle"
                                        for s in self.slots),
                       tokens=self.tokens_emitted,
                       tok_per_s=self.tokens_emitted / max(now, 1e-9))
        self._maybe_snapshot(now, self.steps)
        return did

    def run(self, requests: list[Request],
            arrivals: Optional[list[float]] = None) -> list[Request]:
        """Serve ``requests`` to completion.  ``arrivals[i]`` (seconds
        from start) drives an open-loop load; requests arriving onto a
        full bounded queue (``max_queue``) are load-shed (``rejected``)."""
        for r in requests:
            self._validate(r)
        t0 = time.monotonic()
        if self._tracing:
            self._toff = self.tracer.now() - _now(t0)
        if arrivals is None:
            arrivals = [0.0] * len(requests)
        order = sorted(range(len(requests)), key=lambda i: arrivals[i])
        pending = deque((arrivals[i], requests[i]) for i in order)
        for a, r in pending:
            r.arrival_s = a
        while pending or self._ready \
                or any(s.phase != "idle" for s in self.slots):
            now = _now(t0)
            while pending and pending[0][0] <= now:
                _, req = pending.popleft()
                if self._tracing and req.trace is None:
                    req.trace = self.tracer.new_trace("req")
                if 0 < self.cfg.max_queue <= len(self._ready):
                    req.rejected = True
                    req.done = True
                    req.done_s = now
                    self._emit("reject", now, uid=req.uid,
                               queue_depth=len(self._ready), **_tr(req))
                    if self._tracing and req.trace:
                        self.tracer.record(
                            "queued", req.arrival_s + self._toff,
                            max(now - req.arrival_s, 0.0), req.trace,
                            parent=ROOT_SPAN, attrs={"uid": req.uid})
                        self._record_waterfall(req, now)
                    continue
                self._ready.append(req)
            if not self.step(now) and not self._ready:
                if pending:
                    time.sleep(max(pending[0][0] - _now(t0), 0.0))
        return requests
