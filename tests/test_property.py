"""Hypothesis property tests on system invariants.

Requires the optional ``hypothesis`` dev dependency (see ROADMAP.md
§Tooling); the module skips cleanly when it is absent so the tier-1 run
never aborts at collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import srsi as S
from repro.core import rank as R
from repro.core import AdapproxConfig, RankConfig, adapprox, tree_nbytes
from repro.distributed.straggler import StragglerConfig, StragglerMonitor

SET = dict(max_examples=15, deadline=None)


@given(m=st.integers(8, 96), n=st.integers(8, 96), r=st.integers(1, 8),
       scale_exp=st.integers(-12, 6))
@settings(**SET)
def test_srsi_projection_contraction_any_scale(m, n, r, scale_exp):
    """||A - QU^T||_F <= ||A||_F for every shape/rank/magnitude, and the
    factors are finite — including magnitudes that underflow naive power
    iteration (the scale-normalisation invariant)."""
    r = min(r, min(m, n) - 1) or 1
    key = jax.random.PRNGKey(m * 1000 + n * 10 + r)
    a = jnp.abs(jax.random.normal(key, (m, n))) * (10.0 ** scale_exp)
    res = S.srsi_dense(a, r, 2, 2, jax.random.fold_in(key, 1))
    assert np.all(np.isfinite(np.asarray(res.q)))
    assert np.all(np.isfinite(np.asarray(res.u)))
    approx = res.q @ res.u.T
    na = float(jnp.linalg.norm(a))
    assert float(jnp.linalg.norm(a - approx)) <= na * (1 + 1e-3) + 1e-30


@given(m=st.integers(16, 64), n=st.integers(16, 64), r=st.integers(2, 6))
@settings(**SET)
def test_srsi_q_orthonormal(m, n, r):
    key = jax.random.PRNGKey(m + n * 131 + r)
    a = jnp.abs(jax.random.normal(key, (m, n)))
    res = S.srsi_dense(a, r, 2, 2, jax.random.fold_in(key, 7))
    gram = np.asarray(res.q.T @ res.q)
    # columns either orthonormal or dropped (zero)
    diag = np.diag(gram)
    for i in range(r):
        assert abs(diag[i] - 1.0) < 1e-4 or abs(diag[i]) < 1e-6
    off = gram - np.diag(diag)
    assert np.abs(off).max() < 1e-4


@given(decay=st.floats(0.3, 0.95), thresh=st.floats(0.005, 0.3))
@settings(**SET)
def test_rank_selection_feasible_or_kmax(decay, thresh):
    col = decay ** jnp.arange(64)
    cum = jnp.cumsum(col / jnp.sum(col))
    cfg = R.RankConfig(xi_thresh=thresh, k_init=1)
    k = int(R.select_rank_paper_iteration(cum, jnp.asarray(1.0), cfg, 64))
    xi = float(R.xi_of_k(cum, jnp.asarray(1.0), jnp.asarray(k)))
    assert xi <= thresh + 1e-6 or k == 64


@given(b1=st.sampled_from([0.0, 0.9]), d=st.floats(0.1, 2.0),
       gscale=st.floats(1e-4, 1e3))
@settings(**SET)
def test_adapprox_update_rms_bounded(b1, d, gscale):
    """Post-clip update RMS <= lr * d regardless of gradient scale
    (first step, wd = 0; EMA of clipped updates keeps the bound)."""
    params = {"w": jnp.zeros((64, 64))}
    cfg = AdapproxConfig(lr=1.0, b1=b1, clip_d=d, weight_decay=0.0,
                         min_dim_factor=1, oversample=2, n_iter=2,
                         rank=RankConfig(k_init=4, mode="static"))
    opt = adapprox(cfg)
    state = opt.init(params)
    g = {"w": gscale * jax.random.normal(jax.random.PRNGKey(3), (64, 64))}
    upd, _ = opt.update(g, state, params)
    rms = float(jnp.sqrt(jnp.mean(jnp.square(upd["w"]))))
    assert rms <= d * (1 + 1e-3)


@given(seq=st.lists(st.floats(0.05, 0.15), min_size=30, max_size=60))
@settings(**SET)
def test_straggler_never_escalates_on_uniform(seq):
    mon = StragglerMonitor(StragglerConfig(persist=3))
    for t in seq:
        mon.observe(t)
    assert not mon.escalations


@given(rows=st.integers(1, 64), inner=st.integers(1, 8),
       depth=st.integers(1, 4), width=st.integers(1, 32),
       b2=st.floats(0.5, 0.999), steps=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_count_min_query_never_underestimates(rows, inner, depth, width,
                                              b2, steps, seed):
    """The sketch backend's core invariant: after any number of EMA
    steps, the min-over-depth query is >= the exact per-row second-moment
    EMA for EVERY row (additions are non-negative, decay is uniform,
    collisions only add mass)."""
    from repro.core.sketch import _leaf_seeds, bucket_indices
    from repro.kernels import ref

    rng = np.random.default_rng(seed)
    idx = jnp.asarray(bucket_indices(rows, width, _leaf_seeds(seed, 0, depth)))
    table = jnp.zeros((depth, width, inner), jnp.float32)
    exact = np.zeros((rows, inner), np.float32)
    for _ in range(steps):
        g = jnp.asarray(rng.standard_normal((rows, inner)), jnp.float32)
        table, q = ref.sketch_update(table, g, idx, b2)
        exact = b2 * exact + (1.0 - b2) * np.square(np.asarray(g))
        assert np.all(np.asarray(q) >= exact * (1 - 1e-5) - 1e-7)


@given(k=st.integers(1, 32))
@settings(**SET)
def test_factored_state_memory_monotone_in_rank(k):
    params = {"w": jnp.zeros((256, 256))}
    def nbytes(kk):
        opt = adapprox(AdapproxConfig(
            rank=RankConfig(k_init=kk, mode="static"), b1=0.0,
            min_dim_factor=1))
        return tree_nbytes(opt.init(params))
    assert nbytes(k) <= nbytes(k + 1)
