"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs forward + one train step + prefill/decode
on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import applicable_cells
from repro.configs import ASSIGNED, get_config, get_smoke_config, make_batch
from repro.core import apply_updates, make_optimizer
from repro.models import build_model

B, S = 2, 32


def _setup(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S)
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", ASSIGNED + ["gpt2-117m", "gpt2-345m"])
def test_forward_shapes_and_finite(arch):
    cfg, model, params, batch = _setup(arch)
    logits, _ = model.forward(params, batch["tokens"],
                              batch.get("embeds"))
    n_front = 0
    if cfg.family == "vlm":
        n_front = cfg.frontend_tokens
        assert logits.shape == (B, S, cfg.vocab)
    elif cfg.family == "encdec":
        assert logits.shape == (B, batch["tokens"].shape[1], cfg.vocab)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step_with_adapprox(arch):
    cfg, model, params, batch = _setup(arch)
    opt = make_optimizer("adapprox", lr=1e-3, k_init=4, mode="static",
                         min_dim_factor=16, oversample=2, n_iter=2)
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, metrics), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        upd, s = opt.update(g, s, p)
        return apply_updates(p, upd), s, loss

    p1, state, loss = step(params, state, batch)
    assert np.isfinite(float(loss))
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         params, p1)
    assert max(jax.tree.leaves(moved)) > 0.0
    # second step stays finite
    _, _, loss2 = step(p1, state, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_then_decode(arch):
    cfg, model, params, batch = _setup(arch)
    cache = model.init_cache(B, cache_len=S + 8)
    if cfg.family in ("encdec", "vlm"):
        if cfg.family == "encdec":
            logits, cache = model.prefill(params, batch["tokens"], cache,
                                          embeds=batch["embeds"])
        else:
            logits, cache = model.prefill(params, batch["tokens"], cache)
    else:
        logits, cache = model.prefill(params, batch["tokens"], cache)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-370m", "zamba2-2.7b"])
def test_decode_consistent_with_forward(arch):
    """Greedy prefill+decode must match the full forward's next-token
    argmax at the same position."""
    cfg, model, params, batch = _setup(arch)
    tokens = batch["tokens"]
    logits_full, _ = model.forward(params, tokens)
    cache = model.init_cache(B, cache_len=S + 4)
    logits_pre, _ = model.prefill(params, tokens, cache)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1, :], np.float32),
        np.asarray(logits_full[:, -1, :], np.float32), rtol=2e-2, atol=2e-2)


def test_param_specs_mirror_params():
    for arch in ASSIGNED:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        specs = model.param_specs()
        jax.tree.map(lambda p, s: None, params, specs,
                     is_leaf=lambda x: isinstance(x, tuple) and all(
                         isinstance(e, (str, type(None))) for e in x))
        # same structure when specs' tuples are treated as leaves
        pleaves = jax.tree.leaves(params)
        sleaves = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, tuple))
        assert len(pleaves) == len(sleaves), arch
        for p, s in zip(pleaves, sleaves):
            assert p.ndim == len(s), (arch, p.shape, s)


def test_full_configs_match_assignment():
    """Exact numbers from the assignment sheet."""
    c = get_config("deepseek-67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (95, 8192, 64, 8, 22016, 102400)
    c = get_config("kimi-k2-1t-a32b")
    assert c.moe.n_experts == 384 and c.moe.top_k == 8
    assert c.vocab == 163840 and c.d_model == 7168 and c.n_layers == 61
    c = get_config("zamba2-2.7b")
    assert c.ssm.d_state == 64 and c.n_layers == 54
    c = get_config("qwen3-14b")
    assert c.qk_norm and c.n_kv_heads == 8
    c = get_config("qwen2-7b")
    assert c.qkv_bias
    c = get_config("mamba2-370m")
    assert c.ssm.d_state == 128 and c.n_heads == 0
    c = get_config("whisper-large-v3")
    assert c.enc_layers == 32 and c.vocab == 51866
    c = get_config("olmoe-1b-7b")
    assert c.moe.n_experts == 64 and c.moe.top_k == 8
    c = get_config("minitron-4b")
    assert c.vocab == 256000
    c = get_config("llava-next-mistral-7b")
    assert c.frontend == "vision" and c.d_ff == 14336


def test_long_context_cells_only_for_subquadratic():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        cells = applicable_cells(cfg)
        if arch in ("mamba2-370m", "zamba2-2.7b"):
            assert "long_500k" in cells
        else:
            assert "long_500k" not in cells


def test_param_count_sane():
    """Analytic param counts in the right ballpark for named sizes."""
    approx = {
        "qwen2-7b": 7.6e9, "deepseek-67b": 67e9, "qwen3-14b": 14e9,
        "minitron-4b": 4e9, "mamba2-370m": 0.37e9,
        "kimi-k2-1t-a32b": 1.0e12, "olmoe-1b-7b": 6.9e9,
    }
    for arch, expect in approx.items():
        n = get_config(arch).param_count()
        assert 0.5 * expect < n < 1.7 * expect, (arch, n, expect)
