"""Pallas kernel sweeps: shapes x dtypes against the pure-jnp oracles,
executed in interpret mode on CPU (the kernel body itself runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (128, 128, 8),     # aligned
    (256, 192, 16),
    (100, 130, 4),     # unaligned -> exercises padding
    (512, 64, 32),
    (64, 512, 3),      # r not lane-aligned
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.fixture(autouse=True)
def force_pallas():
    ops.set_mode("pallas")      # interpret=True on CPU
    yield
    ops.set_mode("auto")


def _mk(m, n, r, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (m, r), jnp.float32)
    u = jax.random.normal(jax.random.fold_in(key, 1), (n, r), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 2), (m, n)).astype(dtype)
    return q, u, g


@pytest.mark.parametrize("m,n,r", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_lowrank_update_matches_ref(m, n, r, dtype):
    q, u, g = _mk(m, n, r, dtype)
    out_k, fro_k = ops.lowrank_update(q, u, g, 0.999, 1e-8, with_frob=True)
    out_r, fro_r = ref.lowrank_update(q, u, g, 0.999, 1e-8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(fro_k), float(fro_r), rtol=1e-3)


@pytest.mark.parametrize("m,n,r", SHAPES[:3])
def test_lowrank_update_batched(m, n, r):
    qs = jnp.stack([_mk(m, n, r, jnp.float32, s)[0] for s in range(3)])
    us = jnp.stack([_mk(m, n, r, jnp.float32, s)[1] for s in range(3)])
    gs = jnp.stack([_mk(m, n, r, jnp.float32, s)[2] for s in range(3)])
    out = ops.lowrank_update(qs, us, gs, 0.99, 1e-8)
    assert out.shape == (3, m, n)
    for i in range(3):
        expect, _ = ref.lowrank_update(qs[i], us[i], gs[i], 0.99, 1e-8)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n,s", [(128, 128, 8), (256, 100, 16),
                                   (96, 320, 40), (33, 65, 7)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_sq_matmul_matches_ref(m, n, s, dtype):
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (m, n)).astype(dtype)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, s), jnp.float32)
    got = ops.sq_matmul(g, x)
    want = ref.sq_matmul(g, x)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * float(jnp.abs(want).max()))


@pytest.mark.parametrize("m,n,s", [(128, 96, 8), (70, 50, 5)])
def test_sq_matmul_t_matches_ref(m, n, s):
    key = jax.random.PRNGKey(4)
    g = jax.random.normal(key, (m, n))
    y = jax.random.normal(jax.random.fold_in(key, 1), (m, s), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.sq_matmul_t(g, y)),
                               np.asarray(ref.sq_matmul_t(g, y)),
                               rtol=1e-4, atol=1e-3)


def test_update_zero_grad_is_zero():
    q, u, g = _mk(128, 128, 8, jnp.float32)
    out = ops.lowrank_update(q, u, jnp.zeros_like(g), 0.999, 1e-8)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


@pytest.mark.parametrize("m,n,r", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_precond_matches_ref(m, n, r, dtype):
    q, u, g = _mk(m, n, r, dtype)
    out_k, vfro_k, usq_k, _, _, _ = ops.fused_precond(q, u, g, 0.999, 1e-8)
    out_r, vfro_r, usq_r, _, _, _ = ref.fused_precond(q, u, g, 0.999, 1e-8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(vfro_k), float(vfro_r), rtol=1e-3)
    np.testing.assert_allclose(float(usq_k), float(usq_r), rtol=1e-3)


@pytest.mark.parametrize("m,n,r", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_precond_guided_matches_ref(m, n, r, dtype):
    q, u, g = _mk(m, n, r, dtype)
    m1 = jax.random.normal(jax.random.PRNGKey(7), (m, n), jnp.float32)
    got = ops.fused_precond(q, u, g, 0.999, 1e-8, m1=m1)
    want = ref.fused_precond(q, u, g, 0.999, 1e-8, m1=m1)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=2e-4, atol=2e-4)
    for k, w in zip(got[1:5], want[1:5]):        # vfro, usq, m1dot, m1sq
        np.testing.assert_allclose(float(k), float(w), rtol=1e-3)


@pytest.mark.parametrize("m,n,r", SHAPES[:3])
def test_fused_precond_batched(m, n, r):
    qs = jnp.stack([_mk(m, n, r, jnp.float32, s)[0] for s in range(3)])
    us = jnp.stack([_mk(m, n, r, jnp.float32, s)[1] for s in range(3)])
    gs = jnp.stack([_mk(m, n, r, jnp.float32, s)[2] for s in range(3)])
    out, vfro, usq, _, _, _ = ops.fused_precond(qs, us, gs, 0.99, 1e-8)
    assert out.shape == (3, m, n) and vfro.shape == (3,) and usq.shape == (3,)
    for i in range(3):
        eo, ev, eu, _, _, _ = ref.fused_precond(qs[i], us[i], gs[i],
                                                0.99, 1e-8)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(eo),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(usq[i]), float(eu), rtol=1e-3)


@pytest.mark.parametrize("m,n", [(128, 128), (100, 130), (64, 512)])
@pytest.mark.parametrize("shared", [False, True])
def test_fused_apply_matches_ref(m, n, shared):
    key = jax.random.PRNGKey(11)
    u_hat = jax.random.normal(key, (m, n), jnp.float32)
    m1 = jax.random.normal(jax.random.fold_in(key, 1), (m, n), jnp.float32)
    d = jnp.float32(1.7)
    os_, ss = jnp.float32(2.5), jnp.float32(2.5 if shared else 1.0)
    got_out, got_m1 = ops.fused_apply(u_hat, m1, d, 0.9, os_, ss,
                                      shared_out=shared)
    want_out, want_m1 = ref.fused_apply(u_hat, m1, d, 0.9, os_, ss)
    np.testing.assert_allclose(np.asarray(got_out), np.asarray(want_out),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_m1), np.asarray(want_m1),
                               rtol=1e-5, atol=1e-6)


def test_fused_apply_batched_and_b1_zero():
    key = jax.random.PRNGKey(12)
    u_hat = jax.random.normal(key, (3, 96, 80), jnp.float32)
    m1 = jax.random.normal(jax.random.fold_in(key, 1), (3, 96, 80),
                           jnp.float32)
    d = jnp.asarray([1.0, 2.0, 0.5], jnp.float32)
    s = jnp.ones((3,), jnp.float32)
    out, m1n = ops.fused_apply(u_hat, m1, d, 0.9, s, s)
    for i in range(3):
        eo, em = ref.fused_apply(u_hat[i], m1[i], d[i], 0.9, s[i], s[i])
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(eo),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(m1n[i]), np.asarray(em),
                                   rtol=1e-5, atol=1e-6)
    # b1 = 0: no first moment, pure scaled copy
    out0, none = ops.fused_apply(u_hat, None, d, 0.0, s, s)
    assert none is None
    np.testing.assert_allclose(np.asarray(out0),
                               np.asarray(u_hat / d[:, None, None]),
                               rtol=1e-6)


def test_kernel_path_in_optimizer_matches_ref_path():
    """AdapproxConfig(use_kernels=True) must produce the same update as the
    reference path (kernels run in interpret mode here)."""
    from repro.core import AdapproxConfig, RankConfig, adapprox
    params = {"w": jax.random.normal(jax.random.PRNGKey(5), (160, 144)) * 0.1}
    g = {"w": jax.random.normal(jax.random.PRNGKey(6), (160, 144))}
    outs = {}
    for use in (False, True):
        cfg = AdapproxConfig(lr=1e-3, min_dim_factor=1, oversample=2,
                             n_iter=2, use_kernels=use,
                             rank=RankConfig(k_init=8, mode="static"))
        opt = adapprox(cfg)
        st = opt.init(params)
        upd, _ = opt.update(g, st, params)
        outs[use] = np.asarray(upd["w"])
    np.testing.assert_allclose(outs[True], outs[False], rtol=2e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Ragged shapes: fold, fold-fused pass 1, quantized tile loads, bucketing
# ---------------------------------------------------------------------------

RAGGED = [(130, 258, 3), (97, 140, 4), (257, 129, 5)]


@pytest.mark.parametrize("m,n,r", RAGGED + SHAPES[:2])
def test_one_sided_fold_matches_ref(m, n, r):
    q, u, g = _mk(m, n, r, jnp.float32, seed=m)
    mask = (jnp.arange(r) < max(1, r - 1)).astype(jnp.float32)
    got = ops.one_sided_fold(u, q, g, 0.999, col_mask=mask)
    want = ref.one_sided_fold(u, q, g, 0.999, col_mask=mask)
    assert got.shape == (n, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("m,n,r", RAGGED + SHAPES[:2])
def test_fused_precond_with_fold_matches_ref(m, n, r):
    """Fold-fused pass 1: the extra (G^2)^T Q output must match the ref
    oracle on ragged shapes (row/col/r padding all in play at once)."""
    q, u, g = _mk(m, n, r, jnp.float32, seed=n)
    got = ops.fused_precond(q, u, g, 0.999, 1e-8, with_fold=True)
    want = ref.fused_precond(q, u, g, 0.999, 1e-8, with_fold=True)
    assert got[5].shape == (n, r)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(got[1]), float(want[1]), rtol=1e-3)
    np.testing.assert_allclose(np.asarray(got[5]), np.asarray(want[5]),
                               rtol=2e-4,
                               atol=1e-4 * float(jnp.abs(want[5]).max()))


@pytest.mark.parametrize("m,n,r", [(256, 256, 8), (130, 258, 3),
                                   (300, 180, 6)])
def test_fused_precond_quantized_matches_dequantized_ref(m, n, r):
    """int8-dequant tile loads: fused_precond on QuantizedMatrix factors
    must match the ref oracle run on the host-dequantized factors — the
    in-kernel codec and row masks are exact, not approximate."""
    from repro.core import quantized as QZ
    q, u, g = _mk(m, n, r, jnp.float32, seed=r)
    qm, um = QZ.quantize(q), QZ.quantize(u)
    got = ops.fused_precond(qm, um, g, 0.999, 1e-8, with_fold=True)
    want = ref.fused_precond(QZ.dequantize(qm), QZ.dequantize(um), g,
                             0.999, 1e-8, with_fold=True)
    assert got[0].shape == (m, n) and got[5].shape == (n, r)
    # rtol 1e-3 (vs 2e-4 on the f32 tests): where the reconstructed V is
    # near zero, u_hat = g/(sqrt(V)+eps) amplifies matmul tile-order ULP
    # noise; the codec itself is exact (bitwise test in test_fused.py)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(got[1]), float(want[1]), rtol=1e-3)
    np.testing.assert_allclose(float(got[2]), float(want[2]), rtol=1e-3)
    np.testing.assert_allclose(np.asarray(got[5]), np.asarray(want[5]),
                               rtol=2e-4,
                               atol=1e-4 * float(jnp.abs(want[5]).max()))


def test_bucketing_is_bit_neutral_and_collapses_instances():
    """Mixed near-miss shapes: bucketing must not change a single bit of
    the tensor outputs (zero padding is exact elementwise and append-only
    in the dot reductions), and may move the scalar tile reductions only
    at f32 roundoff (the in-tile sum tree reshapes with the block size),
    while collapsing the dispatch census to one padded signature."""
    shapes = [(100, 130, 3), (97, 140, 4)]
    outs = {}
    try:
        for bucketed in (False, True):
            ops.set_bucketing(bucketed)
            ops.reset_kernel_instances()
            res = []
            for (m, n, r) in shapes:
                q, u, g = _mk(m, n, r, jnp.float32, seed=m + n)
                out, vfro, usq, _, _, yf = ops.fused_precond(
                    q, u, g, 0.999, 1e-8, with_fold=True)
                res.append((np.asarray(out), float(vfro), float(usq),
                            np.asarray(yf)))
            keys = {k for k in ops.kernel_instances()
                    if k[0] == "fused_precond"}
            outs[bucketed] = (res, keys)
    finally:
        ops.set_bucketing(True)
        ops.reset_kernel_instances()
    (res_u, keys_u), (res_b, keys_b) = outs[False], outs[True]
    for (a, av, au, ay), (b, bv, bu, by) in zip(res_u, res_b):
        np.testing.assert_array_equal(a, b)          # bitwise
        np.testing.assert_array_equal(ay, by)        # bitwise
        np.testing.assert_allclose(av, bv, rtol=1e-6)
        np.testing.assert_allclose(au, bu, rtol=1e-6)
    assert len(keys_b) == 1, keys_b    # 100/97 -> 128, 130/140 -> 192
    assert len(keys_u) == 2, keys_u
