"""Pallas kernel sweeps: shapes x dtypes against the pure-jnp oracles,
executed in interpret mode on CPU (the kernel body itself runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (128, 128, 8),     # aligned
    (256, 192, 16),
    (100, 130, 4),     # unaligned -> exercises padding
    (512, 64, 32),
    (64, 512, 3),      # r not lane-aligned
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.fixture(autouse=True)
def force_pallas():
    ops.set_mode("pallas")      # interpret=True on CPU
    yield
    ops.set_mode("auto")


def _mk(m, n, r, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (m, r), jnp.float32)
    u = jax.random.normal(jax.random.fold_in(key, 1), (n, r), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 2), (m, n)).astype(dtype)
    return q, u, g


@pytest.mark.parametrize("m,n,r", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_lowrank_update_matches_ref(m, n, r, dtype):
    q, u, g = _mk(m, n, r, dtype)
    out_k, fro_k = ops.lowrank_update(q, u, g, 0.999, 1e-8, with_frob=True)
    out_r, fro_r = ref.lowrank_update(q, u, g, 0.999, 1e-8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(fro_k), float(fro_r), rtol=1e-3)


@pytest.mark.parametrize("m,n,r", SHAPES[:3])
def test_lowrank_update_batched(m, n, r):
    qs = jnp.stack([_mk(m, n, r, jnp.float32, s)[0] for s in range(3)])
    us = jnp.stack([_mk(m, n, r, jnp.float32, s)[1] for s in range(3)])
    gs = jnp.stack([_mk(m, n, r, jnp.float32, s)[2] for s in range(3)])
    out = ops.lowrank_update(qs, us, gs, 0.99, 1e-8)
    assert out.shape == (3, m, n)
    for i in range(3):
        expect, _ = ref.lowrank_update(qs[i], us[i], gs[i], 0.99, 1e-8)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n,s", [(128, 128, 8), (256, 100, 16),
                                   (96, 320, 40), (33, 65, 7)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_sq_matmul_matches_ref(m, n, s, dtype):
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (m, n)).astype(dtype)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, s), jnp.float32)
    got = ops.sq_matmul(g, x)
    want = ref.sq_matmul(g, x)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * float(jnp.abs(want).max()))


@pytest.mark.parametrize("m,n,s", [(128, 96, 8), (70, 50, 5)])
def test_sq_matmul_t_matches_ref(m, n, s):
    key = jax.random.PRNGKey(4)
    g = jax.random.normal(key, (m, n))
    y = jax.random.normal(jax.random.fold_in(key, 1), (m, s), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.sq_matmul_t(g, y)),
                               np.asarray(ref.sq_matmul_t(g, y)),
                               rtol=1e-4, atol=1e-3)


def test_update_zero_grad_is_zero():
    q, u, g = _mk(128, 128, 8, jnp.float32)
    out = ops.lowrank_update(q, u, jnp.zeros_like(g), 0.999, 1e-8)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


def test_kernel_path_in_optimizer_matches_ref_path():
    """AdapproxConfig(use_kernels=True) must produce the same update as the
    reference path (kernels run in interpret mode here)."""
    from repro.core import AdapproxConfig, RankConfig, adapprox
    params = {"w": jax.random.normal(jax.random.PRNGKey(5), (160, 144)) * 0.1}
    g = {"w": jax.random.normal(jax.random.PRNGKey(6), (160, 144))}
    outs = {}
    for use in (False, True):
        cfg = AdapproxConfig(lr=1e-3, min_dim_factor=1, oversample=2,
                             n_iter=2, use_kernels=use,
                             rank=RankConfig(k_init=8, mode="static"))
        opt = adapprox(cfg)
        st = opt.init(params)
        upd, _ = opt.update(g, st, params)
        outs[use] = np.asarray(upd["w"])
    np.testing.assert_allclose(outs[True], outs[False], rtol=2e-4, atol=1e-6)
