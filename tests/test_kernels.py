"""Pallas kernel sweeps: shapes x dtypes against the pure-jnp oracles,
executed in interpret mode on CPU (the kernel body itself runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (128, 128, 8),     # aligned
    (256, 192, 16),
    (100, 130, 4),     # unaligned -> exercises padding
    (512, 64, 32),
    (64, 512, 3),      # r not lane-aligned
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.fixture(autouse=True)
def force_pallas():
    ops.set_mode("pallas")      # interpret=True on CPU
    yield
    ops.set_mode("auto")


def _mk(m, n, r, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (m, r), jnp.float32)
    u = jax.random.normal(jax.random.fold_in(key, 1), (n, r), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 2), (m, n)).astype(dtype)
    return q, u, g


@pytest.mark.parametrize("m,n,r", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_lowrank_update_matches_ref(m, n, r, dtype):
    q, u, g = _mk(m, n, r, dtype)
    out_k, fro_k = ops.lowrank_update(q, u, g, 0.999, 1e-8, with_frob=True)
    out_r, fro_r = ref.lowrank_update(q, u, g, 0.999, 1e-8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(fro_k), float(fro_r), rtol=1e-3)


@pytest.mark.parametrize("m,n,r", SHAPES[:3])
def test_lowrank_update_batched(m, n, r):
    qs = jnp.stack([_mk(m, n, r, jnp.float32, s)[0] for s in range(3)])
    us = jnp.stack([_mk(m, n, r, jnp.float32, s)[1] for s in range(3)])
    gs = jnp.stack([_mk(m, n, r, jnp.float32, s)[2] for s in range(3)])
    out = ops.lowrank_update(qs, us, gs, 0.99, 1e-8)
    assert out.shape == (3, m, n)
    for i in range(3):
        expect, _ = ref.lowrank_update(qs[i], us[i], gs[i], 0.99, 1e-8)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n,s", [(128, 128, 8), (256, 100, 16),
                                   (96, 320, 40), (33, 65, 7)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_sq_matmul_matches_ref(m, n, s, dtype):
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (m, n)).astype(dtype)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, s), jnp.float32)
    got = ops.sq_matmul(g, x)
    want = ref.sq_matmul(g, x)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * float(jnp.abs(want).max()))


@pytest.mark.parametrize("m,n,s", [(128, 96, 8), (70, 50, 5)])
def test_sq_matmul_t_matches_ref(m, n, s):
    key = jax.random.PRNGKey(4)
    g = jax.random.normal(key, (m, n))
    y = jax.random.normal(jax.random.fold_in(key, 1), (m, s), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.sq_matmul_t(g, y)),
                               np.asarray(ref.sq_matmul_t(g, y)),
                               rtol=1e-4, atol=1e-3)


def test_update_zero_grad_is_zero():
    q, u, g = _mk(128, 128, 8, jnp.float32)
    out = ops.lowrank_update(q, u, jnp.zeros_like(g), 0.999, 1e-8)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


@pytest.mark.parametrize("m,n,r", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_precond_matches_ref(m, n, r, dtype):
    q, u, g = _mk(m, n, r, dtype)
    out_k, vfro_k, usq_k, _, _ = ops.fused_precond(q, u, g, 0.999, 1e-8)
    out_r, vfro_r, usq_r, _, _ = ref.fused_precond(q, u, g, 0.999, 1e-8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(vfro_k), float(vfro_r), rtol=1e-3)
    np.testing.assert_allclose(float(usq_k), float(usq_r), rtol=1e-3)


@pytest.mark.parametrize("m,n,r", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_precond_guided_matches_ref(m, n, r, dtype):
    q, u, g = _mk(m, n, r, dtype)
    m1 = jax.random.normal(jax.random.PRNGKey(7), (m, n), jnp.float32)
    got = ops.fused_precond(q, u, g, 0.999, 1e-8, m1=m1)
    want = ref.fused_precond(q, u, g, 0.999, 1e-8, m1=m1)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=2e-4, atol=2e-4)
    for k, w in zip(got[1:], want[1:]):          # vfro, usq, m1dot, m1sq
        np.testing.assert_allclose(float(k), float(w), rtol=1e-3)


@pytest.mark.parametrize("m,n,r", SHAPES[:3])
def test_fused_precond_batched(m, n, r):
    qs = jnp.stack([_mk(m, n, r, jnp.float32, s)[0] for s in range(3)])
    us = jnp.stack([_mk(m, n, r, jnp.float32, s)[1] for s in range(3)])
    gs = jnp.stack([_mk(m, n, r, jnp.float32, s)[2] for s in range(3)])
    out, vfro, usq, _, _ = ops.fused_precond(qs, us, gs, 0.99, 1e-8)
    assert out.shape == (3, m, n) and vfro.shape == (3,) and usq.shape == (3,)
    for i in range(3):
        eo, ev, eu, _, _ = ref.fused_precond(qs[i], us[i], gs[i], 0.99, 1e-8)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(eo),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(usq[i]), float(eu), rtol=1e-3)


@pytest.mark.parametrize("m,n", [(128, 128), (100, 130), (64, 512)])
@pytest.mark.parametrize("shared", [False, True])
def test_fused_apply_matches_ref(m, n, shared):
    key = jax.random.PRNGKey(11)
    u_hat = jax.random.normal(key, (m, n), jnp.float32)
    m1 = jax.random.normal(jax.random.fold_in(key, 1), (m, n), jnp.float32)
    d = jnp.float32(1.7)
    os_, ss = jnp.float32(2.5), jnp.float32(2.5 if shared else 1.0)
    got_out, got_m1 = ops.fused_apply(u_hat, m1, d, 0.9, os_, ss,
                                      shared_out=shared)
    want_out, want_m1 = ref.fused_apply(u_hat, m1, d, 0.9, os_, ss)
    np.testing.assert_allclose(np.asarray(got_out), np.asarray(want_out),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_m1), np.asarray(want_m1),
                               rtol=1e-5, atol=1e-6)


def test_fused_apply_batched_and_b1_zero():
    key = jax.random.PRNGKey(12)
    u_hat = jax.random.normal(key, (3, 96, 80), jnp.float32)
    m1 = jax.random.normal(jax.random.fold_in(key, 1), (3, 96, 80),
                           jnp.float32)
    d = jnp.asarray([1.0, 2.0, 0.5], jnp.float32)
    s = jnp.ones((3,), jnp.float32)
    out, m1n = ops.fused_apply(u_hat, m1, d, 0.9, s, s)
    for i in range(3):
        eo, em = ref.fused_apply(u_hat[i], m1[i], d[i], 0.9, s[i], s[i])
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(eo),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(m1n[i]), np.asarray(em),
                                   rtol=1e-5, atol=1e-6)
    # b1 = 0: no first moment, pure scaled copy
    out0, none = ops.fused_apply(u_hat, None, d, 0.0, s, s)
    assert none is None
    np.testing.assert_allclose(np.asarray(out0),
                               np.asarray(u_hat / d[:, None, None]),
                               rtol=1e-6)


def test_kernel_path_in_optimizer_matches_ref_path():
    """AdapproxConfig(use_kernels=True) must produce the same update as the
    reference path (kernels run in interpret mode here)."""
    from repro.core import AdapproxConfig, RankConfig, adapprox
    params = {"w": jax.random.normal(jax.random.PRNGKey(5), (160, 144)) * 0.1}
    g = {"w": jax.random.normal(jax.random.PRNGKey(6), (160, 144))}
    outs = {}
    for use in (False, True):
        cfg = AdapproxConfig(lr=1e-3, min_dim_factor=1, oversample=2,
                             n_iter=2, use_kernels=use,
                             rank=RankConfig(k_init=8, mode="static"))
        opt = adapprox(cfg)
        st = opt.init(params)
        upd, _ = opt.update(g, st, params)
        outs[use] = np.asarray(upd["w"])
    np.testing.assert_allclose(outs[True], outs[False], rtol=2e-4, atol=1e-6)
