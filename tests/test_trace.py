"""Span-tracing suite (repro.telemetry.trace + the instrumented loops).

Pins the observability PR's contracts:
  * live spans nest per thread, inherit the enclosing trace id, and land
    in the JSONL sink as schema-valid ``kind="span"`` events;
  * ``drain_open`` (the preemption path) emits exactly ONE event per
    span — the truncated drain wins over the normal ``__exit__``;
  * ``check_events`` catches orphaned parents, negative durations and
    incomplete request waterfalls — the ``tools/traceview.py --check``
    CI gate;
  * the train loop emits a ``train_step`` span per step with
    data_wait / step_dispatch / device_sync children, refresh-vs-fold
    attribution from the in-jit snapshot counters, and checkpoint
    save/restore spans — while the trained state stays BITWISE identical
    to an untraced run (spans never enter jit);
  * the committed BENCH_step_time.json pins host-side tracing overhead
    <= 3% wall vs the telemetry row.
"""
import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig
from repro.config import OptimizerConfig
from repro.core import build_optimizer
from repro.data import DataConfig
from repro.telemetry import (SinkConfig, TelemetrySink, Tracer,
                             check_events, chrome_trace, load_events,
                             span_stats, step_breakdown, validate_dir)
from repro.telemetry.trace import ROOT_SPAN
from repro.train import LoopConfig, train

REPO = Path(__file__).resolve().parent.parent


def _tracer(tmp_path, sub="trace"):
    sink = TelemetrySink(SinkConfig(directory=str(tmp_path / sub)))
    return Tracer(sink=sink), sink, tmp_path / sub


def _drain(sink, d):
    sink.flush()
    sink.close()
    return load_events(d)


# ---------------------------------------------------------------------------
# span API
# ---------------------------------------------------------------------------

class TestSpans:
    def test_nesting_inherits_trace_and_parent(self, tmp_path):
        tracer, sink, d = _tracer(tmp_path)
        with tracer.span("outer") as o:
            with tracer.span("inner") as i:
                assert i.trace == o.trace
        events = _drain(sink, d)
        assert validate_dir(d) == 2
        by_name = {e["name"]: e for e in events}
        assert by_name["inner"]["parent"] == by_name["outer"]["span"]
        assert by_name["inner"]["trace"] == by_name["outer"]["trace"]
        assert "parent" not in by_name["outer"]
        assert by_name["outer"]["dur_s"] >= by_name["inner"]["dur_s"] >= 0
        assert check_events(events) == []

    def test_attrs_promote_step_uid(self, tmp_path):
        tracer, sink, d = _tracer(tmp_path)
        with tracer.span("s", step=7, uid=3, phase="refresh"):
            pass
        (e,) = _drain(sink, d)
        assert e["step"] == 7 and e["uid"] == 3
        assert e["attrs"] == {"phase": "refresh"}

    def test_record_builds_rooted_waterfall(self, tmp_path):
        tracer, sink, d = _tracer(tmp_path)
        t = tracer.new_trace("req")
        tracer.record("queued", 0.0, 0.5, t, parent=ROOT_SPAN)
        tracer.record("request", 0.0, 2.0, t, span=ROOT_SPAN)
        events = _drain(sink, d)
        assert check_events(events) == []
        root = next(e for e in events if e["name"] == "request")
        assert root["span"] == ROOT_SPAN

    def test_drain_open_emits_exactly_once(self, tmp_path):
        """A span open when drain_open fires (the SIGTERM path) is
        emitted truncated; the interrupted ``__exit__`` must NOT emit a
        second event for the same span id."""
        tracer, sink, d = _tracer(tmp_path)
        cm = tracer.span("interrupted")
        cm.__enter__()
        tracer.drain_open()
        cm.__exit__(None, None, None)
        events = _drain(sink, d)
        assert len(events) == 1
        assert events[0]["truncated"] is True
        assert events[0]["name"] == "interrupted"

    def test_null_tracer_sinkless_tracer_are_noops(self, tmp_path):
        from repro.telemetry import NULL_TRACER
        with NULL_TRACER.span("x") as h:
            h.set(step=1)
        NULL_TRACER.record("y", 0, 1, "t")
        NULL_TRACER.drain_open()
        sinkless = Tracer()       # times and discards
        with sinkless.span("z"):
            pass
        sinkless.flush()


# ---------------------------------------------------------------------------
# analysis helpers
# ---------------------------------------------------------------------------

def _sp(**kw):
    """Hand-built schema-valid span event."""
    e = {"kind": "span", "schema": 1, "trace": "t",
         "t0_s": 0.0, "dur_s": 1.0}
    e.update(kw)
    return e


def _finish(**kw):
    e = {"kind": "serve", "schema": 1, "event": "finish", "t_s": 1.0,
         "scheduler": "continuous", "uid": 0, "tokens": 5, "trace": "t"}
    e.update(kw)
    return e


class TestAnalysis:
    def test_span_stats_percentiles(self):
        events = [{"kind": "span", "name": "s", "trace": "t",
                   "span": f"s{i}", "t0_s": 0.0, "dur_s": float(i)}
                  for i in range(1, 101)]
        s = span_stats(events)["s"]
        assert s["count"] == 100
        assert s["p50_s"] == pytest.approx(50.5)
        assert s["p95_s"] == pytest.approx(95.05)
        assert s["p99_s"] == pytest.approx(99.01)

    def test_chrome_trace_structure(self, tmp_path):
        tracer, sink, d = _tracer(tmp_path)
        with tracer.span("a", step=1):
            with tracer.span("b"):
                pass
        ct = chrome_trace(_drain(sink, d))
        xs = [e for e in ct["traceEvents"] if e["ph"] == "X"]
        ms = [e for e in ct["traceEvents"] if e["ph"] == "M"]
        assert {e["name"] for e in xs} == {"a", "b"}
        assert len(ms) == 1                      # one trace -> one tid
        assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
        assert xs[0]["args"].get("step") == 1 or \
            xs[1]["args"].get("step") == 1

    def test_check_events_flags_orphans(self):
        events = [_sp(name="child", span="s1", parent="missing")]
        assert any("orphaned" in p for p in check_events(events))

    def test_check_events_flags_negative_duration(self):
        events = [_sp(name="s", span="s1", dur_s=-0.1)]
        assert any("negative" in p for p in check_events(events))

    def test_check_events_flags_incomplete_waterfall(self):
        events = [
            _finish(),
            _sp(name="request", span=ROOT_SPAN, dur_s=1.0),
        ]
        probs = check_events(events)
        assert any("incomplete waterfall" in p for p in probs)
        # completing it silences the check
        events += [
            _sp(name="queued", span="s1", parent=ROOT_SPAN, dur_s=0.1),
            _sp(name="prefill_chunk", span="s2", parent=ROOT_SPAN,
                t0_s=0.1, dur_s=0.2),
            _sp(name="decode", span="s3", parent=ROOT_SPAN,
                t0_s=0.3, dur_s=0.6),
        ]
        assert check_events(events) == []

    def test_truncated_trace_exempt_from_completeness(self):
        events = [
            _finish(),
            _sp(name="request", span=ROOT_SPAN, dur_s=1.0,
                truncated=True),
        ]
        assert check_events(events) == []


# ---------------------------------------------------------------------------
# train-loop integration
# ---------------------------------------------------------------------------

class _QuadraticModel:
    """Minimal model satisfying the train-loop protocol; the 8x8 matrix
    leaf is factorable under min_dim_factor=4 (refresh/fold test)."""

    def init(self, key):
        del key
        return {"w": jnp.ones((8, 8))}

    def loss(self, params, batch):
        del batch
        l = jnp.sum(jnp.square(params["w"])) * 1e-3
        return l, {"loss": l}


_DATA = DataConfig(vocab=8, seq_len=4, global_batch=2)


def _adamw():
    return build_optimizer(OptimizerConfig(name="adamw",
                                           schedule="constant", lr=1e-3))


class TestTrainLoop:
    def test_step_spans_and_breakdown(self, tmp_path):
        tracer, sink, d = _tracer(tmp_path)
        train(_QuadraticModel(), _adamw(), _DATA,
              LoopConfig(total_steps=5, log_every=1), tracer=tracer)
        events = _drain(sink, d)
        assert check_events(events) == []
        stats = span_stats(events)
        for name in ("train_step", "data_wait", "step_dispatch",
                     "device_sync"):
            assert stats[name]["count"] == 5, name
        bd = step_breakdown(events)
        assert bd["steps"] == 5
        assert {p["phase"] for p in bd["phases"]} >= {
            "data_wait", "step_dispatch", "device_sync"}
        # shares account for the whole step
        assert sum(p["share"] for p in bd["phases"]) == pytest.approx(1.0)

    def test_tracing_is_bitwise_invisible(self, tmp_path):
        """Spans are host-side only: the trained state must be BITWISE
        identical with tracing on and off."""
        tracer, sink, d = _tracer(tmp_path)
        ref, _ = train(_QuadraticModel(), _adamw(), _DATA,
                       LoopConfig(total_steps=4, log_every=2))
        traced, _ = train(_QuadraticModel(), _adamw(), _DATA,
                          LoopConfig(total_steps=4, log_every=2),
                          tracer=tracer)
        sink.close()
        np.testing.assert_array_equal(np.asarray(ref.params["w"]),
                                      np.asarray(traced.params["w"]))

    def test_refresh_vs_fold_attribution(self, tmp_path):
        """train_step spans carry the refresh-vs-fold phase read from the
        in-jit snapshot counters (refresh_every=2: step 1 refreshes,
        step 2 folds, ...)."""
        tracer, sink, d = _tracer(tmp_path)
        opt = build_optimizer(OptimizerConfig(
            name="adapprox", schedule="constant", lr=1e-3, k=2,
            rank_mode="static", min_dim_factor=4, implicit=False,
            refresh_every=2, telemetry=True))
        train(_QuadraticModel(), opt, _DATA,
              LoopConfig(total_steps=4, log_every=1), tracer=tracer)
        events = _drain(sink, d)
        steps = sorted((e for e in events if e["name"] == "train_step"),
                       key=lambda e: e["step"])
        phases = [e["attrs"]["phase"] for e in steps]
        assert phases[0] == "refresh"
        assert set(phases) == {"refresh", "fold"}
        bd = step_breakdown(events)
        assert set(bd["refresh_vs_fold"]) == {"refresh", "fold"}

    def test_checkpoint_spans(self, tmp_path):
        tracer, sink, d = _tracer(tmp_path)
        ck = CheckpointConfig(directory=str(tmp_path / "ck"),
                              save_every=2, async_save=False)
        train(_QuadraticModel(), _adamw(), _DATA,
              LoopConfig(total_steps=4, log_every=2, ckpt=ck),
              tracer=tracer)
        # restart: restore gets its own span
        train(_QuadraticModel(), _adamw(), _DATA,
              LoopConfig(total_steps=5, log_every=2, ckpt=ck),
              tracer=tracer)
        events = _drain(sink, d)
        assert check_events(events) == []
        stats = span_stats(events)
        for name in ("checkpoint_save", "ckpt_gather", "ckpt_write"):
            assert stats[name]["count"] >= 2, name
        assert stats["ckpt_restore"]["count"] == 1


# ---------------------------------------------------------------------------
# committed bench artifact: tracing overhead pin
# ---------------------------------------------------------------------------

def test_bench_trace_overhead_within_3pct():
    """The committed BENCH_step_time.json carries the traced row (4
    recorded spans per step through a real JSONL sink); host-side
    tracing overhead vs the telemetry row is pinned <= 3% wall."""
    data = json.loads((REPO / "BENCH_step_time.json").read_text())
    by_name = {r["name"]: r["ms_per_step"] for r in data["results"]}
    assert "adapprox_refresh5_warm1_traced" in by_name
    ratio = data["derived"]["trace_overhead_vs_refresh5_warm1_telemetry"]
    assert ratio <= 1.03, f"tracing overhead {ratio:.3f}x > 1.03x"
