"""Adapprox optimizer behaviour tests (Algorithm 3 fidelity + invariants)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdapproxConfig, AdapproxState, RankConfig, adapprox,
                        adapprox_state, apply_updates, make_optimizer,
                        rank_metrics, tree_nbytes)
from repro.core import factored as F


def make_params(key, factor_dims=(256, 192)):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, factor_dims) * 0.02,
        "b": jnp.zeros((factor_dims[1],)),
        "stack": jax.random.normal(k2, (3,) + factor_dims) * 0.02,
    }


def make_grads(key, params):
    return jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, p.size), p.shape),
        params)


def small_cfg(**kw):
    base = dict(lr=1e-3, b1=0.9, b2=0.999, min_dim_factor=128,
                oversample=4, n_iter=3,
                rank=RankConfig(k_init=4, k_max=32, mode="paper",
                                xi_thresh=0.05, delta_s=5))
    base.update(kw)
    return AdapproxConfig(**base)


def test_state_layout():
    params = make_params(jax.random.PRNGKey(0))
    opt = adapprox(small_cfg())
    state = opt.init(params)
    leaves = dict(zip(["b", "stack", "w"],
                      adapprox_state(state).leaves))  # dict flatten order
    assert isinstance(leaves["w"], F.FactoredLeaf)
    assert isinstance(leaves["stack"], F.FactoredLeaf)
    assert isinstance(leaves["b"], F.DenseLeaf)
    assert leaves["stack"].q.shape[0] == 3          # batched over the stack
    assert leaves["w"].q.shape == (256, leaves["w"].q.shape[-1])
    assert leaves["w"].m1.shape == (256, 192)


def test_no_first_moment_when_b1_zero():
    params = make_params(jax.random.PRNGKey(0))
    opt = adapprox(small_cfg(b1=0.0))
    state = opt.init(params)
    for leaf in adapprox_state(state).leaves:
        assert leaf.m1 is None
    grads = make_grads(jax.random.PRNGKey(1), params)
    updates, state = jax.jit(opt.update)(grads, state, params)
    assert all(np.all(np.isfinite(np.asarray(u)))
               for u in jax.tree.leaves(updates))


def test_update_clipping_bounds_rms():
    """After clipping, RMS(update)/lr <= d (before weight decay, b1=0)."""
    params = {"w": jnp.zeros((256, 256))}
    cfg = small_cfg(b1=0.0, clip_d=1.0, lr=1.0, weight_decay=0.0)
    opt = adapprox(cfg)
    state = opt.init(params)
    grads = {"w": 100.0 * jax.random.normal(jax.random.PRNGKey(2), (256, 256))}
    updates, _ = jax.jit(opt.update)(grads, state, params)
    rms = float(jnp.sqrt(jnp.mean(jnp.square(updates["w"]))))
    assert rms <= 1.0 + 1e-4


def test_factored_tracks_dense_oracle():
    """With full-rank storage the factored second moment must reproduce the
    exact-V Adapprox trajectory."""
    m = n = 64
    params = {"w": jax.random.normal(jax.random.PRNGKey(3), (m, n)) * 0.1}
    cfg = small_cfg(min_dim_factor=1, b1=0.9,
                    rank=RankConfig(k_init=64, mode="static"),
                    oversample=0, n_iter=6)
    opt = adapprox(cfg)
    state = opt.init(params)

    # dense oracle
    v = jnp.zeros((m, n))
    m1 = jnp.zeros((m, n))
    w_or = params["w"]
    w_fac = params["w"]
    key = jax.random.PRNGKey(4)
    upd = jax.jit(opt.update)
    for t in range(1, 6):
        g = jax.random.normal(jax.random.fold_in(key, t), (m, n))
        # oracle step (Algorithm 3 with exact V)
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = g / (jnp.sqrt(v) + cfg.eps)
        u = u / jnp.maximum(1.0, jnp.sqrt(jnp.mean(u**2) + 1e-30) / cfg.clip_d)
        m1 = cfg.b1 * m1 + (1 - cfg.b1) * u
        w_or = w_or - 1e-3 * m1
        # factored step
        updates, state = upd({"w": g}, state, {"w": w_fac})
        w_fac = w_fac + updates["w"]
        np.testing.assert_allclose(np.asarray(w_fac), np.asarray(w_or),
                                   rtol=2e-3, atol=2e-6)


def test_adaptive_rank_rises_for_high_rank_v():
    """A gradient stream with many dominant directions must push k above
    k_init when xi_thresh is tight."""
    params = {"w": jnp.zeros((256, 256))}
    cfg = small_cfg(b1=0.0, rank=RankConfig(k_init=1, k_max=64, mode="paper",
                                            xi_thresh=0.01, delta_s=1))
    opt = adapprox(cfg)
    state = opt.init(params)
    key = jax.random.PRNGKey(5)
    upd = jax.jit(opt.update)
    for t in range(1, 4):
        g = jax.random.normal(jax.random.fold_in(key, t), (256, 256))
        _, state = upd({"w": g}, state, params)
    k = int(adapprox_state(state).leaves[0].k)
    assert k > 1, "adaptive rank should grow for a near-full-rank V"
    xi = float(adapprox_state(state).leaves[0].xi)
    assert xi <= 0.01 + 1e-5 or k == 64


def test_adaptive_rank_stays_low_for_rank1_v():
    """Rank-1 gradient stream (outer product) -> xi tiny at k = 1."""
    params = {"w": jnp.zeros((256, 256))}
    cfg = small_cfg(b1=0.0, rank=RankConfig(k_init=1, k_max=64, mode="paper",
                                            xi_thresh=0.01, delta_s=1))
    opt = adapprox(cfg)
    state = opt.init(params)
    r = jax.random.normal(jax.random.PRNGKey(6), (256, 1))
    c = jax.random.normal(jax.random.PRNGKey(7), (1, 256))
    upd = jax.jit(opt.update)
    for t in range(1, 4):
        _, state = upd({"w": r @ c}, state, params)
    assert int(adapprox_state(state).leaves[0].k) <= 2


def test_implicit_mode_matches_explicit():
    params = {"w": jax.random.normal(jax.random.PRNGKey(8), (128, 128)) * 0.1}
    g = jax.random.normal(jax.random.PRNGKey(9), (128, 128))
    outs = []
    for implicit in (False, True):
        cfg = small_cfg(min_dim_factor=1, implicit=implicit, seed=0)
        opt = adapprox(cfg)
        state = opt.init(params)
        updates, state2 = jax.jit(opt.update)({"w": g}, state, params)
        outs.append((np.asarray(updates["w"]),
                     np.asarray(adapprox_state(state2).leaves[0].q)))
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=1e-3, atol=1e-5)


def test_weight_decay_decoupled():
    """wd must act on W directly, not scale with the gradient path."""
    params = {"w": jnp.full((128, 128), 2.0)}
    cfg = small_cfg(b1=0.0, weight_decay=0.1, lr=0.5, min_dim_factor=1)
    opt = adapprox(cfg)
    state = opt.init(params)
    updates, _ = jax.jit(opt.update)({"w": jnp.zeros((128, 128))}, state,
                                     params)
    # zero grad => update = -lr * wd * W = -0.5*0.1*2 = -0.1
    np.testing.assert_allclose(np.asarray(updates["w"]), -0.1, atol=1e-6)


def test_guidance_modes():
    params = {"w": jnp.zeros((128, 128))}
    g = jax.random.normal(jax.random.PRNGKey(10), (128, 128))
    mags = {}
    for mode in ("off", "update", "stored"):
        cfg = small_cfg(guidance=mode, min_dim_factor=1, seed=0)
        opt = adapprox(cfg)
        state = opt.init(params)
        upd = jax.jit(opt.update)
        updates, state = upd({"w": g}, state, params)
        updates, state = upd({"w": g}, state, params)  # aligned stream
        mags[mode] = float(jnp.sqrt(jnp.mean(updates["w"] ** 2)))
    # repeated identical gradients => high cosine similarity => guidance
    # amplifies the step
    assert mags["update"] > mags["off"]
    assert mags["stored"] >= mags["update"] * 0.99


def test_memory_factored_vs_adamw():
    """Factored state must be much smaller than AdamW's for big matrices
    (Table 2 direction)."""
    params = {"w": jnp.zeros((1024, 1024))}
    ada = adapprox(small_cfg(b1=0.0,
                             rank=RankConfig(k_init=8, mode="static")))
    aw = make_optimizer("adamw")
    nb_ada = tree_nbytes(ada.init(params))
    nb_aw = tree_nbytes(aw.init(params))
    assert nb_ada < nb_aw * 0.05


def test_update_entry_amplification_bounded():
    """Where the low-rank V-hat underestimates, |u| is still bounded by
    1/sqrt(1-b2) because V_t >= (1-b2) G^2 elementwise (the fresh-G^2 term is
    exact).  This is the stability floor that lets Adapprox survive
    approximation error (cf. paper App. A clipping discussion)."""
    params = {"w": jnp.zeros((256, 256))}
    cfg = small_cfg(b1=0.0, b2=0.999, clip_d=1e9, lr=1.0,
                    rank=RankConfig(k_init=1, mode="static"))
    opt = adapprox(cfg)
    state = opt.init(params)
    g = jax.random.normal(jax.random.PRNGKey(11), (256, 256))
    updates, _ = jax.jit(opt.update)({"w": g}, state, params)
    bound = 1.0 / np.sqrt(1.0 - 0.999)
    assert float(jnp.max(jnp.abs(updates["w"]))) <= bound * (1 + 1e-4)


def test_rank_metrics():
    params = make_params(jax.random.PRNGKey(0))
    opt = adapprox(small_cfg())
    state = opt.init(params)
    m = rank_metrics(state)
    assert "adapprox/mean_rank" in m
