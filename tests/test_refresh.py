"""Amortized second-moment refresh: warm-start S-RSI, refresh-interval
scheduling (factor folding), bucketed leaf execution, and the streaming
frob_sq — the perf mechanisms behind ``refresh_every`` / ``warm_start`` /
``bucketed`` (all default-off; the default chain stays bit-exact vs seed,
which tests/test_compose.py::test_chained_adapprox_matches_seed_monolith
continues to enforce)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adapprox_state, apply_updates, make_optimizer
from repro.core import srsi as S
from repro.core.adamw import AdamWConfig, adamw
from repro.core.transform import partition
from repro.kernels import ops as KO
from repro.kernels import ref as KR


# ---------------------------------------------------------------------------
# warm-start S-RSI
# ---------------------------------------------------------------------------

def _drifting_ema(key, m, n, steps, b2=0.99, rank=6):
    """An EMA second-moment stream with a stable dominant subspace: V_t =
    b2 V_{t-1} + (1-b2) (M + eps*N_t)^2 for a fixed low-rank-ish M."""
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (m, rank))
    b = jax.random.normal(k2, (rank, n))
    scales = 10.0 ** (-jnp.arange(rank, dtype=jnp.float32) / 2.0)
    base = (a * scales) @ b
    v = jnp.zeros((m, n))
    out = []
    for t in range(steps):
        noise = jax.random.normal(jax.random.fold_in(key, 100 + t), (m, n))
        g = base + 0.05 * noise
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        out.append(v)
    return out


def test_warm_start_converges_to_cold_subspace():
    """Warm-started l=1 S-RSI tracks the same dominant subspace as a cold
    l=5 run on a slowly-drifting EMA matrix: after a few steps the top-k
    principal angles between the two bases are small, and the captured
    energy matches."""
    mats = _drifting_ema(jax.random.PRNGKey(0), 128, 96, steps=10)
    r, p = 12, 4
    u_warm = None
    res_w = res_c = None
    for t, v in enumerate(mats):
        key = jax.random.fold_in(jax.random.PRNGKey(1), t)
        res_c = S.srsi_dense(v, r, p, n_iter=5, key=key)
        res_w = S.srsi_dense(v, r, p, n_iter=1, key=key, u0=u_warm,
                             use_warm=None if u_warm is None else
                             jnp.asarray(True))
        u_warm = res_w.u
    # top-4 principal angles: cos(theta) = singular values of Qw^T Qc
    k = 4
    sv = jnp.linalg.svd(res_w.q[:, :k].T @ res_c.q[:, :k],
                        compute_uv=False)
    assert float(jnp.min(sv)) > 0.95, sv
    # captured-energy parity at full stored rank (relative)
    ew = float(res_w.cum_energy[-1] / res_w.frob_sq)
    ec = float(res_c.cum_energy[-1] / res_c.frob_sq)
    assert ew > ec - 0.02, (ew, ec)


def test_warm_start_zero_u0_falls_back_to_gaussian():
    """All-zero warm columns (init state) must reproduce the cold sketch
    bit-for-bit — the per-column fallback re-randomizes them."""
    a = jnp.square(jax.random.normal(jax.random.PRNGKey(2), (64, 48)))
    key = jax.random.PRNGKey(3)
    cold = S.srsi_dense(a, 8, 4, 2, key)
    warm = S.srsi_dense(a, 8, 4, 2, key, u0=jnp.zeros((48, 8)),
                        use_warm=jnp.asarray(True))
    np.testing.assert_array_equal(np.asarray(cold.q), np.asarray(warm.q))
    np.testing.assert_array_equal(np.asarray(cold.u), np.asarray(warm.u))


def test_warm_start_drift_guard_rerandomizes():
    """use_warm=False (the xi drift guard tripping) must drop the warm seed
    entirely and reproduce the cold-start result bit-for-bit."""
    a = jnp.square(jax.random.normal(jax.random.PRNGKey(4), (64, 48)))
    key = jax.random.PRNGKey(5)
    junk = jax.random.normal(jax.random.PRNGKey(6), (48, 8))
    cold = S.srsi_dense(a, 8, 4, 2, key)
    guarded = S.srsi_dense(a, 8, 4, 2, key, u0=junk,
                           use_warm=jnp.asarray(False))
    np.testing.assert_array_equal(np.asarray(cold.q), np.asarray(guarded.q))
    np.testing.assert_array_equal(np.asarray(cold.u), np.asarray(guarded.u))


# ---------------------------------------------------------------------------
# streaming frob_sq (implicit mode no longer materializes V)
# ---------------------------------------------------------------------------

def test_streaming_frob_sq_matches_dense():
    """Tiled frob_sq == sum(materialize()**2) incl. the tile-wise clamp,
    for row counts that don't divide the tile (padding path)."""
    key = jax.random.PRNGKey(7)
    for m, n, tile in [(130, 48, 64), (512, 32, 128), (64, 96, 512)]:
        q = jax.random.normal(jax.random.fold_in(key, m), (m, 6))
        u = jax.random.normal(jax.random.fold_in(key, m + 1), (n, 6))
        g = jax.random.normal(jax.random.fold_in(key, m + 2), (m, n))
        v = S.make_implicit_v(q, u, g, 0.99)
        want = float(jnp.sum(jnp.square(v.materialize())))
        got = float(v.frob_sq(row_tile=tile))
        np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# one-sided fold (refresh-interval mode's between-refresh update)
# ---------------------------------------------------------------------------

def test_one_sided_fold_kernel_matches_ref():
    key = jax.random.PRNGKey(8)
    u = jax.random.normal(key, (48, 8))
    q = jax.random.normal(jax.random.fold_in(key, 1), (64, 8))
    g = jax.random.normal(jax.random.fold_in(key, 2), (64, 48))
    mask = (jnp.arange(8) < 5).astype(jnp.float32)
    want = KR.one_sided_fold(u, q, g, 0.999, mask)
    prev = KO._MODE
    try:
        KO.set_mode("ref")
        got = KO.one_sided_fold(u, q, g, 0.999, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # batched leading dim
        ub, qb, gb = (jnp.stack([x, x]) for x in (u, q, g))
        gotb = KO.one_sided_fold(ub, qb, gb, 0.999, mask)
        np.testing.assert_allclose(np.asarray(gotb[0]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        KO.set_mode("pallas")       # interpret mode off-TPU
        got_k = KO.one_sided_fold(u, q, g, 0.999, mask)
        np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
    finally:
        KO.set_mode(prev)


def test_fold_step_update_is_exact_wrt_implicit_operator():
    """On a non-refresh step the elementwise update must STILL be the exact
    Adapprox rule u = G/(sqrt(V_t)+eps) with V_t = b2*max(QU^T,0)+(1-b2)G^2
    built from the stored factors — folding only amortizes the
    re-factorization, never the update."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(9), (160, 144)) * 0.02}
    opt = make_optimizer("adapprox", lr=1.0, weight_decay=0.0, b1=0.0,
                         k_init=8, mode="static", min_dim_factor=64,
                         refresh_every=3)
    state = opt.init(params)
    upd = jax.jit(opt.update)
    p = params
    cfg_b2, cfg_eps, clip_d = 0.999, 1e-8, 1.0
    for t in range(1, 4):
        st_pre = adapprox_state(state)
        q, u = st_pre.leaves[0].q, st_pre.leaves[0].u
        g = {"w": jax.random.normal(jax.random.fold_in(
            jax.random.PRNGKey(10), t), (160, 144))}
        got, state = upd(g, state, p)
        v = (cfg_b2 * jnp.maximum(q @ u.T, 0.0)
             + (1.0 - cfg_b2) * jnp.square(g["w"]))
        want = g["w"] / (jnp.sqrt(v) + cfg_eps)
        want = want / jnp.maximum(
            1.0, jnp.sqrt(jnp.mean(jnp.square(want)) + 1e-30) / clip_d)
        np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(-want),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"step {t}")
        st_post = adapprox_state(state)
        if t in (2, 3):            # fold steps: basis frozen, k/xi kept
            np.testing.assert_array_equal(np.asarray(st_post.leaves[0].q),
                                          np.asarray(q))
        p = apply_updates(p, got)


def test_fold_tracks_projected_ema():
    """Across a fold interval the stored U equals the explicit rank-
    projected EMA  U_t = b2*U_{t-1} + (1-b2)(G^2)^T Q  under the frozen
    refresh-step basis."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(11), (160, 144)) * 0.02}
    opt = make_optimizer("adapprox", lr=1e-3, weight_decay=0.0, b1=0.0,
                         k_init=8, mode="static", min_dim_factor=64,
                         refresh_every=4)
    state = opt.init(params)
    upd = jax.jit(opt.update)
    p, b2 = params, 0.999
    u_ref = q_ref = None
    for t in range(1, 5):
        g = {"w": jax.random.normal(jax.random.fold_in(
            jax.random.PRNGKey(12), t), (160, 144))}
        got, state = upd(g, state, p)
        st = adapprox_state(state)
        if t == 1:                 # refresh step: adopt the new basis
            q_ref, u_ref = st.leaves[0].q, st.leaves[0].u
        else:                      # fold: U <- b2 U + (1-b2)(G^2)^T Q
            u_ref = b2 * u_ref + (1.0 - b2) * (
                jnp.square(g["w"]).T @ q_ref)
            np.testing.assert_array_equal(np.asarray(st.leaves[0].q),
                                          np.asarray(q_ref))
            np.testing.assert_allclose(np.asarray(st.leaves[0].u),
                                       np.asarray(u_ref),
                                       rtol=1e-5, atol=1e-7)
        p = apply_updates(p, got)


# ---------------------------------------------------------------------------
# checkpoint/restore across a refresh interval
# ---------------------------------------------------------------------------

def _toy_partitioned_opt():
    labeler = lambda params: jax.tree.map(
        lambda p: "factored" if p.ndim >= 2 else "dense", params)
    sub_f = make_optimizer("adapprox", lr=1e-3, weight_decay=0.0,
                           k_init=6, mode="static", min_dim_factor=64,
                           refresh_every=3, warm_start=True, n_iter_warm=1)
    sub_d = adamw(AdamWConfig(lr=1e-3))
    return partition(labeler, {"factored": sub_f, "dense": sub_d})


def test_refresh_every_checkpoint_roundtrip():
    """A mid-refresh-interval checkpoint/restore through PartitionState is
    bit-transparent: the refresh phase is a pure function of state.step, so
    serializing the state to host numpy and rebuilding it continues the
    trajectory bit-for-bit (incl. which steps refresh vs fold)."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(13), (160, 144)) * 0.02,
              "b": jnp.zeros((144,))}
    opt = _toy_partitioned_opt()
    gkey = jax.random.PRNGKey(14)
    grads = lambda t, p: jax.tree.map(lambda x: jax.random.normal(
        jax.random.fold_in(gkey, t * 17 + x.size), x.shape), p)
    upd = jax.jit(opt.update)

    # uninterrupted run: 5 steps (refresh at t=1 and t=4, folds between)
    state = opt.init(params)
    p = params
    for t in range(1, 6):
        u, state = upd(grads(t, p), state, p)
        p = apply_updates(p, u)

    # interrupted run: stop after t=2 (mid-interval), round-trip the state
    # through host numpy (what a checkpoint does), continue
    state2 = opt.init(params)
    p2 = params
    for t in range(1, 3):
        u, state2 = upd(grads(t, p2), state2, p2)
        p2 = apply_updates(p2, u)
    flat, treedef = jax.tree.flatten(state2)
    restored = jax.tree.unflatten(
        treedef, [jnp.asarray(np.asarray(x)) for x in flat])
    for t in range(3, 6):
        u, restored = upd(grads(t, p2), restored, p2)
        p2 = apply_updates(p2, u)

    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bucketed leaf execution
# ---------------------------------------------------------------------------

def _bucket_params():
    key = jax.random.PRNGKey(15)
    mk = lambda i, s: jax.random.normal(jax.random.fold_in(key, i), s) * 0.02
    return {
        "attn_q": mk(0, (160, 144)),
        "attn_k": mk(1, (160, 144)),
        "attn_v": mk(2, (160, 144)),
        "proj": mk(3, (144, 160)),
        "stack": mk(4, (3, 160, 144)),
        "bias": jnp.zeros((144,)),
    }


def _run_steps(opt, params, n_steps, gkey):
    state = opt.init(params)
    upd = jax.jit(opt.update)
    p = params
    for t in range(1, n_steps + 1):
        g = jax.tree.map(lambda x: jax.random.normal(
            jax.random.fold_in(gkey, t * 31 + x.size), x.shape), p)
        u, state = upd(g, state, p)
        p = apply_updates(p, u)
    return p, state


def _assert_same_adapprox_run(p_seq, s_seq, p_bkt, s_bkt):
    """Updates/params and every trajectory-relevant state field (q, u, k,
    m1, dense v, step, key) must match bit-for-bit.  The metrics-only
    ``xi`` scalar is allowed 1 float32 ulp: XLA's fusion emitter compiles
    the gather+div+sqrt chain of xi_of_k differently inside batched vs
    unbatched programs (every constituent primitive is bit-stable under
    vmap in isolation — verified — but fused neighborhoods differ), and xi
    never feeds back into the update arithmetic (factored.py documents it
    as metrics-only; its only control use is the warm-start drift-guard
    threshold compare, where a 1-ulp wobble matters only at the exact
    threshold boundary)."""
    for a, b in zip(jax.tree.leaves(p_seq), jax.tree.leaves(p_bkt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    sa, sb = adapprox_state(s_seq), adapprox_state(s_bkt)
    np.testing.assert_array_equal(np.asarray(sa.step), np.asarray(sb.step))
    for la, lb in zip(sa.leaves, sb.leaves):
        for field in ("q", "u", "k", "m1", "v"):
            xa = getattr(la, field, None)
            xb = getattr(lb, field, None)
            if xa is None:
                assert xb is None
                continue
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                          err_msg=field)
        if hasattr(la, "xi"):
            np.testing.assert_allclose(np.asarray(la.xi), np.asarray(lb.xi),
                                       rtol=0, atol=1e-6)


def test_bucketed_bit_identical_to_per_leaf_loop():
    """bucketed=True groups the three same-shape attention projections into
    one vmapped trace; updates and all trajectory-relevant state must be
    bit-identical to the sequential per-leaf loop."""
    params = _bucket_params()
    gkey = jax.random.PRNGKey(16)
    kw = dict(lr=1e-3, weight_decay=0.0, k_init=4, k_max=16, mode="paper",
              xi_thresh=0.05, delta_s=2, min_dim_factor=64)
    p_seq, s_seq = _run_steps(make_optimizer("adapprox", **kw),
                              params, 4, gkey)
    p_bkt, s_bkt = _run_steps(make_optimizer("adapprox", bucketed=True, **kw),
                              params, 4, gkey)
    _assert_same_adapprox_run(p_seq, s_seq, p_bkt, s_bkt)


def test_bucketed_bit_identical_with_refresh_and_warm_start():
    """Bucketing composes with the amortized-refresh knobs: still
    bit-identical when refresh_every/warm_start drive the cond+fold path."""
    params = _bucket_params()
    gkey = jax.random.PRNGKey(17)
    kw = dict(lr=1e-3, weight_decay=0.0, k_init=6, mode="static",
              min_dim_factor=64, refresh_every=3, warm_start=True,
              n_iter_warm=1)
    p_seq, s_seq = _run_steps(make_optimizer("adapprox", **kw),
                              params, 5, gkey)
    p_bkt, s_bkt = _run_steps(make_optimizer("adapprox", bucketed=True, **kw),
                              params, 5, gkey)
    _assert_same_adapprox_run(p_seq, s_seq, p_bkt, s_bkt)


def test_warm_start_trajectory_stays_close_to_cold():
    """End-to-end guardrail: warm-started amortized refresh follows the
    exact-refresh parameter trajectory closely on a short run."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(18), (160, 144)) * 0.02}
    gkey = jax.random.PRNGKey(19)
    kw = dict(lr=1e-2, weight_decay=0.0, k_init=8, mode="static",
              min_dim_factor=64)
    p_cold, _ = _run_steps(make_optimizer("adapprox", **kw), params, 10, gkey)
    p_fast, _ = _run_steps(
        make_optimizer("adapprox", refresh_every=5, warm_start=True,
                       n_iter_warm=1, **kw), params, 10, gkey)
    ref_step = float(jnp.sqrt(jnp.mean(jnp.square(p_cold["w"] - params["w"]))))
    dev = float(jnp.sqrt(jnp.mean(jnp.square(p_cold["w"] - p_fast["w"]))))
    # trajectories deviate by well under the distance travelled
    assert dev < 0.35 * ref_step, (dev, ref_step)


def test_int8_factor_checkpoint_roundtrip_mid_interval():
    """Quantized factor state (QuantizedMatrix int8 payload + per-block
    f32 scale/zero) round-trips through PartitionState -> host numpy ->
    rebuilt state bit-for-bit, interrupted MID-refresh-interval so the
    restored run must continue the frozen-Q fold cadence on exactly the
    dequantized factors the uninterrupted run sees."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(13),
                                     (160, 144)) * 0.02,
              "b": jnp.zeros((144,))}
    labeler = lambda ps: jax.tree.map(
        lambda p: "factored" if p.ndim >= 2 else "dense", ps)
    sub_f = make_optimizer("adapprox", lr=1e-3, weight_decay=0.0,
                           k_init=6, mode="static", min_dim_factor=64,
                           refresh_every=3, warm_start=True, n_iter_warm=1,
                           fused_update=True, factor_dtype="int8")
    sub_d = adamw(AdamWConfig(lr=1e-3))
    opt = partition(labeler, {"factored": sub_f, "dense": sub_d})
    gkey = jax.random.PRNGKey(14)
    grads = lambda t, p: jax.tree.map(lambda x: jax.random.normal(
        jax.random.fold_in(gkey, t * 17 + x.size), x.shape), p)
    upd = jax.jit(opt.update)

    state = opt.init(params)
    p = params
    for t in range(1, 6):
        u, state = upd(grads(t, p), state, p)
        p = apply_updates(p, u)

    # interrupt after t=2 (a fold step: mid-interval, frozen Q) and
    # round-trip every leaf -- including the int8 payloads -- through host
    # numpy, exactly what the checkpoint layer serializes
    state2 = opt.init(params)
    p2 = params
    for t in range(1, 3):
        u, state2 = upd(grads(t, p2), state2, p2)
        p2 = apply_updates(p2, u)
    flat, treedef = jax.tree.flatten(state2)
    assert any(x.dtype == jnp.int8 for x in flat), \
        "expected int8 factor leaves in the checkpointed state"
    restored = jax.tree.unflatten(
        treedef, [jnp.asarray(np.asarray(x)) for x in flat])
    for t in range(3, 6):
        u, restored = upd(grads(t, p2), restored, p2)
        p2 = apply_updates(p2, u)

    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
