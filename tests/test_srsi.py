"""S-RSI correctness: approximation quality vs SVD, orthonormality,
implicit-operator equivalence, batching, and the error-rate identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import srsi as S

jax.config.update("jax_enable_x64", False)


def lowrank_plus_noise(key, m, n, rank, noise=1e-3):
    """Synthetic second-moment-like matrix: nonneg, few dominant directions."""
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.normal(k1, (m, rank))
    b = jax.random.normal(k2, (rank, n))
    scales = 10.0 ** (-jnp.arange(rank, dtype=jnp.float32) / 2.0)
    base = (a * scales) @ b
    mat = jnp.square(base) + noise * jnp.square(jax.random.normal(k3, (m, n)))
    return mat.astype(jnp.float32)


def test_cholesky_qr2_orthonormal():
    y = jax.random.normal(jax.random.PRNGKey(0), (257, 33))
    q = S.cholesky_qr2(y)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(33), atol=1e-5)


def test_cholesky_qr2_rank_deficient_no_nan():
    y = jnp.zeros((64, 8))
    q = S.cholesky_qr2(y)
    assert not np.any(np.isnan(np.asarray(q)))


def test_srsi_matches_svd_quality():
    a = lowrank_plus_noise(jax.random.PRNGKey(1), 256, 192, rank=6)
    res = S.srsi_dense(a, r_store=12, oversample=5, n_iter=5,
                       key=jax.random.PRNGKey(2))
    approx = S.reconstruct(res.q, res.u)
    err = jnp.linalg.norm(a - approx) / jnp.linalg.norm(a)
    # Optimal rank-12 error via SVD:
    sv = jnp.linalg.svd(a, compute_uv=False)
    opt = jnp.sqrt(jnp.sum(sv[12:] ** 2)) / jnp.linalg.norm(a)
    assert float(err) <= float(opt) * 1.10 + 1e-6, (err, opt)


def test_error_rate_identity():
    """xi from cum_energy must equal the directly computed residual norm.

    The projection identity ``||A - Q_k Q_k^T A||^2 = ||A||^2 - ||Q_k^T
    A||^2`` holds exactly only for exactly-orthonormal Q.  CholeskyQR3
    leaves ~1e-6 relative orthonormality error in fp32, which enters the
    *energy* (xi^2) at that order — so xi itself carries an absolute floor
    of ~sqrt(1e-6) = 1e-3.  Once the true residual drops to that floor
    (large k), identity-xi and direct-xi legitimately diverge in relative
    terms; the correct expectation is agreement up to rtol OR the fp32
    floor, whichever is larger.
    """
    a = lowrank_plus_noise(jax.random.PRNGKey(3), 128, 96, rank=4)
    res = S.srsi_dense(a, r_store=16, oversample=4, n_iter=4,
                       key=jax.random.PRNGKey(4))
    xi_floor = 2e-3          # sqrt(CholeskyQR3 fp32 orthonormality error)
    for k in [1, 3, 8, 16]:
        mask = S.col_mask(16, jnp.asarray(k))
        approx = (res.q * mask[None, :]) @ (res.u * mask[None, :]).T
        direct = jnp.linalg.norm(a - approx) / jnp.linalg.norm(a)
        via_id = S.approx_error_rate(res, jnp.asarray(k))
        np.testing.assert_allclose(float(via_id), float(direct),
                                   rtol=5e-3, atol=xi_floor)
        # the identity may sit at its floor, but must never *understate*
        # a residual that is clearly above it (rank selection depends on
        # xi being an upper-ish estimate at coarse k)
        if float(direct) > 10 * xi_floor:
            assert float(via_id) > float(direct) * 0.99


def test_implicit_equals_dense_operator():
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (64, 8))
    u = jax.random.normal(jax.random.fold_in(key, 1), (48, 8))
    g = jax.random.normal(jax.random.fold_in(key, 2), (64, 48))
    v = S.make_implicit_v(q, u, g, 0.99)
    x = jax.random.normal(jax.random.fold_in(key, 3), (48, 5))
    y = jax.random.normal(jax.random.fold_in(key, 4), (64, 5))
    vmat = 0.99 * q @ u.T + 0.01 * g * g
    np.testing.assert_allclose(np.asarray(v.mv(x)), np.asarray(vmat @ x),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(v.rmv(y)), np.asarray(vmat.T @ y),
                               rtol=2e-4, atol=2e-4)
    vclamped = 0.99 * jnp.maximum(q @ u.T, 0.0) + 0.01 * g * g
    np.testing.assert_allclose(float(v.frob_sq()),
                               float(jnp.sum(vclamped ** 2)),
                               rtol=1e-4)


def test_srsi_implicit_close_to_dense_srsi():
    """Same operator, same key => identical sketches up to fp error."""
    key = jax.random.PRNGKey(6)
    q0 = jnp.abs(jax.random.normal(key, (96, 4)))
    u0 = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (80, 4)))
    g = jax.random.normal(jax.random.fold_in(key, 2), (96, 80))
    v = S.make_implicit_v(q0, u0, g, 0.999)
    vmat = v.materialize()
    skey = jax.random.PRNGKey(7)
    res_i = S.srsi_implicit(v, 8, 4, 3, skey)
    res_d = S.srsi_dense(vmat, 8, 4, 3, skey)
    ri = S.reconstruct(res_i.q, res_i.u)
    rd = S.reconstruct(res_d.q, res_d.u)
    # materialize() clamps at 0 while mv/rmv do not; the operators differ
    # only where QU^T < 0 which is rare/small => reconstructions agree.
    np.testing.assert_allclose(np.asarray(ri), np.asarray(rd),
                               rtol=1e-3, atol=1e-4 * float(jnp.max(vmat)))


def test_batched_srsi():
    keys = jax.random.split(jax.random.PRNGKey(8), 3)
    mats = jnp.stack([lowrank_plus_noise(k, 64, 64, 3) for k in keys])
    bkeys = jax.random.split(jax.random.PRNGKey(9), 3)
    res = S.srsi_dense_batched(mats, 8, 4, 3, bkeys)
    assert res.q.shape == (3, 64, 8)
    assert res.u.shape == (3, 64, 8)
    for i in range(3):
        approx = res.q[i] @ res.u[i].T
        err = jnp.linalg.norm(mats[i] - approx) / jnp.linalg.norm(mats[i])
        assert float(err) < 0.05


def test_zero_matrix_is_safe():
    res = S.srsi_dense(jnp.zeros((32, 32)), 4, 2, 2, jax.random.PRNGKey(0))
    assert not np.any(np.isnan(np.asarray(res.q)))
    assert not np.any(np.isnan(np.asarray(res.u)))
    np.testing.assert_allclose(np.asarray(S.reconstruct(res.q, res.u)), 0.0,
                               atol=1e-6)
