"""End-to-end behaviour tests for the paper's system: the full public API
surface exercised the way a user would — config -> model -> Adapprox ->
step -> metrics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CELLS, applicable_cells
from repro.configs import ASSIGNED, get_config, get_smoke_config, input_specs
from repro.core import Schedule, apply_updates, make_optimizer, rank_metrics
from repro.models import build_model


def test_all_assigned_archs_have_all_cells_defined():
    """Every (arch x applicable cell) has well-defined input specs."""
    count = 0
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for cell in applicable_cells(cfg):
            specs = input_specs(cfg, cell)
            assert "tokens" in specs
            b = CELLS[cell].global_batch
            assert specs["tokens"].shape[0] == b
            count += 1
    assert count == 32          # 10 archs x 3 + 2 long-context


def test_paper_algorithm3_end_to_end():
    """Algorithm 3 exactly as the paper runs it (adaptive rank, clipping,
    update-EMA first moment) trains a real LM and reports sane rank/xi."""
    cfg = get_smoke_config("gpt2-117m", vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(
        "adapprox", lr=Schedule(3e-3, warmup_steps=5, total_steps=60),
        b1=0.9, b2=0.999, weight_decay=0.1,
        k_init=1, k_max=16, mode="paper", xi_thresh=0.01, delta_s=10,
        oversample=5, n_iter=5, min_dim_factor=32)
    state = opt.init(params)

    @jax.jit
    def step(p, s, toks):
        (loss, _), g = jax.value_and_grad(model.loss, has_aux=True)(
            p, {"tokens": toks})
        upd, s = opt.update(g, s, p)
        return apply_updates(p, upd), s, loss

    from repro.data import DataConfig, make_source
    src = make_source(DataConfig(vocab=cfg.vocab, seq_len=64,
                                 global_batch=4, seed=0))
    losses = []
    for t in range(60):
        toks = jnp.asarray(src.batch_at(t)["tokens"])
        params, state, loss = step(params, state, toks)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    m = rank_metrics(state)
    assert 1.0 <= float(m["adapprox/mean_rank"]) <= 16.0
    assert float(m["adapprox/mean_xi"]) >= 0.0


def test_factored_state_is_the_memory_story():
    """The system-level claim: for a real model, Adapprox(b1=0) state is
    <2% of AdamW state (paper Table 2's headline)."""
    from repro.core import tree_nbytes
    cfg = get_config("gpt2-345m")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    nb_ada = tree_nbytes(jax.eval_shape(
        make_optimizer("adapprox", b1=0.0, k_init=1, mode="static").init,
        params))
    nb_aw = tree_nbytes(jax.eval_shape(make_optimizer("adamw").init, params))
    assert nb_ada < 0.02 * nb_aw
