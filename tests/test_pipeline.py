"""GPipe pipeline parallelism: exact forward + gradient equivalence with
sequential execution, on 4 host devices (subprocess — needs its own XLA
device count)."""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed.pipeline import (pipeline_apply, split_stages,
                                        stage_fn_from_layers)

L, D, M, MB = 8, 16, 6, 4
mesh = jax.make_mesh((4,), ("stage",))

key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) * (1.0 / jnp.sqrt(D))
x = jax.random.normal(jax.random.fold_in(key, 1), (M, MB, D))

def layer_fn(wl, h):
    return jnp.tanh(h @ wl)

def sequential(w, x):
    def body(h, wl):
        return layer_fn(wl, h), None
    out, _ = jax.lax.scan(lambda h, wl: (layer_fn(wl, h), None), x, w)
    return out

stage_params = split_stages(w, 4)
stage_fn = stage_fn_from_layers(layer_fn)

out_pipe = pipeline_apply(stage_fn, stage_params, x, mesh)
out_seq = jax.vmap(lambda xm: sequential(w, xm))(
    x.reshape(M, 1, MB, D)[:, 0])
np.testing.assert_allclose(np.asarray(out_pipe), np.asarray(out_seq),
                           rtol=1e-5, atol=1e-5)

# gradient equivalence (ppermute transposes to the reverse schedule)
def loss_pipe(w):
    sp = split_stages(w, 4)
    return jnp.sum(pipeline_apply(stage_fn, sp, x, mesh) ** 2)

def loss_seq(w):
    return jnp.sum(jax.vmap(lambda xm: sequential(w, xm))(x) ** 2)

g_pipe = jax.grad(loss_pipe)(w)
g_seq = jax.grad(loss_seq)(w)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                           rtol=1e-4, atol=1e-4)
print("PIPELINE_OK")
"""


def test_gpipe_matches_sequential_forward_and_grad():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=str(REPO))
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "PIPELINE_OK" in out.stdout
