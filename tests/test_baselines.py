"""AdamW / Adafactor / CAME baseline tests + cross-optimizer convergence
on a common convex problem (all four must reach the optimum region)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdafactorConfig, AdamWConfig, CAMEConfig, adafactor,
                        adamw, apply_updates, came, make_optimizer,
                        tree_nbytes)


def test_adamw_matches_reference_formula():
    params = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, -0.4]])}
    opt = adamw(AdamWConfig(lr=0.01, b1=0.9, b2=0.999, eps=1e-8,
                            weight_decay=0.1))
    state = opt.init(params)
    updates, state = opt.update(g, state, params)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat, vhat = m / 0.1, v / 0.001
    expect = -(0.01 * (mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.asarray(params["w"])))
    np.testing.assert_allclose(np.asarray(updates["w"]), expect,
                               rtol=1e-4, atol=1e-6)


def test_adafactor_state_is_sublinear():
    params = {"w": jnp.zeros((2048, 2048))}
    st = adafactor(AdafactorConfig(b1=0.0)).init(params)
    # rank-1 stats: 2 * 2048 floats << 2048^2
    assert tree_nbytes(st) < 2048 * 2048 * 4 * 0.01


def test_came_requires_first_moment():
    with pytest.raises(ValueError):
        came(CAMEConfig(b1=0.0))


def test_came_state_layout():
    params = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((512,))}
    # chain state: stage 0 is scale_by_came's CAMEState
    st = came(CAMEConfig()).init(params)[0]
    leaves = {0: st.leaves[0], 1: st.leaves[1]}
    # dict order: b first
    assert leaves[0].v is not None and leaves[0].r is None      # dense for 1D
    assert leaves[1].r.shape == (256,) and leaves[1].cs.shape == (512,)


@pytest.mark.parametrize("name,kwargs", [
    ("adamw", dict(lr=0.05)),
    ("adafactor", dict(lr=0.05, b1=0.9, b2_schedule=False, b2=0.99)),
    ("came", dict(lr=0.05, b2=0.99, b3=0.999)),
    # Full-rank factor storage: on an adversarial (flat-spectrum) quadratic
    # a truncated V loses curvature information by construction — the paper's
    # premise (Fig. 1) is spectral concentration, which real models provide
    # and this toy problem deliberately does not.  Fidelity of the truncated
    # path is covered by test_adapprox.py::test_factored_tracks_dense_oracle
    # and the LM convergence benches.
    ("adapprox", dict(lr=0.05, b2=0.99, k_init=24, mode="static",
                      min_dim_factor=1, oversample=0, n_iter=4)),
])
def test_optimizers_converge_on_quadratic(name, kwargs):
    """min ||W - T||^2 — every optimizer must drive the loss down ~100x."""
    target = jax.random.normal(jax.random.PRNGKey(0), (32, 24)) * 0.5
    params = {"w": jnp.zeros((32, 24))}
    opt = make_optimizer(name, **kwargs)
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2)

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(loss_fn)(p)
        upd, s = opt.update(g, s, p)
        return apply_updates(p, upd), s, loss

    loss0 = float(loss_fn(params))
    for _ in range(300):
        params, state, loss = step(params, state)
    assert float(loss) < loss0 * 0.01, (name, loss0, float(loss))
    assert np.all(np.isfinite(np.asarray(params["w"])))


def test_memory_ordering_matches_table2():
    """adamw > adapprox(k_max) > came ~ adafactor ~ adapprox(k=1), b1=0.9."""
    shapes = [(768, 768), (768, 3072), (3072, 768), (50257, 768)]
    params = {f"w{i}": jnp.zeros(s) for i, s in enumerate(shapes)}
    nb = {}
    nb["adamw"] = tree_nbytes(make_optimizer("adamw").init(params))
    nb["adafactor"] = tree_nbytes(
        make_optimizer("adafactor", b1=0.9).init(params))
    nb["came"] = tree_nbytes(make_optimizer("came").init(params))
    nb["adapprox_k1"] = tree_nbytes(
        make_optimizer("adapprox", k_init=1, mode="static").init(params))
    # adaptive mode allocates at the paper's k_max = 0.25 * min(m, n)
    nb["adapprox_kmax"] = tree_nbytes(
        make_optimizer("adapprox", k_max=10**9, mode="paper").init(params))
    assert nb["adamw"] > nb["adapprox_kmax"] > nb["came"]
    assert abs(nb["came"] - nb["adafactor"]) < nb["adafactor"] * 0.02
    assert abs(nb["adapprox_k1"] - nb["adafactor"]) < nb["adafactor"] * 0.02
