"""Substrate tests: data determinism, checkpoint atomicity/restore/reshard,
elastic planning, straggler policy, gradient compression."""
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointConfig, CheckpointManager,
                              list_checkpoints, restore_pytree, save_pytree)
from repro.data import DataConfig, DataIterator, make_source
from repro.distributed import (CompressionConfig, MeshPlan, StragglerMonitor,
                               compress_gradients, plan_remesh)
from repro.distributed.straggler import StragglerConfig


# -- data -------------------------------------------------------------------

def test_data_deterministic_across_restarts():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=7)
    src = make_source(cfg)
    b1 = src.batch_at(5)
    b2 = src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_slicing_partitions_global_batch():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=1)
    src = make_source(cfg)
    full = src.batch_at(3)["tokens"]
    part0 = src.batch_at(3, start=0, count=4)["tokens"]
    part1 = src.batch_at(3, start=4, count=4)["tokens"]
    np.testing.assert_array_equal(np.vstack([part0, part1]), full)


def test_data_iterator_prefetch_and_resume():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=4, seed=2)
    it = DataIterator(cfg, start_step=10)
    b = next(it)
    assert b["step"] == 10
    b = next(it)
    assert b["step"] == 11
    it.close()
    # resume from a checkpointed step reproduces the same stream
    it2 = DataIterator(cfg, start_step=11)
    b2 = next(it2)
    it2.close()
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


# -- checkpoint ---------------------------------------------------------------

def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (16, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = make_tree()
    save_pytree(tree, tmp_path, step=3)
    out = restore_pytree(tmp_path / "step_000000003", tree)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, out)


def test_checkpoint_atomicity_ignores_uncommitted(tmp_path):
    tree = make_tree()
    save_pytree(tree, tmp_path, step=1)
    # simulate a crash mid-save: directory without commit marker
    broken = tmp_path / "step_000000002"
    broken.mkdir()
    (broken / "manifest.json").write_text("{}")
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
    assert mgr.latest_step() == 1


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                             keep=2, async_save=False))
    tree = make_tree()
    for s in (1, 2, 3, 4):
        mgr.save(tree, s)
    steps = [int(p.name.split("_")[1]) for p in list_checkpoints(tmp_path)]
    assert steps == [3, 4]


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                             async_save=True))
    tree = make_tree()
    mgr.save(tree, 10)
    mgr.wait()
    assert mgr.latest_step() == 10
    out, step = mgr.restore(tree)
    assert step == 10


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_pytree(make_tree(), tmp_path, step=1)
    bad = {"w": jnp.zeros((4, 4)), "nested": {"b": jnp.zeros(5, jnp.int32)}}
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_pytree(tmp_path / "step_000000001", bad)


# -- elastic ---------------------------------------------------------------

def test_plan_remesh_keeps_tp_when_possible():
    p = plan_remesh(512, target_model=16)
    assert (p.pods, p.data, p.model) == (2, 16, 16)
    p = plan_remesh(256, target_model=16)
    assert (p.pods, p.data, p.model) == (1, 16, 16)
    # lose a node's worth: 240 devices -> 15 data shards, same TP
    p = plan_remesh(240, target_model=16)
    assert p.model == 16 and p.data == 15 and p.pods == 1


def test_plan_remesh_degrades_tp_last():
    p = plan_remesh(8, target_model=16)
    assert p.model == 8 and p.devices <= 8


# -- straggler ----------------------------------------------------------------

def test_straggler_flags_outliers_and_escalates():
    mon = StragglerMonitor(StragglerConfig(window=30, z_thresh=4.0,
                                           persist=3, min_steps=10))
    for _ in range(20):
        assert not mon.observe(0.100 + np.random.default_rng(0).normal()
                               * 0.0)
    flagged = [mon.observe(0.5) for _ in range(3)]
    assert all(flagged)
    assert len(mon.escalations) == 1


def test_straggler_tolerates_noise():
    rng = np.random.default_rng(1)
    mon = StragglerMonitor(StragglerConfig(window=50, persist=3))
    for _ in range(100):
        mon.observe(0.1 + abs(rng.normal()) * 0.005)
    assert not mon.escalations


# -- gradient compression -------------------------------------------------------

def test_compression_reduces_rank_and_converges():
    """Error feedback: compressed-gradient GD reaches the optimum when the
    gradient stream is compressible (low-rank-dominated — the premise of
    PowerSGD, mirroring the Adapprox Fig.-1 premise for V).  An
    incompressible full-rank stream at high lr is the documented EF
    failure mode and is deliberately not asserted here."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    target = (jax.random.normal(k1, (64, 4)) @
              jax.random.normal(k2, (4, 48)))          # rank-4 optimum
    params = {"w": jnp.zeros((64, 48))}
    comp = compress_gradients(CompressionConfig(rank=8, min_dim=8))
    state = comp.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p: 0.5 * jnp.sum((p["w"] - target) ** 2))(p)
        g_hat, s = comp.update(g, s, p)
        p = {"w": p["w"] - 0.5 * g_hat["w"]}
        return p, s

    for _ in range(200):
        params, state = step(params, state)
    final = float(jnp.mean((params["w"] - target) ** 2))
    assert final < 1e-3, final


def test_compression_passthrough_small_leaves():
    params = {"small": jnp.zeros((4, 4))}
    comp = compress_gradients(CompressionConfig(rank=2, min_dim=8))
    state = comp.init(params)
    g = {"small": jnp.ones((4, 4))}
    out, _ = comp.update(g, state, params)
    np.testing.assert_array_equal(np.asarray(out["small"]),
                                  np.asarray(g["small"]))
