"""Metrics registry suite (repro.telemetry.metrics).

Pins the exposition contract from both surfaces:
  * counter / gauge / histogram accounting, including the ``le``
    boundary semantics (a value EQUAL to a bucket bound lands in that
    bucket) and label canonicalisation;
  * ``MetricsRegistry.snapshot`` produces a schema-valid
    ``kind="metric"`` event whose sample keys are EXACTLY the
    Prometheus sample names;
  * ``render()`` round-trips through ``parse_prometheus`` — types,
    help text, cumulative buckets, ``_sum`` / ``_count``;
  * the train loop emits one snapshot every ``metrics_every`` steps
    into the shared sink.
"""
import jax.numpy as jnp
import pytest

from repro.config import OptimizerConfig
from repro.core import build_optimizer
from repro.data import DataConfig
from repro.telemetry import (MetricsRegistry, SinkConfig, TelemetrySink,
                             Tracer, load_events, parse_prometheus,
                             validate_dir)
from repro.telemetry.metrics import DEFAULT_BUCKETS, default_registry
from repro.telemetry.sink import validate_event
from repro.train import LoopConfig, train


class TestAccounting:
    def test_counter(self):
        reg = MetricsRegistry()
        c = reg.counter("requests_total", "served requests")
        c.inc()
        c.inc(2.5)
        assert c.value() == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_counter_labels_are_independent_and_canonical(self):
        c = MetricsRegistry().counter("toks_total")
        c.inc(3, scheduler="wave")
        c.inc(5, scheduler="continuous")
        # kwarg order must not matter (labels are sorted)
        c.inc(1, b="2", a="1")
        c.inc(1, a="1", b="2")
        assert c.value(scheduler="wave") == 3
        assert c.value(scheduler="continuous") == 5
        assert c.value(a="1", b="2") == 2
        assert 'toks_total{a="1",b="2"}' in c.samples()

    def test_gauge_sets(self):
        g = MetricsRegistry().gauge("queue_depth")
        g.set(4)
        g.set(2)
        assert g.value() == 2

    def test_histogram_le_boundary(self):
        """A value equal to a bucket bound counts in THAT bucket
        (Prometheus le= is inclusive)."""
        h = MetricsRegistry().histogram("lat", buckets=(0.1, 1.0))
        h.observe(0.1)      # == first bound -> first bucket
        h.observe(0.5)
        h.observe(5.0)      # overflow
        assert h._counts[""] == [1, 1, 1]
        assert h.count() == 3
        assert h.sum() == pytest.approx(5.6)
        s = h.samples()["lat"]
        assert s["buckets"] == [0.1, 1.0]
        assert s["counts"] == [1, 1, 1]

    def test_histogram_bad_buckets(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.histogram("h", buckets=(1.0, 1.0))
        with pytest.raises(ValueError):
            reg.histogram("h2", buckets=(2.0, 1.0))


class TestRegistry:
    def test_get_or_create_returns_same_object(self):
        reg = MetricsRegistry()
        assert reg.counter("c") is reg.counter("c")

    def test_type_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x")

    def test_bucket_conflict_raises(self):
        reg = MetricsRegistry()
        reg.histogram("h", buckets=(0.1, 1.0))
        with pytest.raises(ValueError, match="different buckets"):
            reg.histogram("h", buckets=(0.2, 1.0))
        # same buckets is fine
        reg.histogram("h", buckets=(0.1, 1.0))

    def test_bad_names_rejected(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.counter("bad name")
        with pytest.raises(ValueError):
            reg.counter("c").inc(1, **{"bad-label": "v"})

    def test_default_registry_is_shared(self):
        assert default_registry() is default_registry()


class TestExposition:
    def _populated(self):
        reg = MetricsRegistry()
        reg.counter("steps_total", "steps run").inc(7)
        reg.gauge("loss").set(0.125, split="train")
        h = reg.histogram("step_seconds", "step wall",
                          buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 2.0):
            h.observe(v)
        return reg

    def test_snapshot_is_schema_valid_metric_event(self):
        ev = self._populated().snapshot(t_s=1.25, step=7)
        validate_event(ev | {"schema": 1})
        assert ev["kind"] == "metric"
        assert ev["step"] == 7
        assert ev["counters"]["steps_total"] == 7
        assert ev["gauges"]['loss{split="train"}'] == 0.125
        assert ev["histograms"]["step_seconds"]["count"] == 3

    def test_render_parse_round_trip(self):
        reg = self._populated()
        parsed = parse_prometheus(reg.render())
        assert parsed["types"] == {"steps_total": "counter",
                                   "loss": "gauge",
                                   "step_seconds": "histogram"}
        assert parsed["help"]["steps_total"] == "steps run"
        s = parsed["samples"]
        assert s["steps_total"] == 7
        assert s['loss{split="train"}'] == 0.125
        # cumulative buckets + sum/count
        assert s['step_seconds_bucket{le="0.1"}'] == 1
        assert s['step_seconds_bucket{le="1"}'] == 2
        assert s['step_seconds_bucket{le="+Inf"}'] == 3
        assert s["step_seconds_sum"] == pytest.approx(2.55)
        assert s["step_seconds_count"] == 3

    def test_snapshot_keys_match_prometheus_sample_names(self):
        """The JSONL snapshot and the text exposition must agree on
        sample naming — the cross-surface contract."""
        reg = self._populated()
        ev = reg.snapshot(t_s=0.0)
        parsed = parse_prometheus(reg.render())
        for k in list(ev["counters"]) + list(ev["gauges"]):
            assert k in parsed["samples"], k
        for k in ev["histograms"]:
            assert f'{k}_count' in parsed["samples"] or \
                any(sk.startswith(k + "_count{")
                    for sk in parsed["samples"])

    def test_label_escaping_round_trips(self):
        reg = MetricsRegistry()
        reg.counter("c").inc(1, path='a"b\\c')
        parsed = parse_prometheus(reg.render())
        assert parsed["samples"]['c{path="a\\"b\\\\c"}'] == 1

    def test_default_buckets_cover_latency_range(self):
        assert DEFAULT_BUCKETS[0] <= 0.001
        assert DEFAULT_BUCKETS[-1] >= 10.0
        assert list(DEFAULT_BUCKETS) == sorted(set(DEFAULT_BUCKETS))


# ---------------------------------------------------------------------------
# train-loop cadence
# ---------------------------------------------------------------------------

class _QuadraticModel:
    def init(self, key):
        del key
        return {"w": jnp.ones((8, 8))}

    def loss(self, params, batch):
        del batch
        l = jnp.sum(jnp.square(params["w"])) * 1e-3
        return l, {"loss": l}


def test_train_loop_metric_cadence(tmp_path):
    """6 steps with metrics_every=2 -> 3 kind="metric" snapshots in the
    sink, carrying the train counters/histograms."""
    sink = TelemetrySink(SinkConfig(directory=str(tmp_path)))
    reg = MetricsRegistry()
    tracer = Tracer(sink=sink, registry=reg)
    opt = build_optimizer(OptimizerConfig(name="adamw",
                                          schedule="constant", lr=1e-3))
    train(_QuadraticModel(), opt,
          DataConfig(vocab=8, seq_len=4, global_batch=2),
          LoopConfig(total_steps=6, log_every=3),
          tracer=tracer, metrics_every=2)
    sink.close()
    assert validate_dir(tmp_path) > 0
    snaps = [e for e in load_events(tmp_path) if e["kind"] == "metric"]
    assert len(snaps) == 3
    assert [s["step"] for s in snaps] == [2, 4, 6]
    last = snaps[-1]
    assert last["counters"]["train_steps_total"] == 6
    assert last["histograms"]["train_step_seconds"]["count"] == 6
    assert reg.counter("train_steps_total").value() == 6
    # the gauge tracks the latest loss
    assert "train_loss" in last["gauges"]
