"""Adaptive rank selection (Algorithm 2) unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rank as R


def make_cum_energy(decay=0.5, r=64, total=1.0):
    """Synthetic captured-energy CDF: col j captures decay^j of the rest."""
    col = decay ** jnp.arange(r)
    col = col / jnp.sum(col) * total
    return jnp.cumsum(col), jnp.asarray(total)


def test_f_increment_matches_paper_constants():
    """With the paper's (eta, omega, phi, tau) = (200, -10, -2.5, -9),
    f(xi) ~= 22 across (0, 1] — the rank grows in ~constant increments."""
    cfg = R.RankConfig()
    for xi in [0.011, 0.05, 0.3, 0.9, 1.0]:
        val = float(R.f_increment(jnp.asarray(xi), cfg))
        assert 21.0 < val < 24.0, (xi, val)


def test_exact_selection_is_minimal_feasible():
    cum, frob = make_cum_energy(decay=0.6)
    cfg = R.RankConfig(xi_thresh=0.05, k_init=1)
    k = int(R.select_rank_exact(cum, frob, cfg, k_max=64))
    xi_k = float(R.xi_of_k(cum, frob, jnp.asarray(k)))
    assert xi_k <= 0.05 + 1e-6
    if k > 1:
        xi_prev = float(R.xi_of_k(cum, frob, jnp.asarray(k - 1)))
        assert xi_prev > 0.05


def test_paper_iteration_feasible_and_geq_exact():
    cum, frob = make_cum_energy(decay=0.8, r=256)
    cfg = R.RankConfig(xi_thresh=0.02, k_init=1)
    k_paper = int(R.select_rank_paper_iteration(cum, frob, cfg, k_max=256))
    k_exact = int(R.select_rank_exact(cum, frob, cfg, k_max=256))
    assert k_paper >= k_exact
    assert float(R.xi_of_k(cum, frob, jnp.asarray(k_paper))) <= 0.02 + 1e-6
    # paper increments are ~22, so overshoot is bounded by one increment
    assert k_paper - k_exact < 25


def test_k_max_respected_when_infeasible():
    """Flat spectrum where the threshold is unreachable -> k == k_max."""
    cum, frob = make_cum_energy(decay=0.999, r=32, total=1.0)
    cfg = R.RankConfig(xi_thresh=1e-6)
    k = int(R.select_rank_paper_iteration(cum, frob, cfg, k_max=32))
    assert k == 32


def test_refresh_interval():
    cum, frob = make_cum_energy()
    cfg = R.RankConfig(xi_thresh=0.05, delta_s=10)
    k_prev = jnp.asarray(3, jnp.int32)
    # step 11 -> refresh; step 12 -> keep
    k_sel = R.select_rank(cum, frob, cfg, 64, jnp.asarray(11), k_prev)
    k_keep = R.select_rank(cum, frob, cfg, 64, jnp.asarray(12), k_prev)
    assert int(k_keep) == 3
    assert int(k_sel) != 3 or int(R.select_rank_exact(cum, frob, cfg, 64)) == 3


def test_resolve_k_max_quarter_rule():
    cfg = R.RankConfig(k_max=10_000)
    assert R.resolve_k_max((768, 3072), cfg) == 192   # 0.25 * 768
    assert R.resolve_k_max((4, 1024, 1024), cfg) == 256
    assert R.resolve_k_max((130, 130), cfg) == 32


def test_selection_jit_compatible():
    cum, frob = make_cum_energy()
    cfg = R.RankConfig(xi_thresh=0.05)
    fn = jax.jit(lambda c, f, s, kp: R.select_rank(c, f, cfg, 64, s, kp))
    out = fn(cum, frob, jnp.asarray(1), jnp.asarray(1, jnp.int32))
    assert out.dtype == jnp.int32
