"""Fused two-pass update pipeline (AdapproxConfig.fused_update).

Contract pinned here:

  * ``fused_update=True`` is BITWISE-identical to the unfused path for
    ``guidance="off"`` — on every leaf kind (factored, stacked-factored,
    dense 2-D, 1-D), under ``refresh_every`` folding, under ``bucketed``
    execution, and for b1 = 0;
  * guidance modes ("update"/"stored") agree to fp tolerance: the fused
    pipeline recovers the guidance scalars algebraically from the pass-1
    partials (reassociated reductions).  NOTE the 1/(1 - theta) guidance
    scale is chaotic at theta ~= 1 — at exactly-degenerate points (step 1,
    where m1 = 0 makes the update and the first moment parallel) the two
    paths can round theta to opposite sides of 1 and clamp to opposite
    ends of [0, guidance_max_scale].  That instability belongs to the
    guidance definition, not the fusion; the tolerance test below warms
    the first moment up with guidance off first, as any real run would
    effectively do after a handful of steps.
  * a PartitionState checkpoint round-trip with the knob on is
    bit-transparent;
  * the roofline traffic model shows >= 2x fewer HBM bytes for the
    elementwise stage in every mode combination.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdamWConfig, AdapproxConfig, RankConfig, adamw,
                        adapprox, apply_updates, make_optimizer, partition)

KEY = jax.random.PRNGKey(0)

PARAMS = {
    "w": jax.random.normal(KEY, (160, 144)) * 0.02,             # factored
    "stk": jax.random.normal(jax.random.fold_in(KEY, 5),
                             (3, 96, 80)) * 0.02,               # stacked
    "ln": jax.random.normal(jax.random.fold_in(KEY, 6),
                            (4, 96)) * 0.02,                    # dense 2-D
    "b": jnp.zeros((144,)),                                     # dense 1-D
}


def _cfg(**kw):
    base = dict(lr=1e-3, b1=0.9, min_dim_factor=64, oversample=2, n_iter=2,
                rank=RankConfig(k_init=8, mode="static"))
    base.update(kw)
    return AdapproxConfig(**base)


def _run(cfg, params=PARAMS, steps=6, state=None, t0=1):
    opt = adapprox(cfg)
    st = opt.init(params) if state is None else state
    p = params
    upd = jax.jit(opt.update)
    for t in range(t0, t0 + steps):
        g = jax.tree.map(lambda x: jax.random.normal(
            jax.random.fold_in(KEY, t * 31 + x.size), x.shape), p)
        u, st = upd(g, st, p)
        p = apply_updates(p, u)
    return p, st


def _assert_tree_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("implicit", [False, True])
@pytest.mark.parametrize("refresh_every", [1, 3])
@pytest.mark.parametrize("bucketed", [False, True])
def test_fused_bitwise_vs_unfused_guidance_off(implicit, refresh_every,
                                               bucketed):
    kw = dict(implicit=implicit, refresh_every=refresh_every,
              warm_start=refresh_every > 1)
    p_ref, st_ref = _run(_cfg(**kw))
    p_fused, st_fused = _run(_cfg(fused_update=True, bucketed=bucketed, **kw))
    _assert_tree_bitwise(p_ref, p_fused)
    _assert_tree_bitwise(st_ref, st_fused)


def test_fused_bitwise_b1_zero():
    p_ref, _ = _run(_cfg(b1=0.0))
    p_fused, _ = _run(_cfg(b1=0.0, fused_update=True))
    _assert_tree_bitwise(p_ref, p_fused)


@pytest.mark.parametrize("guidance", ["update", "stored"])
def test_fused_guidance_modes_tolerance(guidance):
    """Fused guidance scalars come from reassociated reductions -> fp
    tolerance, not bitwise.  Warm the first moment up with guidance off
    (bitwise-identical on both paths) so theta is away from its chaotic
    fixed point at 1, then compare the guided continuation."""
    outs = {}
    for fused in (False, True):
        base = _cfg(implicit=True, fused_update=fused)
        p, st = _run(base, steps=3)                       # m1 warm-up
        gcfg = dataclasses.replace(base, guidance=guidance)
        p, _ = _run(gcfg, params=p, steps=4, state=st, t0=4)
        outs[fused] = p
    for k in PARAMS:
        a, b = np.asarray(outs[False][k]), np.asarray(outs[True][k])
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-6)


def test_fused_all_guidance_modes_bitwise_dense_leaves():
    """Dense leaves never take the guidance branch, so they stay bitwise
    even with guidance enabled."""
    for guidance in ("off", "update", "stored"):
        p_ref, _ = _run(_cfg(guidance=guidance), steps=3)
        p_fused, _ = _run(_cfg(guidance=guidance, fused_update=True),
                          steps=3)
        for k in ("ln", "b"):
            np.testing.assert_array_equal(np.asarray(p_ref[k]),
                                          np.asarray(p_fused[k]))


def test_fused_checkpoint_roundtrip_partition_state():
    """Mid-refresh-interval checkpoint/restore through PartitionState with
    fused_update on is bit-transparent (same contract as test_refresh)."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(13),
                                     (160, 144)) * 0.02,
              "b": jnp.zeros((144,))}
    labeler = lambda ps: jax.tree.map(
        lambda p: "factored" if p.ndim >= 2 else "dense", ps)
    sub_f = make_optimizer("adapprox", lr=1e-3, weight_decay=0.0,
                           k_init=6, mode="static", min_dim_factor=64,
                           refresh_every=3, warm_start=True, n_iter_warm=1,
                           fused_update=True)
    sub_d = adamw(AdamWConfig(lr=1e-3))
    opt = partition(labeler, {"factored": sub_f, "dense": sub_d})
    gkey = jax.random.PRNGKey(14)
    grads = lambda t, p: jax.tree.map(lambda x: jax.random.normal(
        jax.random.fold_in(gkey, t * 17 + x.size), x.shape), p)
    upd = jax.jit(opt.update)

    state = opt.init(params)
    p = params
    for t in range(1, 6):
        u, state = upd(grads(t, p), state, p)
        p = apply_updates(p, u)

    state2 = opt.init(params)
    p2 = params
    for t in range(1, 3):
        u, state2 = upd(grads(t, p2), state2, p2)
        p2 = apply_updates(p2, u)
    flat, treedef = jax.tree.flatten(state2)
    restored = jax.tree.unflatten(
        treedef, [jnp.asarray(np.asarray(x)) for x in flat])
    for t in range(3, 6):
        u, restored = upd(grads(t, p2), restored, p2)
        p2 = apply_updates(p2, u)

    _assert_tree_bitwise(p, p2)
    _assert_tree_bitwise(state, restored)


def test_traffic_model_at_least_2x():
    """The fused pipeline must cut modeled elementwise-stage HBM bytes by
    >= 2x for every paper-default (b1 > 0) mode — the pass-count claim,
    checked against the roofline model rather than asserted in prose.  The
    momentless b1 = 0 ablation has the shortest unfused tail and the same
    skinny factor reads on both sides, which caps it just under 2x
    (~1.95x) — pinned at >= 1.9x."""
    from benchmarks.roofline import optimizer_update_traffic
    for m, n, r in [(768, 2304, 128), (3072, 768, 64), (160, 144, 8)]:
        for b1 in (0.0, 0.9):
            for guidance in (False, True):
                if guidance and b1 == 0.0:
                    continue                     # guidance needs a moment
                unf = optimizer_update_traffic(m, n, r, b1, guidance,
                                               fused=False)["total"]
                fus = optimizer_update_traffic(m, n, r, b1, guidance,
                                               fused=True)["total"]
                floor = 2.0 if b1 > 0 else 1.9
                assert unf / fus >= floor, (m, n, r, b1, guidance, unf / fus)


def test_fused_pallas_interpret_matches_ref_mode():
    """The whole fused optimizer under forced-pallas (interpret on CPU)
    agrees with the ref dispatch — covers vmapped pallas_call on stacked
    leaves and the aliased pass-2 kernel."""
    from repro.kernels import ops

    def run(mode):
        ops.set_mode(mode)
        try:
            return _run(_cfg(implicit=True, fused_update=True), steps=3)[0]
        finally:
            ops.set_mode("auto")

    a, b = run("ref"), run("pallas")
    for k in PARAMS:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("refresh_every", [1, 3])
@pytest.mark.parametrize("bucketed", [False, True])
def test_int8_fused_bitwise_vs_unfused(refresh_every, bucketed):
    """Lazy int8 dequant (fused tile loads) vs the eager path (unfused:
    dequantize up front, compute in f32, requantize): same codec, same
    arithmetic, so params and the re-quantized factor state must match
    BITWISE across refresh and fold steps, bucketed or not."""
    kw = dict(factor_dtype="int8", refresh_every=refresh_every,
              warm_start=refresh_every > 1)
    p_ref, st_ref = _run(_cfg(**kw))
    p_fused, st_fused = _run(_cfg(fused_update=True, bucketed=bucketed,
                                  **kw))
    _assert_tree_bitwise(p_ref, p_fused)
    _assert_tree_bitwise(st_ref, st_fused)


def test_int8_fused_pallas_interpret_matches_ref_mode():
    """int8 + fused under forced-pallas runs the in-kernel dequant codec
    (_deq_tile) and the fold-fused pass 1 for real (interpret mode);
    must agree with the ref dispatch, which dequantizes on the host."""
    from repro.kernels import ops

    def run(mode):
        ops.set_mode(mode)
        try:
            return _run(_cfg(factor_dtype="int8", fused_update=True,
                             refresh_every=3, warm_start=True),
                        steps=4)[0]
        finally:
            ops.set_mode("auto")

    a, b = run("ref"), run("pallas")
    for k in PARAMS:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-4, atol=1e-6)


def test_fold_fused_and_dequant_traffic_floors():
    """The two new roofline ratios, pinned by test rather than prose:
    fold-fused pass 1 cuts fold-step bytes >= FOLD_FUSED_FLOOR vs the
    PR-4 fused pipeline whose fold matmul reads G twice more, and int8
    factor reads come in at >= DEQUANT_FLOOR fewer bytes than f32 (4x
    payload minus the per-block scale/zero sidecar)."""
    from benchmarks.roofline import (DEQUANT_FLOOR, FOLD_FUSED_FLOOR,
                                     QUICK_SHAPES, factor_read_bytes,
                                     optimizer_fold_step_traffic)
    for m, n, r in QUICK_SHAPES:
        base = optimizer_fold_step_traffic(m, n, r, fused=True,
                                           fold_fused=False)["total"]
        fold = optimizer_fold_step_traffic(m, n, r, fused=True,
                                           fold_fused=True)["total"]
        assert base / fold >= FOLD_FUSED_FLOOR, (m, n, r, base / fold)
        f32 = factor_read_bytes(m, n, r, "float32")
        i8 = factor_read_bytes(m, n, r, "int8")
        assert f32 / i8 >= DEQUANT_FLOOR, (m, n, r, f32 / i8)
