"""Dry-run driver CI test: run the test preset in a subprocess (it needs a
different XLA device count than the rest of the suite) over reduced
configs on a (2, 2, 2) mesh — exercises the full lower+compile+analyze
pipeline including sharding rules, microbatching and the HLO cost walker."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


@pytest.mark.parametrize("arch,cells", [
    ("qwen2-7b", "train_4k,decode_32k"),
    ("olmoe-1b-7b", "train_4k"),          # MoE: shard_map EP path
    ("zamba2-2.7b", "train_4k,long_500k"),  # hybrid + long-context
])
def test_dryrun_test_preset(tmp_path, arch, cells):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--preset", "test",
         "--arch", arch, "--cell", cells, "--out", str(tmp_path),
         "--force"],
        capture_output=True, text=True, env=env, cwd=str(REPO),
        timeout=500)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "all dry-run cells compiled" in out.stdout

    recs = list(tmp_path.glob("*.json"))
    assert recs
    for p in recs:
        rec = json.loads(p.read_text())
        assert rec["flops"] > 0
        assert rec["memory"]["peak_bytes"] > 0
        assert rec["devices"] == 8


def test_hlo_cost_walker_loop_multiplication():
    """The walker must multiply scan bodies by trip count (XLA's own
    cost_analysis does not)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_cost import parse_hlo_costs

    def f(x):
        def body(c, _):
            return c @ x, None
        return jax.lax.scan(body, x, None, length=7)[0]

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    cost = parse_hlo_costs(compiled.as_text())
    expect = 7 * 2 * 128 ** 3
    assert abs(cost.flops - expect) / expect < 0.05
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):       # jax < 0.5 returns list
        xla_cost = xla_cost[0]
    assert cost.flops > xla_cost["flops"] * 5
