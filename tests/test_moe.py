"""MoE correctness: sort-impl vs dense oracle, capacity drop semantics,
gradient flow, shard_map EP equivalence on a 1-device mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoESpec
from repro.models import moe as MOE


def cfg_with(impl="sort", n_experts=8, top_k=2, cap=8.0):
    return ModelConfig(
        arch="t", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=64,
        moe=MoESpec(n_experts=n_experts, top_k=top_k, d_ff_expert=16,
                    capacity_factor=cap, impl=impl))


def test_sort_matches_dense_with_ample_capacity():
    """With capacity >= all tokens, sort-based dispatch must equal the
    dense (all-experts) weighted combine exactly."""
    cfg_s, cfg_d = cfg_with("sort", cap=64.0), cfg_with("dense", cap=64.0)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg_s, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    # dense impl computes every expert; mask to top-k happens via gates
    y_s, aux_s = MOE.moe_apply_local(cfg_s, p, x)
    y_d, aux_d = MOE.moe_apply_local(cfg_d, p, x)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_d),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_capacity_drop_reduces_output():
    """Tiny capacity drops tokens -> output differs from ample capacity and
    is finite (drop semantics, not crash)."""
    cfg_tiny = cfg_with("sort", cap=0.25)
    cfg_big = cfg_with("sort", cap=64.0)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg_tiny, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y_t, _ = MOE.moe_apply_local(cfg_tiny, p, x)
    y_b, _ = MOE.moe_apply_local(cfg_big, p, x)
    assert np.all(np.isfinite(np.asarray(y_t)))
    assert not np.allclose(np.asarray(y_t), np.asarray(y_b))


def test_gradients_flow_through_sort_dispatch():
    cfg = cfg_with("sort", cap=8.0)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))

    def loss(p):
        y, aux = MOE.moe_apply_local(cfg, p, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert np.all(np.isfinite(np.asarray(g[name])))
        assert float(jnp.abs(g[name]).max()) > 0, name


def test_sharded_equals_local_on_single_device_mesh():
    cfg = cfg_with("sort", cap=64.0)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y_l, aux_l = MOE.moe_apply_local(cfg, p, x)
    y_s, aux_s = MOE.moe_apply_sharded(cfg, p, x, mesh, dp_axes=("data",),
                                       gather_axes=())
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_l),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_l), rtol=1e-5)


def test_router_probabilities_normalized():
    cfg = cfg_with()
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, 32)
    xf = jax.random.normal(jax.random.PRNGKey(2), (16, 32))
    gates, idx, aux = MOE._route(cfg, p["router"], xf)
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0,
                               atol=1e-5)
    assert int(idx.max()) < cfg.moe.n_experts
    assert float(aux) >= 1.0 - 1e-3     # LB loss lower bound is 1 at uniform


def test_ep_tp_equals_local_on_single_device_mesh():
    cfg = cfg_with("sort", cap=64.0)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 32))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y_l, aux_l = MOE.moe_apply_local(cfg, p, x)
    y_s, aux_s = MOE.moe_apply_ep_tp(cfg, p, x, mesh)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_l),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_l), rtol=1e-5)
