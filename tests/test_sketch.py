"""The count-min sketch second-moment backend (repro.core.sketch):
kernel parity sweeps, routing, the no-underestimate invariant, the
dense-Adam fallback, memory accounting, sharding specs, telemetry, and
convergence on an embedding-dominated problem."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.config import OptimizerConfig
from repro.core import (apply_updates, build_optimizer, make_optimizer,
                        scale_by_adam, tree_nbytes)
from repro.core.sketch import (SketchConfig, SketchDense, SketchLeaf,
                               _leaf_seeds, bucket_indices, scale_by_sketch,
                               should_sketch, sketch_state)
from repro.distributed import sharding as SH
from repro.kernels import ops, ref
from repro.telemetry import validate_event
from repro.telemetry.runtime import TelemetryRuntime


# ---------------------------------------------------------------------------
# kernel parity: fused hashed EMA update + min-over-depth query
# ---------------------------------------------------------------------------

# (rows, width, depth, inner): aligned, big-aligned, unaligned, degenerate
SKETCH_SHAPES = [
    (256, 128, 4, 256),
    (37, 5, 3, 16),
    (1000, 130, 2, 100),
    (8, 3, 1, 4),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.fixture()
def force_pallas():
    ops.set_mode("pallas")      # interpret=True on CPU
    yield
    ops.set_mode("auto")


def _mk_sketch(rows, width, depth, inner, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    table = jnp.abs(jax.random.normal(key, (depth, width, inner),
                                      jnp.float32))
    g = jax.random.normal(jax.random.fold_in(key, 1),
                          (rows, inner)).astype(dtype)
    idx = jnp.asarray(bucket_indices(rows, width,
                                     _leaf_seeds(seed, 0, depth)))
    return table, g, idx


@pytest.mark.parametrize("rows,width,depth,inner", SKETCH_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sketch_update_matches_ref(force_pallas, rows, width, depth, inner,
                                   dtype):
    table, g, idx = _mk_sketch(rows, width, depth, inner, dtype)
    new_k, q_k = ops.sketch_update(table, g, idx, 0.999)
    new_r, q_r = ref.sketch_update(table, g, idx, 0.999)
    # scatter parity is tolerance-level (matmul vs segment-sum summation
    # order); the gather is a single-term dot and stays exact
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(new_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(q_k), np.asarray(q_r),
                               rtol=2e-4, atol=2e-4)


def test_sketch_update_oracle_is_ema_scatter():
    """Hand-check the oracle on a collision: two rows hashed to the same
    bucket accumulate, the query returns the shared bucket."""
    table = jnp.zeros((1, 2, 1), jnp.float32)
    g = jnp.asarray([[1.0], [2.0], [3.0]])
    idx = jnp.asarray([[0, 0, 1]], jnp.int32)
    new, q = ref.sketch_update(table, g, idx, 0.5)
    np.testing.assert_allclose(np.asarray(new[0, :, 0]),
                               [0.5 * (1 + 4), 0.5 * 9])
    np.testing.assert_allclose(np.asarray(q[:, 0]), [2.5, 2.5, 4.5])


# ---------------------------------------------------------------------------
# routing + the transform
# ---------------------------------------------------------------------------

def test_should_sketch_predicate():
    assert should_sketch((1024, 64), 1024)
    assert should_sketch((2048, 8, 4), 1024)
    assert not should_sketch((1023, 64), 1024)      # below the row floor
    assert not should_sketch((4096,), 1024)         # 1-D never sketches
    assert not should_sketch((), 1024)


def test_no_underestimate_through_transform():
    """End to end through scale_by_sketch: the implied vhat never drops
    below the exact dense-Adam vhat (collisions only add mass)."""
    cfg = SketchConfig(b1=0.0, b2=0.9, eps=0.0, depth=2, width=16,
                       min_rows=8)
    params = {"e": jnp.zeros((64, 4))}
    opt = scale_by_sketch(cfg)
    state = opt.init(params)
    exact_v = np.zeros((64, 4), np.float32)
    key = jax.random.PRNGKey(0)
    for t in range(1, 5):
        key, sub = jax.random.split(key)
        g = {"e": jax.random.normal(sub, (64, 4))}
        upd, state = opt.update(g, state, params)
        exact_v = 0.9 * exact_v + 0.1 * np.square(np.asarray(g["e"]))
        bc2 = 1.0 - 0.9 ** t
        # direction = g / sqrt(vhat_sketch); vhat_sketch >= vhat_exact
        # (eps = 0, b1 = 0) => |direction| <= |g| / sqrt(vhat_exact)
        bound = np.abs(np.asarray(g["e"])) / np.sqrt(exact_v / bc2)
        assert np.all(np.abs(np.asarray(upd["e"])) <= bound * (1 + 1e-5))


def test_dense_fallback_bitwise_matches_scale_by_adam():
    """Leaves below min_rows run EXACT dense Adam — bitwise, not close."""
    params = {"w": jnp.full((8, 4), 0.3), "b": jnp.full((5,), -0.2)}
    sk = scale_by_sketch(SketchConfig(b1=0.9, b2=0.999, eps=1e-8,
                                      min_rows=1024))
    ad = scale_by_adam(b1=0.9, b2=0.999, eps=1e-8)
    s_sk, s_ad = sk.init(params), ad.init(params)
    key = jax.random.PRNGKey(1)
    for _ in range(3):
        key, sub = jax.random.split(key)
        g = jax.tree.map(
            lambda p: jax.random.normal(sub, p.shape), params)
        u_sk, s_sk = sk.update(g, s_sk, params)
        u_ad, s_ad = ad.update(g, s_ad, params)
        for a, b in zip(jax.tree.leaves(u_sk), jax.tree.leaves(u_ad)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_make_optimizer_matches_build_optimizer():
    params = {"e": jnp.full((64, 8), 0.4), "b": jnp.full((3,), 0.1)}
    grads = jax.tree.map(lambda p: jnp.full_like(p, 0.2), params)
    m = make_optimizer("sketch", lr=0.05, depth=2, width=32, min_rows=16)
    b = build_optimizer(OptimizerConfig(
        name="sketch", schedule="constant", lr=0.05, weight_decay=0.0,
        sketch_depth=2, sketch_width=32, embedding_min_rows=16))
    u_m, _ = m.update(grads, m.init(params), params)
    u_b, _ = b.update(grads, b.init(params), params)
    for a, c in zip(jax.tree.leaves(u_m), jax.tree.leaves(u_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_seeds_are_deterministic_and_rebuildable():
    """A fresh init rebuilds identical static metadata (what lets
    checkpoint restore re-derive the treedef) and distinct leaves get
    distinct hash seeds."""
    params = {"e1": jnp.zeros((32, 4)), "e2": jnp.zeros((32, 4))}
    opt = scale_by_sketch(SketchConfig(min_rows=8, depth=2, width=16))
    s1, s2 = opt.init(params), opt.init(params)
    assert jax.tree.structure(s1) == jax.tree.structure(s2)
    assert s1.leaves[0].seeds == s2.leaves[0].seeds
    assert s1.leaves[0].seeds != s1.leaves[1].seeds


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------

def test_sketch_memory_reduction_vs_dense_adam():
    """The headline: >= 4x optimizer-state reduction on an embedding leaf
    at b1 = 0 (second moment only; the table is vocab-independent)."""
    params = {"emb": jnp.zeros((8192, 64))}
    sk = scale_by_sketch(SketchConfig(b1=0.0, depth=4, width=256,
                                      min_rows=1024))
    ad = scale_by_adam()
    n_sk = tree_nbytes(sk.init(params))
    n_ad = tree_nbytes(ad.init(params))
    assert n_ad >= 4 * n_sk, (n_ad, n_sk)
    # b1 > 0 allocates the exact first moment on top of the table
    n_m = tree_nbytes(scale_by_sketch(SketchConfig(
        b1=0.9, depth=4, width=256, min_rows=1024)).init(params))
    assert n_m >= n_sk + params["emb"].size * 4


def test_sketch_table_size_independent_of_rows():
    cfg = SketchConfig(b1=0.0, depth=4, width=256, min_rows=64)
    small = scale_by_sketch(cfg).init({"e": jnp.zeros((64, 32))})
    big = scale_by_sketch(cfg).init({"e": jnp.zeros((4096, 32))})
    assert tree_nbytes(small) == tree_nbytes(big)


# ---------------------------------------------------------------------------
# state_sharding_spec protocol
# ---------------------------------------------------------------------------

def test_opt_state_shardings_via_protocol_sketch():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    params = {"emb": jnp.zeros((2048, 64)), "b": jnp.zeros((64,))}
    opt = make_optimizer("sketch", min_rows=1024, depth=2, width=32)
    state_struct = jax.eval_shape(opt.init, params)
    pspecs = {"emb": P("data", "model"), "b": P("model")}
    sh = SH.opt_state_shardings(opt, state_struct, pspecs, mesh)
    st = sh[0]                         # chain stage 0: scale_by_sketch
    # flatten order: b=0 (dense fallback), emb=1 (sketched)
    assert st.leaves[0].m.spec == P("model")
    assert st.leaves[0].v.spec == P("model")
    # hashed row axis is gone -> replicate depth/width, inner follows the
    # param's axis-1 spec (2-D leaf, nothing flattened into it)
    assert st.leaves[1].table.spec == P(None, None, "model")
    assert st.leaves[1].m.spec == P("data", "model")
    assert st.step.spec == P()


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_sketch_telemetry_snapshot_and_event():
    params = {"e": jnp.zeros((64, 4)), "b": jnp.zeros((3,))}
    grads = jax.tree.map(lambda p: jnp.full_like(p, 0.1), params)
    cfg = dict(b2=0.999, depth=2, width=16, min_rows=8)
    on = scale_by_sketch(SketchConfig(telemetry=True, **cfg))
    off = scale_by_sketch(SketchConfig(telemetry=False, **cfg))
    u_on, s_on = on.update(grads, on.init(params), params)
    u_off, _ = off.update(grads, off.init(params), params)
    # collection never changes the update
    for a, b in zip(jax.tree.leaves(u_on), jax.tree.leaves(u_off)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    snap = s_on.telemetry
    assert snap.occupancy.shape == (1,) and snap.leaf_indices == (1,)
    occ = float(snap.occupancy[0])
    over = float(snap.overestimate[0])
    # 64 rows into 16 buckets: every bucket hit; collisions guaranteed
    assert occ == 1.0
    assert over >= 1.0
    # the host-side event conforms to the sink schema
    ev = TelemetryRuntime._sketch_event(3, "embeddings",
                                        jax.device_get(snap))
    ev["schema"] = 1
    validate_event(ev)
    assert ev["mean_occupancy"] == occ and ev["mean_overestimate"] == over


# ---------------------------------------------------------------------------
# convergence: embedding-dominated problem
# ---------------------------------------------------------------------------

def test_sketch_converges_like_adam_on_embeddings():
    """Embedding regression (sparse row updates, the backend's target
    workload): the sketch-Adam loss tracks dense Adam within tolerance."""
    vocab, dim = 256, 16
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (vocab, dim)) * 0.5
    ids = jax.random.randint(jax.random.fold_in(key, 1), (8, 64), 0, vocab)
    params0 = {"emb": jnp.zeros((vocab, dim))}

    def loss_fn(p, batch):
        return jnp.mean((p["emb"][batch] - target[batch]) ** 2)

    def run(opt):
        params, state = params0, opt.init(params0)

        @jax.jit
        def step(p, s, batch):
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            upd, s = opt.update(g, s, p)
            return apply_updates(p, upd), s, loss

        for t in range(200):
            params, state, loss = step(params, state, ids[t % 8])
        return float(loss)

    loss0 = float(loss_fn(params0, ids[0]))
    l_adam = run(make_optimizer("adamw", lr=0.05))
    l_sketch = run(make_optimizer("sketch", lr=0.05, depth=4, width=512,
                                  min_rows=64))
    assert l_sketch < 0.05 * loss0, (loss0, l_sketch)
    assert l_sketch < 3.0 * l_adam + 1e-6, (l_adam, l_sketch)


def test_sketch_state_extractor():
    params = {"e": jnp.zeros((64, 4))}
    opt = make_optimizer("sketch", min_rows=8, depth=2, width=16)
    st = sketch_state(opt.init(params))
    assert isinstance(st.leaves[0], SketchLeaf)
    assert not isinstance(st.leaves[0], SketchDense)
