"""MoE expert parallelism on a REAL multi-device mesh (8 host devices,
subprocess): sharded EP (+FSDP gather) and EP-TP decode layouts must both
match the single-device oracle bit-for-tolerance."""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.config import ModelConfig, MoESpec
from repro.models import moe as MOE

cfg = ModelConfig(arch="t", family="moe", n_layers=1, d_model=32, n_heads=4,
                  n_kv_heads=4, d_ff=64, vocab=64,
                  moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=16,
                              capacity_factor=64.0, impl="sort"))
p = MOE.moe_init(jax.random.PRNGKey(0), cfg, 32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))

mesh = jax.make_mesh((2, 4), ("data", "model"))
y_ref, aux_ref = MOE.moe_apply_local(cfg, p, x)

# EP over model + FSDP gather over data (train layout)
y_ep, aux_ep = MOE.moe_apply_sharded(cfg, p, x, mesh, dp_axes=("data",),
                                     gather_axes=("data",))
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-5)

# weights-stationary EP-TP (decode layout)
y_tp, aux_tp = MOE.moe_apply_ep_tp(cfg, p, x, mesh)
np.testing.assert_allclose(np.asarray(y_tp), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-5)
print("MOE_MULTIDEVICE_OK")
"""


def test_moe_ep_on_8_devices():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=400,
                         cwd=str(REPO))
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "MOE_MULTIDEVICE_OK" in out.stdout
