"""Int8-quantized factor storage (paper's Discussion: quantization
compatibility) — memory and fidelity vs the fp32 factor path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdapproxConfig, RankConfig, adapprox, apply_updates,
                        tree_nbytes)
from repro.core.quantized import dequantize, quantize


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 16)) * 3.0
    err = jnp.abs(dequantize(quantize(x)) - x)
    colmax = jnp.max(jnp.abs(x), axis=0)
    assert float(jnp.max(err / colmax[None, :])) <= 1.0 / 127 + 1e-6


def _cfg(dtype):
    return AdapproxConfig(lr=1e-2, b1=0.9, b2=0.99, min_dim_factor=1,
                          oversample=2, n_iter=3, factor_dtype=dtype,
                          rank=RankConfig(k_init=8, mode="static"), seed=0)


def test_int8_factors_shrink_state_4x():
    params = {"w": jnp.zeros((512, 512))}
    nb32 = tree_nbytes(adapprox(_cfg("float32")).init(params))
    nb8 = tree_nbytes(adapprox(_cfg("int8")).init(params))
    # m1 dominates both; compare factor-only (b1=0)
    import dataclasses
    nb32f = tree_nbytes(adapprox(dataclasses.replace(
        _cfg("float32"), b1=0.0)).init(params))
    nb8f = tree_nbytes(adapprox(dataclasses.replace(
        _cfg("int8"), b1=0.0)).init(params))
    assert nb8f < 0.30 * nb32f          # ~4x (scales add a little)
    assert nb8 < nb32


def test_int8_trajectory_tracks_fp32():
    """Quantisation error must behave like slightly-larger xi, not
    compound: parameter trajectories stay close over 10 steps."""
    params32 = {"w": jax.random.normal(jax.random.PRNGKey(1),
                                       (96, 96)) * 0.1}
    params8 = jax.tree.map(jnp.copy, params32)
    opt32, opt8 = adapprox(_cfg("float32")), adapprox(_cfg("int8"))
    s32, s8 = opt32.init(params32), opt8.init(params8)
    key = jax.random.PRNGKey(2)
    u32 = jax.jit(opt32.update)
    u8 = jax.jit(opt8.update)
    for t in range(10):
        g = {"w": jax.random.normal(jax.random.fold_in(key, t), (96, 96))}
        d32, s32 = u32(g, s32, params32)
        d8, s8 = u8(g, s8, params8)
        params32 = apply_updates(params32, d32)
        params8 = apply_updates(params8, d8)
    diff = float(jnp.max(jnp.abs(params32["w"] - params8["w"])))
    scale = float(jnp.max(jnp.abs(params32["w"])))
    assert diff < 0.05 * scale, (diff, scale)
    assert np.all(np.isfinite(np.asarray(params8["w"])))
