"""Chaos suite: the resilience layer exercised through the REAL train
loop under deterministic fault injection (repro.resilience).

Scenarios (the PR-7 acceptance list):

  * NaN gradient burst with guards on — every parameter stays finite,
    the loss recovers, and the skip counters match the injection
    schedule EXACTLY;
  * guards on without faults — same trajectory as guards off;
  * SIGTERM mid-step (subprocess — the preemption handler re-raises via
    SIG_DFL) — the restarted run resumes from the preemption checkpoint
    and finishes bitwise-identical to an uninterrupted run;
  * corrupted latest checkpoint (bit flip: sizes intact, only the deep
    sha256 verify can see it) — the restart falls back to the previous
    good checkpoint and still finishes bitwise-identical;
  * simulated device loss — the remesh plan from the survivors restores
    the checkpoint under the new mesh (8-device CI job).
"""
import os
import signal
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.checkpoint import serialization as SER
from repro.config import OptimizerConfig
from repro.configs import get_smoke_config
from repro.core import build_optimizer, chain
from repro.data import DataConfig
from repro.models import build_model
from repro.resilience import (FaultPlan, corrupt_latest_checkpoint,
                              inject_faults, remesh_after_loss)
from repro.telemetry import chain_guard_state
from repro.train import LoopConfig, train

REPO = Path(__file__).resolve().parent.parent

# Shared by the in-process tests AND the SIGTERM subprocess (exec'd into
# both namespaces so the two runs are the same program by construction).
SETUP = r"""
import jax, jax.numpy as jnp
from repro.config import OptimizerConfig
from repro.configs import get_smoke_config
from repro.core import build_optimizer
from repro.data import DataConfig
from repro.models import build_model
from repro.train import LoopConfig, train

def make_model():
    return build_model(get_smoke_config("gpt2-117m", vocab=64,
                                        max_seq_len=32))

def make_opt():
    # guarded adapprox with a mid-size refresh interval, so checkpoints
    # land mid-interval and the guard state rides the restore
    return build_optimizer(OptimizerConfig(
        name="adapprox", schedule="constant", lr=3e-3, weight_decay=0.1,
        k=4, rank_mode="static", min_dim_factor=32, implicit=False,
        refresh_every=2, guards=True))

DATA = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=0)
"""
_ns: dict = {}
exec(SETUP, _ns)


def assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# NaN burst through the full loop
# ---------------------------------------------------------------------------

def test_nan_burst_skips_exactly_and_recovers():
    plan = FaultPlan(nan_steps=(5, 6), inf_steps=(11,))
    opt = chain(inject_faults(plan), _ns["make_opt"]())
    state, hist = train(_ns["make_model"](), opt, _ns["DATA"],
                        LoopConfig(total_steps=14, log_every=1))
    gs = chain_guard_state(state.opt_state)
    assert int(np.asarray(gs.skipped)) == 3
    assert int(np.asarray(gs.last_skip)) == 11
    for leaf in jax.tree.leaves(state.params):
        assert bool(np.all(np.isfinite(np.asarray(leaf))))
    losses = [m["loss"] for m in hist]
    assert np.all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_guards_without_faults_match_unguarded_run():
    plan_off = train(_ns["make_model"](),
                     build_optimizer(OptimizerConfig(
                         name="adapprox", schedule="constant", lr=3e-3,
                         weight_decay=0.1, k=4, rank_mode="static",
                         min_dim_factor=32, implicit=False,
                         refresh_every=2)),
                     _ns["DATA"], LoopConfig(total_steps=6, log_every=1))
    plan_on = train(_ns["make_model"](), _ns["make_opt"](), _ns["DATA"],
                    LoopConfig(total_steps=6, log_every=1))
    gs = chain_guard_state(plan_on[0].opt_state)
    assert int(np.asarray(gs.skipped)) == 0
    assert_trees_equal(plan_off[0].params, plan_on[0].params)


# ---------------------------------------------------------------------------
# SIGTERM mid-step -> preemption checkpoint -> bitwise resume
# ---------------------------------------------------------------------------

def test_sigterm_midrun_resumes_bitwise(tmp_path):
    total, kill_at = 10, 6
    ck_dir = str(tmp_path / "ck")

    # uninterrupted reference
    ref, _ = train(_ns["make_model"](), _ns["make_opt"](), _ns["DATA"],
                   LoopConfig(total_steps=total, log_every=5))

    # the killed run MUST be a subprocess: the preemption handler hands
    # the signal on via SIG_DFL + re-raise, which terminates the process
    script = SETUP + f"""
import os, signal
from repro.checkpoint import CheckpointConfig

def hook(step, m):
    if step == {kill_at}:
        os.kill(os.getpid(), signal.SIGTERM)

train(make_model(), make_opt(), DATA,
      LoopConfig(total_steps={total}, log_every=1,
                 ckpt=CheckpointConfig(directory={ck_dir!r},
                                       save_every=10**9,
                                       async_save=False)),
      metric_hook=hook, install_signal_handler=True)
raise SystemExit("unreachable: SIGTERM should have killed the loop")
"""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGTERM, proc.stderr[-2000:]

    mgr = CheckpointManager(CheckpointConfig(directory=ck_dir))
    assert mgr.latest_step() == kill_at
    assert mgr.read_meta(kill_at).get("preempted") is True

    # restart in-process: restores the preemption checkpoint, finishes
    resumed, _ = train(
        _ns["make_model"](), _ns["make_opt"](), _ns["DATA"],
        LoopConfig(total_steps=total, log_every=5,
                   ckpt=CheckpointConfig(directory=ck_dir,
                                         save_every=10**9,
                                         async_save=False)))
    assert_trees_equal(ref.params, resumed.params)
    assert_trees_equal(ref.opt_state, resumed.opt_state)


# ---------------------------------------------------------------------------
# corrupted latest checkpoint -> fallback -> bitwise resume
# ---------------------------------------------------------------------------

def test_corrupt_latest_falls_back_and_resumes_bitwise(tmp_path):
    ck_dir = str(tmp_path / "ck")
    total = 12

    ref, _ = train(_ns["make_model"](), _ns["make_opt"](), _ns["DATA"],
                   LoopConfig(total_steps=total, log_every=5))

    ck = CheckpointConfig(directory=ck_dir, save_every=4, async_save=False)
    train(_ns["make_model"](), _ns["make_opt"](), _ns["DATA"],
          LoopConfig(total_steps=8, log_every=5, ckpt=ck))
    # flip one payload bit in the newest checkpoint (step 8): sizes stay
    # right, so only restore()'s deep verification can catch it
    corrupt_latest_checkpoint(ck_dir, kind="bitflip")
    step8 = Path(ck_dir) / "step_000000008"
    assert SER.verify_checkpoint(step8)
    assert not SER.verify_checkpoint(step8, deep=True)

    resumed, _ = train(_ns["make_model"](), _ns["make_opt"](), _ns["DATA"],
                       LoopConfig(total_steps=total, log_every=5, ckpt=ck))
    assert_trees_equal(ref.params, resumed.params)
    assert_trees_equal(ref.opt_state, resumed.opt_state)


# ---------------------------------------------------------------------------
# simulated device loss -> remesh -> verified restore
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (multidevice CI job)")
def test_device_loss_remesh_restores_under_new_mesh(tmp_path):
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.distributed.elastic import build_mesh, elastic_restore

    # 12x8 divides evenly over the survivors' (data=3, model=2) mesh
    tree = {"w": np.arange(96, dtype=np.float32).reshape(12, 8),
            "step": np.asarray(0, np.int32)}
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                             async_save=False))
    mgr.save(tree, 1, blocking=True)
    mgr.save({**tree, "step": np.asarray(2, np.int32)}, 2, blocking=True)
    # the newest checkpoint dies with the lost devices' host
    corrupt_latest_checkpoint(str(tmp_path), kind="bitflip")

    plan = remesh_after_loss(lost=2, target_model=2, available_devices=8)
    assert plan.devices == 6 and plan.model == 2

    def make_shardings(mesh):
        return {"w": NamedSharding(mesh, P("data", "model")),
                "step": NamedSharding(mesh, P())}

    state, step, mesh = elastic_restore(
        mgr, like=tree, make_shardings=make_shardings,
        available_devices=plan.devices, target_model=2)
    # fallback past the corrupt step-2 checkpoint, restored on the
    # survivors' mesh with the planned shape
    assert step == 1
    assert dict(mesh.shape) == {"data": 3, "model": 2}
    np.testing.assert_array_equal(np.asarray(state["w"]), tree["w"])
    assert state["w"].sharding.mesh.devices.size == 6


# ---------------------------------------------------------------------------
# SIGTERM with tracing live -> open spans drained truncated, sink flushed
# ---------------------------------------------------------------------------

def test_sigterm_drains_open_spans_truncated(tmp_path):
    """The preemption handler chain drains spans still open at SIGTERM
    as ``"truncated": true`` events and flushes the sink BEFORE the
    checkpoint + re-raise, so the trace survives the kill.  The hook
    opens a span and dies inside it — deterministic, unlike killing
    mid-step."""
    total, kill_at = 10, 4
    trace_dir = str(tmp_path / "trace")
    ck_dir = str(tmp_path / "ck")

    script = SETUP + f"""
import os, signal
from repro.checkpoint import CheckpointConfig
from repro.telemetry import SinkConfig, TelemetrySink, Tracer

sink = TelemetrySink(SinkConfig(directory={trace_dir!r}))
tracer = Tracer(sink=sink)

_cm = tracer.span("hook")   # module-held: must stay OPEN at kill time
def hook(step, m):
    if step == {kill_at}:
        _cm.__enter__()
        os.kill(os.getpid(), signal.SIGTERM)

train(make_model(), make_opt(), DATA,
      LoopConfig(total_steps={total}, log_every=1,
                 ckpt=CheckpointConfig(directory={ck_dir!r},
                                       save_every=10**9,
                                       async_save=False)),
      tracer=tracer, metric_hook=hook, install_signal_handler=True)
raise SystemExit("unreachable: SIGTERM should have killed the loop")
"""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGTERM, proc.stderr[-2000:]

    from repro.telemetry import check_events, load_events, validate_dir

    assert validate_dir(trace_dir) > 0      # flushed AND schema-valid
    events = load_events(trace_dir)
    assert check_events(events) == []
    spans = [e for e in events if e["kind"] == "span"]
    # every step up to the kill closed its full span set on disk
    steps = [e for e in spans if e["name"] == "train_step"]
    assert {e["step"] for e in steps} == set(range(1, kill_at + 1))
    # the span open at SIGTERM was drained, marked truncated, exactly once
    hook_spans = [e for e in spans if e["name"] == "hook"]
    assert len(hook_spans) == 1
    assert hook_spans[0]["truncated"] is True
