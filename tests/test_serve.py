"""Serving-engine suite: continuous batching, the paged KV cache, and
the wave baseline.

Pins the PR's load-bearing claims:
  * paged decode is BITWISE identical to the dense cache path (the pool
    seeded from one dense prefill via ``pool_from_dense``);
  * wave and continuous produce IDENTICAL greedy streams for identical
    arrival order on equal-length prompts, and continuous matches a
    per-request solo wave reference on MIXED prompt lengths (the wave
    batch itself is pad-contaminated there — documented engine caveat);
  * ``BlockAllocator`` accounting: free-list reuse, the reservation
    ledger, double-free / exhaustion errors, and clean drain-down after
    an engine run;
  * the wave EOS-on-first-token and ``max_new_tokens<=0`` regressions;
  * admission backs off (with telemetry) instead of failing when the
    pool is occupancy-constrained, and bounded queues load-shed;
  * request churn never recompiles the jitted decode step.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import (ContinuousConfig, ContinuousEngine, Engine,
                         NULL_BLOCK, BlockAllocator, PoolExhausted, Request,
                         ServeConfig, SlotTable, pool_from_dense)
from repro.telemetry import SinkConfig, TelemetrySink, validate_dir

CACHE_LEN = 128
BLOCK_SIZE = 16
NBT = CACHE_LEN // BLOCK_SIZE
VOCAB = 512


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_smoke_config("gpt2-117m")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _reqs(lengths, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, VOCAB, size=n).astype(np.int32),
                    max_new_tokens=m)
            for i, (n, m) in enumerate(zip(lengths, budgets))]


def _clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens) for r in reqs]


def _cont(model, params, **over):
    kw = dict(slots=4, cache_len=CACHE_LEN, block_size=BLOCK_SIZE,
              prefill_chunk=32)
    kw.update(over)
    return ContinuousEngine(model, params, ContinuousConfig(**kw))


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------

class TestBlockAllocator:
    def test_null_block_never_allocated(self):
        a = BlockAllocator(9, BLOCK_SIZE)
        ids = a.alloc(8)
        assert NULL_BLOCK not in ids
        assert sorted(ids) == list(range(1, 9))

    def test_free_then_reuse(self):
        a = BlockAllocator(5, BLOCK_SIZE)
        first = a.alloc(4)
        assert a.free_blocks() == 0
        a.free(first)
        assert a.free_blocks() == 4
        again = a.alloc(4)
        assert sorted(again) == sorted(first)

    def test_double_free_and_bad_ids_raise(self):
        a = BlockAllocator(5, BLOCK_SIZE)
        ids = a.alloc(2)
        a.free(ids)
        with pytest.raises(ValueError, match="double-free"):
            a.free([ids[0]])
        with pytest.raises(ValueError, match="invalid block id"):
            a.free([NULL_BLOCK])
        with pytest.raises(ValueError, match="invalid block id"):
            a.free([99])

    def test_exhaustion(self):
        a = BlockAllocator(5, BLOCK_SIZE)
        a.alloc(3)
        with pytest.raises(PoolExhausted):
            a.alloc(2)

    def test_reservation_ledger(self):
        a = BlockAllocator(9, BLOCK_SIZE)       # 8 usable
        assert a.reserve(5)
        assert a.available() == 3
        assert a.occupancy() == pytest.approx(5 / 8)
        # unreserved allocs may not raid the reservation
        with pytest.raises(PoolExhausted):
            a.alloc(4)
        got = a.alloc(3, reserved=True)         # draw against it
        assert len(got) == 3
        assert a.available() == 3               # 2 still reserved, 3 out
        a.release(2)                            # leftover at finish
        assert a.available() == 5
        assert not a.reserve(6)                 # over-ask reserves nothing
        assert a.available() == 5

    def test_blocks_for(self):
        a = BlockAllocator(5, 16)
        assert a.blocks_for(1) == 1
        assert a.blocks_for(16) == 1
        assert a.blocks_for(17) == 2

    def test_slot_table_padded(self):
        t = SlotTable([3, 1, 2])
        row = t.padded(6)
        assert row.dtype == np.int32
        assert row.tolist() == [3, 1, 2, 0, 0, 0]
        assert t.capacity(16) == 48


# ---------------------------------------------------------------------------
# paged cache vs dense cache: bitwise
# ---------------------------------------------------------------------------

def test_paged_decode_bitwise_matches_dense(model_and_params):
    """Seed the block pool from one dense prefill (pool_from_dense),
    then step both representations on identical fed tokens: the logits
    must match BITWISE every step."""
    model, params = model_and_params
    import jax.numpy as jnp
    rng = np.random.default_rng(7)
    b, plen = 2, 16
    prompts = rng.integers(0, VOCAB, size=(b, plen)).astype(np.int32)
    cache = model.init_cache(b, CACHE_LEN)
    logits, cache = jax.jit(model.prefill)(params, jnp.asarray(prompts),
                                           cache)
    alloc = BlockAllocator(b * NBT + 1, BLOCK_SIZE)
    tables = [SlotTable(alloc.alloc(NBT)) for _ in range(b)]
    pool = pool_from_dense(model, cache, tables, [plen] * b,
                           b * NBT + 1, BLOCK_SIZE)
    tabs = jnp.asarray(np.stack([t.padded(NBT) for t in tables]))
    toks = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    pos = np.full((b,), plen, np.int32)
    dense_step = jax.jit(model.decode_step)
    paged_step = jax.jit(model.decode_paged)
    for _ in range(6):
        ld, cache = dense_step(params, cache, toks)
        lp, pool = paged_step(params, pool, toks, tabs, jnp.asarray(pos))
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
        toks = jnp.argmax(ld[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        pos += 1


# ---------------------------------------------------------------------------
# stream parity between schedulers
# ---------------------------------------------------------------------------

def test_wave_and_continuous_identical_streams(model_and_params):
    """Equal-length prompts (no wave pad contamination), identical
    arrival order: both schedulers must emit identical greedy streams,
    request by request — continuous batching changes WHEN tokens are
    computed, never WHICH."""
    model, params = model_and_params
    reqs = _reqs([12] * 6, [5, 17, 3, 9, 1, 7])
    wave_reqs, cont_reqs = _clone(reqs), _clone(reqs)
    Engine(model, params,
           ServeConfig(slots=4, cache_len=CACHE_LEN)).run(wave_reqs)
    eng = _cont(model, params)
    eng.run(cont_reqs)
    for w, c in zip(wave_reqs, cont_reqs):
        assert w.out_tokens == c.out_tokens, f"req {w.uid} diverged"
        assert len(c.out_tokens) == c.max_new_tokens
        assert c.done and c.done_s is not None
    # clean drain: every block is back in the pool, nothing reserved
    assert eng.alloc.free_blocks() == eng.alloc.usable
    assert eng.alloc.occupancy() == 0.0


def test_continuous_mixed_lengths_match_solo_reference(model_and_params):
    """Mixed prompt lengths batched continuously must match each request
    served ALONE (slots=1 wave = the unbatched reference): per-slot
    positions + block tables isolate rows completely."""
    model, params = model_and_params
    reqs = _reqs([5, 33, 17, 8, 26], [6, 4, 9, 3, 5], seed=3)
    cont_reqs = _clone(reqs)
    _cont(model, params, prefill_chunk=16).run(cont_reqs)
    solo = Engine(model, params, ServeConfig(slots=1, cache_len=CACHE_LEN))
    for r in reqs:
        ref = _clone([r])
        solo.run(ref)
        got = next(c for c in cont_reqs if c.uid == r.uid)
        assert got.out_tokens == ref[0].out_tokens, f"req {r.uid} diverged"


# ---------------------------------------------------------------------------
# wave regressions
# ---------------------------------------------------------------------------

def test_wave_eos_on_first_token(model_and_params):
    """EOS straight out of prefill must end the sequence at one token —
    the seed engine kept decoding its full budget past it."""
    model, params = model_and_params
    probe = _reqs([10], [1], seed=11)
    Engine(model, params,
           ServeConfig(slots=2, cache_len=CACHE_LEN)).run(probe)
    eos = probe[0].out_tokens[0]   # the greedy first token IS our "EOS"
    reqs = _reqs([10], [64], seed=11)
    Engine(model, params,
           ServeConfig(slots=2, cache_len=CACHE_LEN, eos_id=eos)).run(reqs)
    assert reqs[0].out_tokens == [eos]
    assert reqs[0].done and reqs[0].first_token_s is not None

    cont = _reqs([10], [64], seed=11)
    _cont(model, params, eos_id=eos).run(cont)
    assert cont[0].out_tokens == [eos]


def test_zero_budget_emits_nothing(model_and_params):
    model, params = model_and_params
    for make in (lambda: Engine(model, params,
                                ServeConfig(slots=2, cache_len=CACHE_LEN)),
                 lambda: _cont(model, params)):
        reqs = _reqs([9, 9], [0, 3], seed=5)
        make().run(reqs)
        assert reqs[0].out_tokens == []
        assert reqs[0].done and reqs[0].done_s is not None
        assert len(reqs[1].out_tokens) == 3


# ---------------------------------------------------------------------------
# admission, occupancy, load shedding
# ---------------------------------------------------------------------------

def test_admission_backs_off_under_full_occupancy(model_and_params,
                                                  tmp_path):
    """A pool sized for ONE request must serve many: admission waits at
    the occupancy watermark (emitting backoff telemetry) and recycles
    blocks as requests finish — never PoolExhausted, never a wrong
    stream."""
    model, params = model_and_params
    cache_len, nbt = 64, 64 // BLOCK_SIZE
    sink = TelemetrySink(SinkConfig(directory=str(tmp_path)))
    eng = ContinuousEngine(
        model, params,
        ContinuousConfig(slots=2, cache_len=cache_len,
                         block_size=BLOCK_SIZE, prefill_chunk=16,
                         num_blocks=nbt + 1),     # ONE slot's worth
        sink=sink)
    reqs = _reqs([16, 16, 16], [48 - 16, 40 - 16, 20], seed=9)
    eng.run(reqs)
    sink.flush()
    sink.close()
    for r in reqs:
        assert len(r.out_tokens) == r.max_new_tokens
        assert r.done
    assert eng.alloc.free_blocks() == eng.alloc.usable
    events = [json.loads(line)
              for p in sorted(tmp_path.glob("events-*.jsonl"))
              for line in p.read_text().splitlines()]
    kinds = {e["event"] for e in events}
    assert "backoff" in kinds, "full pool must emit admission backoff"
    assert {"admit", "first_token", "finish"} <= kinds
    # schema-valid end to end
    assert validate_dir(tmp_path) == len(events)


def test_bounded_queue_load_sheds(model_and_params):
    model, params = model_and_params
    eng = _cont(model, params, slots=1, max_queue=2)
    reqs = _reqs([8] * 4, [4] * 4, seed=2)
    # all four arrive at t=0, BEFORE the first scheduler step admits
    # anything: two fill the bounded queue, two are shed
    eng.run(reqs)
    served = [r for r in reqs if not r.rejected]
    shed = [r for r in reqs if r.rejected]
    assert len(shed) == 2
    assert all(r.out_tokens == [] and r.done for r in shed)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in served)


def test_oversized_request_rejected_upfront(model_and_params):
    model, params = model_and_params
    eng = _cont(model, params)
    with pytest.raises(ValueError, match="span"):
        eng.run(_reqs([64], [CACHE_LEN], seed=1))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.run([Request(uid=0, prompt=np.zeros(0, np.int32),
                         max_new_tokens=4)])


# ---------------------------------------------------------------------------
# compile-once
# ---------------------------------------------------------------------------

def test_request_churn_never_recompiles_decode(model_and_params):
    """The jitted decode step sees fixed shapes; block tables and
    positions are DATA.  Mixed prompt lengths and budgets across many
    admissions must leave exactly one decode executable, and prefill at
    most one per chunk bucket."""
    model, params = model_and_params
    eng = _cont(model, params, prefill_chunk=32)
    reqs = _reqs([5, 12, 33, 8, 40, 21, 9, 17],
                 [3, 7, 4, 11, 2, 5, 6, 8], seed=4)
    eng.run(reqs)
    assert eng._decode_jit._cache_size() == 1
    assert eng._prefill_jit._cache_size() <= 3   # buckets 8/16/32
    eng.run(_reqs([6, 14, 27], [4, 3, 5], seed=8))
    assert eng._decode_jit._cache_size() == 1


# ---------------------------------------------------------------------------
# span waterfalls (trace-id join contract)
# ---------------------------------------------------------------------------

class TestWaterfalls:
    def _run_traced(self, tmp_path, engine, reqs, **run_kw):
        from repro.telemetry import MetricsRegistry, Tracer
        sink = TelemetrySink(SinkConfig(directory=str(tmp_path)))
        tracer = Tracer(sink=sink, registry=MetricsRegistry())
        engine.sink = sink
        engine.set_tracer(tracer)
        engine.run(reqs, **run_kw)
        tracer.flush()
        sink.flush()
        sink.close()
        from repro.telemetry import load_events
        assert validate_dir(tmp_path) > 0
        return load_events(tmp_path), tracer

    def test_continuous_requests_reconstruct_complete_waterfalls(
            self, model_and_params, tmp_path):
        from repro.telemetry import check_events
        from repro.telemetry.trace import ROOT_SPAN
        model, params = model_and_params
        reqs = _reqs([5, 17, 33, 9, 40], [6, 3, 5, 1, 4])
        events, tracer = self._run_traced(tmp_path,
                                          _cont(model, params), reqs)
        assert check_events(events) == []
        spans = [e for e in events if e["kind"] == "span"]
        finishes = [e for e in events
                    if e["kind"] == "serve" and e["event"] == "finish"]
        assert len(finishes) == len(reqs)
        for f in finishes:
            # every finish joins its waterfall by trace id alone
            mine = [s for s in spans if s["trace"] == f["trace"]]
            names = {s["name"] for s in mine}
            assert {"request", "queued"} <= names
            assert "prefill_chunk" in names
            root = next(s for s in mine if s["name"] == "request")
            assert root["span"] == ROOT_SPAN
            assert root["uid"] == f["uid"]
            assert root["attrs"]["tokens"] == f["tokens"]
            # phases nest under the root and inside its window
            for s in mine:
                if s is root:
                    continue
                assert s["parent"] == ROOT_SPAN
                assert s["t0_s"] >= root["t0_s"] - 1e-6
        # chunked prefill: the 33/40-token prompts crossed prefill_chunk=32
        chunky = [f["trace"] for f in finishes if f["uid"] in (2, 4)]
        for t in chunky:
            n = sum(1 for s in spans
                    if s["trace"] == t and s["name"] == "prefill_chunk")
            assert n == 2
        # registry rolled up the served requests
        reg = tracer.registry
        assert reg.counter("serve_requests_total").value(
            scheduler="continuous") == len(reqs)

    def test_wave_requests_reconstruct_complete_waterfalls(
            self, model_and_params, tmp_path):
        from repro.telemetry import check_events
        model, params = model_and_params
        reqs = _reqs([8, 8, 8], [4, 2, 6])
        eng = Engine(model, params,
                     ServeConfig(slots=4, cache_len=CACHE_LEN))
        events, _ = self._run_traced(tmp_path, eng, reqs)
        assert check_events(events) == []
        spans = [e for e in events if e["kind"] == "span"]
        for name in ("request", "queued", "prefill"):
            assert sum(1 for s in spans if s["name"] == name) == len(reqs)

    def test_untraced_run_emits_no_spans(self, model_and_params, tmp_path):
        """tracer=None (the default) keeps the serve stream span-free —
        tracing is strictly opt-in."""
        model, params = model_and_params
        sink = TelemetrySink(SinkConfig(directory=str(tmp_path)))
        eng = _cont(model, params)
        eng.sink = sink
        eng.run(_reqs([5, 9], [3, 2]))
        sink.flush()
        sink.close()
        from repro.telemetry import load_events
        events = load_events(tmp_path)
        assert events and all(e["kind"] == "serve" for e in events)
        assert all("trace" not in e for e in events)
