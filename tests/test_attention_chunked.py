"""Chunked (online-softmax) attention must match the naive path exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.models import attention as A


def cfg_for(h, kv, hd):
    return ModelConfig(arch="t", family="dense", n_layers=1, d_model=h * hd,
                       n_heads=h, n_kv_heads=kv, d_ff=64, vocab=64,
                       head_dim=hd)


@pytest.mark.parametrize("h,kv,hd", [(4, 4, 16), (8, 2, 32), (4, 1, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_naive(h, kv, hd, causal):
    cfg = cfg_for(h, kv, hd)
    b, s = 2, 256
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))

    got = A._sdpa_chunked(cfg, q, k, v, causal=causal, q_chunk=64,
                          k_chunk=32)
    if causal:
        mask = (jnp.arange(s)[None, None, :] <= jnp.arange(s)[None, :, None])
    else:
        mask = jnp.ones((1, s, s), bool)
    want = A._sdpa(cfg, q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_chunked_cross_shapes():
    """Sq != Sk (cross attention / uneven chunks)."""
    cfg = cfg_for(4, 4, 16)
    b = 2
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (b, 128, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, 192, 4, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, 192, 4, 16))
    got = A._sdpa_chunked(cfg, q, k, v, causal=False, q_chunk=32, k_chunk=64)
    want = A._sdpa(cfg, q, k, v, jnp.ones((1, 128, 192), bool))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)
