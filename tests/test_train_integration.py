"""End-to-end: tiny GPT-2-family model trains with Adapprox, loss drops,
checkpoint-restart is bit-exact, serving engine generates."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig
from repro.configs import get_smoke_config
from repro.core import Schedule, make_optimizer
from repro.data import DataConfig
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig
from repro.train import LoopConfig, TrainState, train


def tiny_model(arch="gpt2-117m", **over):
    cfg = get_smoke_config(arch, **over)
    return cfg, build_model(cfg)


def test_training_reduces_loss():
    cfg, model = tiny_model(vocab=128)
    opt = make_optimizer("adapprox", lr=Schedule(3e-3, warmup_steps=10,
                                                 total_steps=120),
                         b1=0.9, k_init=8, mode="static", min_dim_factor=32,
                         oversample=2, n_iter=2)
    data_cfg = DataConfig(vocab=128, seq_len=64, global_batch=8, seed=0)
    state, hist = train(model, opt, data_cfg,
                        LoopConfig(total_steps=120, log_every=20))
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first * 0.8, (first, last)
    assert np.isfinite(last)


def test_checkpoint_restart_bit_exact(tmp_path):
    cfg, model = tiny_model(vocab=64)
    mk_opt = lambda: make_optimizer("adamw", lr=1e-3)
    data_cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=3)
    ck = CheckpointConfig(directory=str(tmp_path), save_every=10,
                          async_save=False)

    # run 1: 20 steps straight through
    state_a, _ = train(model, mk_opt(), data_cfg,
                       LoopConfig(total_steps=20, log_every=5, ckpt=None))

    # run 2: 10 steps, checkpoint, then a NEW loop restores and finishes
    train(model, mk_opt(), data_cfg,
          LoopConfig(total_steps=10, log_every=5, ckpt=ck))
    state_b, _ = train(model, mk_opt(), data_cfg,
                       LoopConfig(total_steps=20, log_every=5, ckpt=ck))

    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_serving_engine_generates():
    cfg, model = tiny_model("qwen2-7b")
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(slots=2, cache_len=64))
    reqs = [Request(uid=i,
                    prompt=np.arange(5 + i, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=6) for i in range(5)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert all(len(r.out_tokens) == 6 for r in out)
    assert eng.waves == 3          # 2 + 2 + 1
    for r in out:
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_deterministic_across_waves():
    cfg, model = tiny_model("qwen2-7b")
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(7, dtype=np.int32) % cfg.vocab
    outs = []
    for _ in range(2):
        eng = Engine(model, params, ServeConfig(slots=2, cache_len=64))
        r = Request(uid=0, prompt=prompt, max_new_tokens=5)
        eng.run([r])
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]
