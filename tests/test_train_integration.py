"""End-to-end: tiny GPT-2-family model trains with Adapprox, loss drops,
checkpoint-restart is bit-exact (closed-loop telemetry controller
included), serving engine generates."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig
from repro.config import OptimizerConfig, TelemetryConfig
from repro.configs import get_smoke_config
from repro.core import Schedule, build_optimizer, make_optimizer
from repro.data import DataConfig
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig
from repro.telemetry import TelemetryRuntime, get_refresh_every
from repro.train import LoopConfig, TrainState, train


def tiny_model(arch="gpt2-117m", **over):
    cfg = get_smoke_config(arch, **over)
    return cfg, build_model(cfg)


def test_training_reduces_loss():
    cfg, model = tiny_model(vocab=128)
    opt = make_optimizer("adapprox", lr=Schedule(3e-3, warmup_steps=10,
                                                 total_steps=120),
                         b1=0.9, k_init=8, mode="static", min_dim_factor=32,
                         oversample=2, n_iter=2)
    data_cfg = DataConfig(vocab=128, seq_len=64, global_batch=8, seed=0)
    state, hist = train(model, opt, data_cfg,
                        LoopConfig(total_steps=120, log_every=20))
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first * 0.8, (first, last)
    assert np.isfinite(last)


def test_checkpoint_restart_bit_exact(tmp_path):
    cfg, model = tiny_model(vocab=64)
    mk_opt = lambda: make_optimizer("adamw", lr=1e-3)
    data_cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=3)
    ck = CheckpointConfig(directory=str(tmp_path), save_every=10,
                          async_save=False)

    # run 1: 20 steps straight through
    state_a, _ = train(model, mk_opt(), data_cfg,
                       LoopConfig(total_steps=20, log_every=5, ckpt=None))

    # run 2: 10 steps, checkpoint, then a NEW loop restores and finishes
    train(model, mk_opt(), data_cfg,
          LoopConfig(total_steps=10, log_every=5, ckpt=ck))
    state_b, _ = train(model, mk_opt(), data_cfg,
                       LoopConfig(total_steps=20, log_every=5, ckpt=ck))

    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def _auto_refresh_setup():
    """Tiny adapprox run with the closed-loop controller configured so it
    provably acts: the hysteresis band sits above any observable xi, so
    every 5-step interval RELAXES the cadence by 1 (clamped at 4) —
    deterministic cadence changes at steps 5, 10, 15."""
    cfg, model = tiny_model(vocab=64)
    opt = build_optimizer(OptimizerConfig(
        name="adapprox", schedule="constant", lr=3e-3, weight_decay=0.1,
        min_dim_factor=32, k=4, rank_mode="static", implicit=False,
        telemetry=True, dynamic_refresh=True))
    runtime = TelemetryRuntime(TelemetryConfig(
        enabled=True, auto_refresh=True, interval=5, xi_high=2.0,
        xi_low=1.9, relax_patience=1, relax_add=1, t_max=4))
    data_cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=3)
    return model, opt, runtime, data_cfg


def test_controller_kill_restore_reproduces_cadence_sequence(tmp_path):
    """A run killed and restored MID-controller-interval reproduces the
    identical cadence-change sequence and bitwise-identical final params:
    the cadence scalar restores with the optimizer state, the controller's
    partial-interval accumulators ride the checkpoint manifest."""
    total, kill_at = 18, 8           # 8 is inside the [6, 10] interval
    want_log = [(5, "default", 1, 2), (10, "default", 2, 3),
                (15, "default", 3, 4)]

    # --- uninterrupted reference ------------------------------------------
    model, opt, rt_a, data_cfg = _auto_refresh_setup()
    state_a, _ = train(model, opt, data_cfg,
                       LoopConfig(total_steps=total, log_every=5),
                       telemetry=rt_a)
    assert rt_a.cadence_log == want_log
    assert get_refresh_every(state_a.opt_state) == {"default": 4}

    # --- killed at step 8 (mid-interval), then restored -------------------
    ck = CheckpointConfig(directory=str(tmp_path), save_every=kill_at,
                          async_save=False)
    model, opt, rt_b1, _ = _auto_refresh_setup()
    train(model, opt, data_cfg,
          LoopConfig(total_steps=kill_at, log_every=5, ckpt=ck),
          telemetry=rt_b1)
    assert rt_b1.cadence_log == want_log[:1]

    model, opt, rt_b2, _ = _auto_refresh_setup()
    state_b, _ = train(model, opt, data_cfg,
                       LoopConfig(total_steps=total, log_every=5, ckpt=ck),
                       telemetry=rt_b2)
    # restore_meta replayed the pre-kill log; continuation appended the
    # rest — identical sequence, incl. the decision at step 10 whose
    # interval straddles the kill (steps 6-8 observed pre-kill, 9-10 post)
    assert rt_b2.cadence_log == want_log
    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state_a.opt_state),
                    jax.tree.leaves(state_b.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_engine_generates():
    cfg, model = tiny_model("qwen2-7b")
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(slots=2, cache_len=64))
    reqs = [Request(uid=i,
                    prompt=np.arange(5 + i, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=6) for i in range(5)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert all(len(r.out_tokens) == 6 for r in out)
    assert eng.waves == 3          # 2 + 2 + 1
    for r in out:
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_deterministic_across_waves():
    cfg, model = tiny_model("qwen2-7b")
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(7, dtype=np.int32) % cfg.vocab
    outs = []
    for _ in range(2):
        eng = Engine(model, params, ServeConfig(slots=2, cache_len=64))
        r = Request(uid=0, prompt=prompt, max_new_tokens=5)
        eng.run([r])
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]
