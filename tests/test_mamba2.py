"""SSD correctness: chunked algorithm vs naive per-step recurrence, and
decode-step vs full-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, SSMSpec
from repro.models import mamba2 as M


def tiny_cfg(chunk=8):
    return ModelConfig(arch="test", family="ssm", n_layers=1, d_model=32,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab=64,
                       ssm=SSMSpec(d_state=16, d_conv=4, expand=2,
                                   head_dim=16, n_groups=1, chunk=chunk))


def naive_ssd(xs, b, c, dt, a):
    """Reference: h_t = h_{t-1} * exp(dt_t a) + dt_t * B_t (x) X_t;
    y_t = C_t . h_t   (state update includes current token)."""
    bt, s, h, p = xs.shape
    n = b.shape[-1]
    hstate = np.zeros((bt, h, n, p), np.float64)
    ys = np.zeros((bt, s, h, p), np.float64)
    xs, b, c, dt = map(lambda t: np.asarray(t, np.float64), (xs, b, c, dt))
    a = np.asarray(a, np.float64)
    for t in range(s):
        dec = np.exp(dt[:, t, :] * a[None, :])                 # (bt, h)
        outer = (dt[:, t, :, None, None] * b[:, t, :, :, None]
                 * xs[:, t, :, None, :])                       # (bt,h,n,p)
        hstate = hstate * dec[:, :, None, None] + outer
        ys[:, t] = np.einsum("bhnp,bhn->bhp", hstate, c[:, t])
    return ys, np.moveaxis(hstate, -1, -2)  # final (bt, h, p, n)


def test_chunked_ssd_matches_naive():
    key = jax.random.PRNGKey(0)
    bt, s, h, p, n = 2, 32, 4, 8, 16
    ks = jax.random.split(key, 4)
    xs = jax.random.normal(ks[0], (bt, s, h, p))
    b = jax.random.normal(ks[1], (bt, s, h, n)) * 0.5
    c = jax.random.normal(ks[2], (bt, s, h, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (bt, s, h)))
    a = -jnp.exp(jnp.linspace(-1.0, 1.0, h))

    for chunk in (8, 16, 32):
        y, hf = M.ssd_chunked(xs, b, c, dt, a, chunk)
        y_ref, hf_ref = naive_ssd(xs, b, c, dt, a)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(hf), hf_ref, rtol=2e-4,
                                   atol=2e-4)


def test_decode_matches_full_forward():
    """Running the full forward over S tokens must agree with S decode
    steps (same params, same inputs)."""
    cfg = tiny_cfg(chunk=4)
    p = M.mamba_init(jax.random.PRNGKey(1), cfg)
    bt, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(2), (bt, s, cfg.d_model),
                          jnp.float32) * 0.5

    full_out, full_cache = M.mamba_apply(
        cfg, p, x, cache=M.init_mamba_cache(bt, cfg, jnp.float32))

    cache = M.init_mamba_cache(bt, cfg, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = M.mamba_decode(cfg, p, x[:, t:t + 1, :], cache)
        outs.append(o)
    dec_out = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(np.asarray(dec_out), np.asarray(full_out),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache.ssm),
                               np.asarray(full_cache.ssm),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache.conv),
                               np.asarray(full_cache.conv),
                               rtol=1e-4, atol=1e-5)


def test_state_is_constant_memory():
    cfg = tiny_cfg()
    cache = M.init_mamba_cache(4, cfg, jnp.bfloat16)
    assert cache.ssm.shape == (4, 4, 16, 16)       # B, H, P, N — no S dim
    assert cache.conv.shape[1] == cfg.ssm.d_conv
