"""Pallas flash-attention kernel: sweeps vs the naive softmax oracle
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models import attention as A


@pytest.fixture(autouse=True)
def force_pallas():
    ops.set_mode("pallas")
    yield
    ops.set_mode("auto")


def _cfg(h, kv, hd):
    return ModelConfig(arch="t", family="dense", n_layers=1, d_model=h * hd,
                       n_heads=h, n_kv_heads=kv, d_ff=64, vocab=64,
                       head_dim=hd)


@pytest.mark.parametrize("h,kv,hd,s,causal", [
    (4, 4, 64, 256, True),
    (8, 2, 64, 256, True),       # GQA broadcast
    (4, 1, 128, 128, False),     # MQA, lane-aligned dh
    (2, 2, 80, 512, True),       # dh needs padding to 128
])
def test_flash_matches_sdpa(h, kv, hd, s, causal):
    cfg = _cfg(h, kv, hd)
    b = 2
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))

    got = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    if causal:
        mask = (jnp.arange(s)[None, None, :] <= jnp.arange(s)[None, :, None])
    else:
        mask = jnp.ones((1, s, s), bool)
    want = A._sdpa(cfg, q, k, v, mask).reshape(b, s, h, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_bf16_inputs():
    cfg = _cfg(4, 4, 64)
    b, s = 1, 128
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (b, s, 4, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, s, 4, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (b, s, 4, 64)).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    mask = (jnp.arange(s)[None, None, :] <= jnp.arange(s)[None, :, None])
    want = A._sdpa(cfg, q, k, v, mask).reshape(b, s, 4, 64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
