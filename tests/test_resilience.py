"""Resilience layer units (repro.resilience + hardened checkpoint I/O).

Guard contracts:
  * the chain-level skip-step wrapper zeroes the update AND reverts the
    whole inner state on a non-finite step — params and every EMA
    (weight decay included) are exactly what they were before the
    poisoned step, only the guard counters advance;
  * wrapping a chain in the guard changes NOTHING on healthy steps
    (bitwise);
  * the per-leaf xi watchdog forces a full refresh on a trip and demotes
    the leaf to the exact dense second moment after ``max_demotions``
    consecutive trips, with the dense EMA advancing from there.

Checkpoint-hardening contracts:
  * ``list_checkpoints`` / ``latest_step`` skip uncommitted,
    manifest-less and size-mismatched step dirs;
  * the deep sha256 verify catches a single flipped payload bit that the
    structural check cannot see, and ``CheckpointManager.restore`` falls
    back to the previous good checkpoint;
  * transient OSErrors are retried with backoff, everything else
    propagates immediately;
  * the preemption handler install is idempotent and the async-save
    error path surfaces on the next ``wait()``.
"""
import dataclasses
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.checkpoint import serialization as SER
from repro.core import (AdapproxConfig, RankConfig, adapprox, adapprox_state,
                        apply_updates, make_optimizer)
from repro.resilience import (FaultPlan, GuardConfig, GuardedState,
                              corrupt_latest_checkpoint, flip_bit,
                              inject_faults, remesh_after_loss,
                              tree_all_finite)
from repro.resilience.guards import guard_updates


def toy_params():
    key = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(key, (64, 48)) * 0.02,
            "b": jnp.zeros((48,))}


def toy_grads(params, t):
    key = jax.random.PRNGKey(7)
    return jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, t * 10 + p.size),
                                    p.shape), params)


# ---------------------------------------------------------------------------
# tree_all_finite
# ---------------------------------------------------------------------------

def test_tree_all_finite():
    ok = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2))}
    assert bool(tree_all_finite(ok))
    assert not bool(tree_all_finite({"a": jnp.array([1.0, jnp.nan])}))
    assert not bool(tree_all_finite({"a": jnp.array([jnp.inf])}))
    # integer leaves cannot be non-finite and must not break the check
    assert bool(tree_all_finite({"i": jnp.arange(3), "f": jnp.ones(2)}))
    assert bool(tree_all_finite({}))


# ---------------------------------------------------------------------------
# chain-level skip-step wrapper
# ---------------------------------------------------------------------------

def test_skip_step_freezes_params_and_state():
    params = toy_params()
    opt = guard_updates(make_optimizer("adamw", lr=1e-2, weight_decay=0.1),
                        GuardConfig())
    state = opt.init(params)
    p = params
    for t in (1, 2):
        upd, state = opt.update(toy_grads(p, t), state, p)
        p = apply_updates(p, upd)
    pre_inner = jax.tree.leaves(state.inner)

    poisoned = jax.tree.map(lambda g: g.at[0].set(jnp.nan), toy_grads(p, 3))
    upd, state = opt.update(poisoned, state, p)
    for leaf in jax.tree.leaves(upd):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)
    # the WHOLE inner state reverted: weight decay, momenta, step counter
    for a, b in zip(pre_inner, jax.tree.leaves(state.inner)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(state.skipped) == 1 and int(state.last_skip) == 3

    # a healthy step proceeds normally afterwards
    upd, state = opt.update(toy_grads(p, 4), state, p)
    assert any(float(np.abs(np.asarray(l)).max()) > 0
               for l in jax.tree.leaves(upd))
    assert int(state.skipped) == 1 and int(state.steps) == 4


def test_guard_is_bitwise_noop_on_healthy_steps():
    params = toy_params()
    bare = make_optimizer("adamw", lr=1e-2, weight_decay=0.1)
    wrapped = guard_updates(make_optimizer("adamw", lr=1e-2,
                                           weight_decay=0.1), GuardConfig())
    sa, sb = bare.init(params), wrapped.init(params)
    p_a = p_b = params
    for t in range(1, 5):
        ua, sa = bare.update(toy_grads(p_a, t), sa, p_a)
        ub, sb = wrapped.update(toy_grads(p_b, t), sb, p_b)
        for la, lb in zip(jax.tree.leaves(ua), jax.tree.leaves(ub)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        p_a, p_b = apply_updates(p_a, ua), apply_updates(p_b, ub)
    assert int(sb.skipped) == 0


def test_guard_init_leaves_do_not_alias():
    # every state leaf must be its own buffer: a shared array across
    # counter fields makes jit with donate_argnums reject the state
    # ("Attempt to donate the same buffer twice") on the sharded path
    opt = guard_updates(make_optimizer("adamw", lr=1e-2), GuardConfig())
    leaves = [l for l in jax.tree.leaves(opt.init(toy_params()))
              if isinstance(l, jax.Array)]
    assert len({id(l) for l in leaves}) == len(leaves)


def test_skip_counters_ride_jit_and_checkpoint_flatten():
    params = toy_params()
    opt = guard_updates(make_optimizer("adamw", lr=1e-2), GuardConfig())
    state = opt.init(params)
    step = jax.jit(opt.update)
    bad = jax.tree.map(lambda g: g * jnp.nan, toy_grads(params, 1))
    _, state = step(bad, state, params)
    assert int(state.skipped) == 1
    # GuardedState is a registered pytree: it flattens for checkpointing
    leaves, treedef = jax.tree.flatten(state)
    rt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rt, GuardedState) and int(rt.skipped) == 1


# ---------------------------------------------------------------------------
# per-leaf xi watchdog: forced refresh -> demotion -> dense EMA
# ---------------------------------------------------------------------------

def guarded_cfg(**kw):
    base = dict(lr=1e-3, min_dim_factor=32, oversample=2, n_iter=2,
                rank=RankConfig(k_init=2, k_max=8, mode="static"),
                guards=GuardConfig(xi_trip=1e-6, max_demotions=2))
    base.update(kw)
    return AdapproxConfig(**base)


def test_xi_trip_forces_refresh_then_demotes():
    params = toy_params()
    opt = adapprox(guarded_cfg())
    state = opt.init(params)
    p = params
    gstates = []
    for t in range(1, 5):
        upd, state = opt.update(toy_grads(p, t), state, p)
        p = apply_updates(p, upd)
        gstates.append(adapprox_state(state).guards)
        assert bool(tree_all_finite(upd)), f"step {t}"
    g1, g2, g3, g4 = gstates
    # rank-2 on a random 64x48 matrix: xi far above the 1e-6 trip line
    assert int(g1.trips[0]) == 1 and int(g1.force_refresh[0]) == 1
    assert int(g1.demoted[0]) == 0
    # second consecutive trip reaches max_demotions: the leaf demotes
    assert int(g2.demoted[0]) == 1 and int(g2.demotions) == 1
    assert int(g2.trip_total) >= 2
    # demoted leaves run the exact dense path: xi pinned to 0, no more
    # trips, and the dense second-moment EMA keeps advancing
    assert int(g3.demoted[0]) == 1 and int(g3.trips[0]) == 0
    dv3, dv4 = np.asarray(g3.dense_v[0]), np.asarray(g4.dense_v[0])
    assert dv3.shape == (64, 48)
    assert not np.array_equal(dv3, dv4)
    assert np.all(dv3 >= 0) and np.all(np.isfinite(dv4))


def test_no_demotion_without_budget():
    params = toy_params()
    cfg = guarded_cfg(guards=GuardConfig(xi_trip=1e-6, max_demotions=0))
    opt = adapprox(cfg)
    state = opt.init(params)
    p = params
    for t in range(1, 4):
        upd, state = opt.update(toy_grads(p, t), state, p)
        p = apply_updates(p, upd)
    g = adapprox_state(state).guards
    # trips keep registering and forcing refreshes, but nothing demotes
    # and no dense shadow buffers were ever allocated
    assert int(g.trip_total) >= 3 and int(g.demotions) == 0
    assert int(g.demoted[0]) == 0 and g.dense_v == ()


# ---------------------------------------------------------------------------
# deterministic gradient injection
# ---------------------------------------------------------------------------

def test_inject_faults_schedule_is_exact():
    plan = FaultPlan(nan_steps=(2,), inf_steps=(3,))
    assert plan.fault_steps == (2, 3)
    inj = inject_faults(plan)
    grads = {"a": jnp.ones((4,))}
    state = inj.init(grads)
    out1, state = inj.update(grads, state)
    np.testing.assert_array_equal(np.asarray(out1["a"]), 1.0)
    out2, state = inj.update(grads, state)
    assert np.all(np.isnan(np.asarray(out2["a"])))
    out3, state = inj.update(grads, state)
    assert np.all(np.isposinf(np.asarray(out3["a"])))
    out4, state = inj.update(grads, state)
    np.testing.assert_array_equal(np.asarray(out4["a"]), 1.0)


# ---------------------------------------------------------------------------
# checkpoint hardening
# ---------------------------------------------------------------------------

def save_tree(directory, step, scale=1.0):
    tree = {"w": np.full((8, 8), scale, np.float32),
            "step": np.asarray(step, np.int32)}
    return SER.save_pytree(tree, directory, step), tree


def test_list_checkpoints_skips_broken_dirs(tmp_path):
    good1, _ = save_tree(tmp_path, 1)
    good2, _ = save_tree(tmp_path, 2)
    # uncommitted dir (kill between mkdir and rename under the old format)
    (tmp_path / "step_000000090").mkdir()
    # committed marker but no manifest
    half = tmp_path / "step_000000091"
    half.mkdir()
    (half / SER.COMMIT_MARKER).touch()
    # committed but a leaf file lost bytes (size mismatch vs manifest)
    trunc, _ = save_tree(tmp_path, 92)
    leaf = trunc / "leaf_00000.npy"
    leaf.write_bytes(leaf.read_bytes()[: leaf.stat().st_size // 2])

    assert SER.list_checkpoints(tmp_path) == [good1, good2]
    assert SER.latest_checkpoint(tmp_path) == good2
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
    assert mgr.latest_step() == 2


def test_deep_verify_catches_bitflip(tmp_path):
    ckpt, tree = save_tree(tmp_path, 5)
    target = ckpt / "leaf_00000.npy"
    flip_bit(str(target), target.stat().st_size - 1, bit=3)
    # sizes intact: the structural check passes, only the hash fails
    assert SER.verify_checkpoint(ckpt)
    assert not SER.verify_checkpoint(ckpt, deep=True)
    with pytest.raises(SER.CheckpointCorruptError):
        SER.restore_pytree(ckpt, tree)
    # verify=False loads whatever bytes are there (debugging escape hatch)
    SER.restore_pytree(ckpt, tree, verify=False)


def test_manager_restore_falls_back_past_corrupt_latest(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                             async_save=False))
    _, tree1 = save_tree(tmp_path, 1, scale=1.0)
    _, tree2 = save_tree(tmp_path, 2, scale=2.0)
    corrupt_latest_checkpoint(str(tmp_path), kind="bitflip")
    restored, step = mgr.restore(like=tree1)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)
    # an explicit step request is a user decision: corruption raises
    with pytest.raises(SER.CheckpointCorruptError):
        mgr.restore(like=tree1, step=2)


def test_manager_restore_raises_when_all_corrupt(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                             async_save=False))
    _, tree = save_tree(tmp_path, 1)
    corrupt_latest_checkpoint(str(tmp_path), kind="bitflip")
    with pytest.raises(SER.CheckpointCorruptError):
        mgr.restore(like=tree)


def test_truncated_latest_is_invisible_even_to_latest_step(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                             async_save=False))
    save_tree(tmp_path, 1)
    save_tree(tmp_path, 2)
    corrupt_latest_checkpoint(str(tmp_path), kind="truncate")
    # the cheap structural size check already hides it — no deep hash paid
    assert mgr.latest_step() == 1


def test_manifest_corruption_hides_checkpoint(tmp_path):
    save_tree(tmp_path, 1)
    save_tree(tmp_path, 2)
    corrupt_latest_checkpoint(str(tmp_path), kind="manifest")
    assert [SER.checkpoint_step(p)
            for p in SER.list_checkpoints(tmp_path)] == [1]


def test_retry_policy(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(
        directory=str(tmp_path), io_retries=2, retry_backoff_s=0.001))
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert mgr._with_retries(flaky, "test") == "ok"
    assert calls["n"] == 3

    calls["n"] = 0

    def always_bad():
        calls["n"] += 1
        raise OSError("down")

    with pytest.raises(OSError):
        mgr._with_retries(always_bad, "test")
    assert calls["n"] == 3          # first attempt + io_retries

    calls["n"] = 0

    def wrong():
        calls["n"] += 1
        raise ValueError("bug")

    # non-OSError is a programming error: no retry
    with pytest.raises(ValueError):
        mgr._with_retries(wrong, "test")
    assert calls["n"] == 1


def test_async_save_error_surfaces_on_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(CheckpointConfig(
        directory=str(tmp_path), async_save=True, io_retries=0))
    monkeypatch.setattr(SER, "save_pytree",
                        lambda *a, **k: (_ for _ in ()).throw(
                            OSError("disk gone")))
    mgr.save({"w": np.zeros(2, np.float32)}, 1)
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.wait()
    # the error is consumed: the manager is usable again afterwards
    mgr.wait()


def test_preemption_handler_install_is_idempotent(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
    before = signal.getsignal(signal.SIGTERM)
    get_state = lambda: ({"w": np.zeros(2, np.float32)}, 0)
    mgr.install_preemption_handler(get_state)
    first = signal.getsignal(signal.SIGTERM)
    assert first is not before
    # double install must NOT chain the handler to itself: prev still
    # points at the handlers from OUTSIDE this manager
    mgr.install_preemption_handler(get_state)
    assert mgr._prev_handlers[signal.SIGTERM] is before
    mgr.uninstall_preemption_handler()
    assert signal.getsignal(signal.SIGTERM) is before
    # uninstall with nothing installed is a no-op
    mgr.uninstall_preemption_handler()
    assert signal.getsignal(signal.SIGTERM) is before


# ---------------------------------------------------------------------------
# device loss -> remesh plan
# ---------------------------------------------------------------------------

def test_remesh_after_loss_plans_for_survivors():
    plan = remesh_after_loss(lost=2, target_model=2, available_devices=8)
    # 6 survivors at TP=2: (data=3, model=2), devices used = 6
    assert plan.model == 2 and plan.devices == 6
    # losing enough devices degrades TP to the largest fitting power of 2
    plan = remesh_after_loss(lost=7, target_model=4, available_devices=8)
    assert plan.model == 1 and plan.devices == 1
    with pytest.raises(ValueError):
        remesh_after_loss(lost=8, available_devices=8)
