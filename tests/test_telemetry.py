"""Telemetry subsystem (repro.telemetry): in-jit snapshot collection,
dynamic refresh cadence (zero recompilation), the JSONL sink + schema,
and the closed-loop refresh controller.

Acceptance pins:
  * telemetry ON changes NOTHING about the update arithmetic — updates
    are bitwise-identical to telemetry OFF for every engineering mode;
  * with ``dynamic_refresh``, a runtime cadence change re-uses the
    compiled executable (jit cache size stays 1) and the refresh/fold
    pattern follows the new cadence;
  * a synthetic xi-drift scenario demonstrably tightens then relaxes
    ``refresh_every`` per group through the hysteresis controller.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.telemetry as T
from repro.config import OptimizerConfig, TelemetryConfig, \
    default_mixed_groups
from repro.core import adapprox_state, apply_updates, build_optimizer
from repro.distributed.straggler import StragglerConfig, StragglerMonitor
from repro.telemetry.controller import ControllerConfig, RefreshController
from repro.telemetry.sink import SinkConfig, TelemetrySink


def toy_params():
    key = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(key, (160, 144)) * 0.02,
        "stack": jax.random.normal(jax.random.fold_in(key, 1),
                                   (2, 96, 80)) * 0.02,
        "b": jnp.zeros((144,)),
    }


def toy_grads(params, t):
    key = jax.random.PRNGKey(42)
    return jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, t * 100 + p.size),
                                    p.shape), params)


def opt_cfg(**over):
    base = dict(name="adapprox", schedule="constant", lr=1e-3,
                weight_decay=0.1, k=8, rank_mode="paper", min_dim_factor=64,
                implicit=False)
    base.update(over)
    return OptimizerConfig(**base)


# ---------------------------------------------------------------------------
# In-jit collection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["default", "refresh_warm", "bucketed",
                                  "fused", "b1_zero"])
def test_updates_bitwise_identical_with_telemetry(mode):
    """Collection must be arithmetic-free: telemetry on == off, bitwise,
    across the engineering modes (clip flags are extra outputs of values
    the update already computes)."""
    over = {
        "default": {},
        "refresh_warm": dict(refresh_every=3, warm_start=True),
        "bucketed": dict(bucketed=True, refresh_every=2),
        "fused": dict(fused_update=True),
        "b1_zero": dict(b1=0.0),
    }[mode]
    params = toy_params()
    a = build_optimizer(opt_cfg(**over))
    b = build_optimizer(opt_cfg(**over, telemetry=True))
    sa, sb = a.init(params), b.init(params)
    p_a = p_b = params
    for t in range(1, 5):
        g = toy_grads(p_a, t)
        ua, sa = a.update(g, sa, p_a)
        ub, sb = b.update(g, sb, p_b)
        for la, lb in zip(jax.tree.leaves(ua), jax.tree.leaves(ub)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=f"{mode} step {t}")
        p_a, p_b = apply_updates(p_a, ua), apply_updates(p_b, ub)


def test_snapshot_contents_and_counters():
    params = toy_params()
    opt = build_optimizer(opt_cfg(refresh_every=3, telemetry=True))
    state = opt.init(params)
    snap0 = adapprox_state(state).telemetry
    # fixed shapes: 2 factored leaves (w + the (2, 96, 80) stack), 3 total
    assert snap0.xi.shape == (2,) and snap0.clip_rate.shape == (3,)
    assert int(snap0.step) == 0
    step = jax.jit(opt.update)
    for t in range(1, 8):
        g = toy_grads(params, t)
        _, state = step(g, state, params)
        snap = adapprox_state(state).telemetry
        assert snap.xi.shape == (2,)          # shape never changes
    # refresh at t = 1, 4, 7 (T = 3)
    assert int(snap.refresh_steps) == 3
    assert int(snap.fold_steps) == 4
    assert int(snap.refresh_steps) + int(snap.fold_steps) == int(snap.step)
    assert float(snap.did_refresh) == 1.0      # step 7 refreshed
    assert int(snap.refresh_every) == 3
    xi = np.asarray(snap.xi)
    assert np.all(xi >= 0) and np.all(xi <= 1)
    assert np.all(np.asarray(snap.k_frac) <= 1.0 + 1e-6)
    clip = np.asarray(snap.clip_rate)
    assert np.all((clip >= 0) & (clip <= 1))
    # leaf index metadata: factored = b-is-first flatten order {b, stack, w}
    assert snap.leaf_indices == (1, 2)
    assert snap.dense_indices == (0,)


def test_snapshot_disabled_leaves_state_unchanged():
    params = toy_params()
    st = build_optimizer(opt_cfg()).init(params)
    sub = adapprox_state(st)
    assert sub.telemetry is None and sub.refresh_every is None
    assert T.named_snapshots(st) == {}
    assert T.telemetry_metrics(st) == {}


def test_telemetry_metrics_aggregates():
    params = toy_params()
    opt = build_optimizer(opt_cfg(telemetry=True))
    state = opt.init(params)
    _, state = opt.update(toy_grads(params, 1), state, params)
    m = T.telemetry_metrics(state)
    assert set(m) == {f"telemetry/default/{k}" for k in
                      ("mean_xi", "max_xi", "mean_k", "mean_k_frac",
                       "clip_rate", "refresh_every", "did_refresh")}
    snap = adapprox_state(state).telemetry
    np.testing.assert_allclose(float(m["telemetry/default/mean_xi"]),
                               float(np.mean(np.asarray(snap.xi))))


# ---------------------------------------------------------------------------
# Dynamic refresh cadence
# ---------------------------------------------------------------------------

def test_dynamic_cadence_changes_do_not_recompile():
    """Acceptance: with --auto-refresh style configs, changing the cadence
    at runtime triggers ZERO recompilations (jit cache stays at 1)."""
    params = toy_params()
    opt = build_optimizer(opt_cfg(refresh_every=2, warm_start=True,
                                  telemetry=True, dynamic_refresh=True))
    state = opt.init(params)
    step = jax.jit(opt.update)
    g = toy_grads(params, 1)
    for _ in range(4):
        _, state = step(g, state, params)
    assert step._cache_size() == 1
    state = T.set_refresh_every(state, {"default": 5})
    for _ in range(6):
        _, state = step(g, state, params)
    assert step._cache_size() == 1, "cadence change recompiled the step"
    state = T.set_refresh_every(state, 3)      # int form: every dyn group
    _, state = step(g, state, params)
    assert step._cache_size() == 1
    assert T.get_refresh_every(state) == {"default": 3}
    # refresh accounting followed the cadence: T=2 over steps 1-4
    # (refresh at 1, 3), T=5 over 5-10 (refresh at 6), T=3 at step 11
    # (11 % 3 = 2 != 1 -> fold)
    snap = T.named_snapshots(state)["default"]
    assert int(snap.refresh_steps) == 3, int(snap.refresh_steps)
    assert int(snap.fold_steps) == 8


def test_dynamic_constant_cadence_matches_static():
    """dynamic_refresh with an untouched cadence reproduces the static
    refresh_every=T path bitwise (same branch arithmetic, traced pred)."""
    params = toy_params()
    a = build_optimizer(opt_cfg(refresh_every=3))
    b = build_optimizer(opt_cfg(refresh_every=3, dynamic_refresh=True))
    sa, sb = a.init(params), b.init(params)
    for t in range(1, 6):
        g = toy_grads(params, t)
        ua, sa = a.update(g, sa, params)
        ub, sb = b.update(g, sb, params)
        for la, lb in zip(jax.tree.leaves(ua), jax.tree.leaves(ub)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=f"step {t}")


def test_set_refresh_every_validates():
    params = toy_params()
    state = build_optimizer(opt_cfg(telemetry=True)).init(params)
    with pytest.raises(ValueError, match="dynamic_refresh"):
        T.set_refresh_every(state, {"default": 2})
    state = build_optimizer(
        opt_cfg(telemetry=True, dynamic_refresh=True)).init(params)
    with pytest.raises(ValueError, match="no Adapprox group"):
        T.set_refresh_every(state, {"nope": 2})
    with pytest.raises(ValueError, match=">= 1"):
        T.set_refresh_every(state, {"default": 0})


def test_partition_groups_named_snapshots():
    params = toy_params()
    opt = build_optimizer(opt_cfg(telemetry=True, dynamic_refresh=True,
                                  groups=default_mixed_groups()))
    state = opt.init(params)
    _, state = opt.update(toy_grads(params, 1), state, params)
    snaps = T.named_snapshots(state)
    assert list(snaps) == ["factored"]         # adamw group carries none
    assert T.get_refresh_every(state) == {"factored": 1}
    m = T.telemetry_metrics(state)
    assert "telemetry/factored/mean_xi" in m


# ---------------------------------------------------------------------------
# Controller
# ---------------------------------------------------------------------------

def test_controller_synthetic_drift_tightens_then_relaxes():
    """Acceptance: a synthetic xi-drift scenario tightens, then relaxes,
    the per-group cadence through the hysteresis band."""
    cfg = ControllerConfig(interval=5, t_min=1, t_max=16, xi_high=0.4,
                           xi_low=0.1, relax_patience=2, tighten_div=2,
                           relax_add=2)
    ctl = RefreshController(cfg)
    t = 8
    changes = []

    def run(steps, xi):
        nonlocal t
        for s in steps:
            c = ctl.observe(s, "g", xi, t)
            if c is not None:
                changes.append((c.step, c.old, c.new))
                t = c.new

    run(range(1, 11), xi=0.8)       # drift: two intervals over xi_high
    assert changes == [(5, 8, 4), (10, 4, 2)]
    run(range(11, 16), xi=0.2)      # dead band: nothing moves
    assert len(changes) == 2
    run(range(16, 41), xi=0.02)     # calm: relaxes only after patience=2
    # patience re-arms after every relax: intervals 20 (calm 1), 25
    # (relax), 30 (calm 1), 35 (relax), 40 (calm 1)
    assert changes[2:] == [(25, 2, 4), (35, 4, 6)]
    # t_min clamp: tightening from 1 is a no-op, not an error
    ctl2 = RefreshController(cfg)
    assert ctl2.observe(5, "g", 0.9, 1) is None or \
        ctl2.observe(5, "g", 0.9, 1).new >= 1


def test_controller_mid_interval_roundtrip_is_deterministic():
    """state_dict/load_state_dict mid-interval: the restored controller
    makes the identical decision sequence as the uninterrupted one."""
    cfg = ControllerConfig(interval=4, xi_high=0.3, xi_low=0.05,
                           relax_patience=1)
    xi_seq = [0.5, 0.4, 0.01, 0.02, 0.6, 0.01, 0.03, 0.01,
              0.02, 0.01, 0.01, 0.02]

    def decisions(ctl, steps, t0):
        t, out = t0, []
        for s in steps:
            c = ctl.observe(s, "g", xi_seq[s - 1], t)
            if c is not None:
                out.append((c.step, c.old, c.new, c.interval_mean_xi))
                t = c.new
        return out, t

    a = RefreshController(cfg)
    want, _ = decisions(a, range(1, 13), 6)

    b = RefreshController(cfg)
    got1, t_mid = decisions(b, range(1, 7), 6)       # killed at step 6
    c = RefreshController(cfg)                       # "restored" process
    c.load_state_dict(json.loads(json.dumps(b.state_dict())))
    got2, _ = decisions(c, range(7, 13), t_mid)
    assert got1 + got2 == want
    assert want, "scenario never moved the cadence — test is vacuous"


# ---------------------------------------------------------------------------
# Sink + schema
# ---------------------------------------------------------------------------

def test_sink_writes_rotates_and_validates(tmp_path):
    sink = TelemetrySink(SinkConfig(directory=str(tmp_path),
                                    rotate_bytes=400))
    for i in range(1, 21):
        sink.emit({"kind": "optimizer", "step": i, "group": "g",
                   "refresh_every": 1, "did_refresh": True,
                   "refresh_steps": i, "fold_steps": 0, "clip_rate": 0.5})
    sink.flush()
    sink.close()
    files = sink.paths()
    assert len(files) > 1, "rotate_bytes=400 should have rotated"
    assert T.validate_dir(tmp_path) == 20
    events = [json.loads(l) for f in files for l in open(f)]
    assert [e["step"] for e in events] == list(range(1, 21))  # ordered


def test_sink_rotation_sequence_is_monotone(tmp_path):
    """A restarted sink resumes PAST the highest existing rotation
    index — not at the file count — so a gap in the sequence (an index
    deleted by log shipping) can never make it overwrite a survivor."""
    (tmp_path / "events-00000.jsonl").write_text(
        '{"kind": "run_meta", "schema": 1, "source": "old-run"}\n')
    (tmp_path / "events-00002.jsonl").write_text(
        '{"kind": "run_meta", "schema": 1, "source": "old-run"}\n')
    sink = TelemetrySink(SinkConfig(directory=str(tmp_path)))
    sink.emit({"kind": "run_meta", "source": "new-run"})
    sink.flush()
    sink.close()
    assert (tmp_path / "events-00003.jsonl").exists()
    # survivors untouched, whole directory still validates in order
    assert "old-run" in (tmp_path / "events-00002.jsonl").read_text()
    assert T.validate_dir(tmp_path) == 3


def test_sink_rejects_malformed_events(tmp_path):
    sink = TelemetrySink(SinkConfig(directory=str(tmp_path)))
    try:
        with pytest.raises(ValueError, match="unknown event kind"):
            sink.emit({"kind": "nope"})
        with pytest.raises(ValueError, match="missing required"):
            sink.emit({"kind": "cadence", "step": 1})
        with pytest.raises(ValueError, match="unknown field"):
            sink.emit({"kind": "run_meta", "source": "x", "extra": 1})
        with pytest.raises(ValueError, match="expected"):
            sink.emit({"kind": "cadence", "step": "one", "group": "g",
                       "old": 1, "new": 2, "interval_mean_xi": 0.1})
    finally:
        sink.close()
    # a hand-corrupted line fails file validation
    p = tmp_path / "events-00099.jsonl"
    p.write_text('{"kind": "run_meta", "schema": 1}\n')
    with pytest.raises(ValueError, match="missing required"):
        T.validate_file(p)


def test_straggler_monitor_emits_to_shared_sink(tmp_path):
    sink = TelemetrySink(SinkConfig(directory=str(tmp_path)))
    mon = StragglerMonitor(StragglerConfig(window=20, min_steps=5,
                                           persist=2, z_thresh=3.0),
                           sink=sink)
    for _ in range(10):
        mon.observe(0.1)
    mon.observe(10.0)                       # flagged
    mon.observe(10.0)                       # flagged + escalated
    sink.close()
    events = [json.loads(l) for f in sink.paths() for l in open(f)]
    kinds = [(e["kind"], e["event"]) for e in events]
    assert ("straggler", "flagged") in kinds
    assert ("straggler", "escalated") in kinds
    assert mon.escalations                   # legacy surface still works
    assert T.validate_dir(tmp_path) == len(events)


# ---------------------------------------------------------------------------
# Runtime end-to-end (optimizer-only; the full train-loop path is covered
# in test_train_integration.py)
# ---------------------------------------------------------------------------

def test_runtime_emits_and_controls(tmp_path):
    params = toy_params()
    opt = build_optimizer(opt_cfg(telemetry=True, dynamic_refresh=True))
    state = opt.init(params)
    step = jax.jit(opt.update)
    rt = T.TelemetryRuntime(TelemetryConfig(
        enabled=True, dir=str(tmp_path), auto_refresh=True, interval=3,
        xi_high=2.0, xi_low=1.9, relax_patience=1, relax_add=3, t_max=7))
    # xi < 1 always => every interval relaxes: 1 -> 4 -> 7 (t_max clamp)
    for t in range(1, 10):
        _, state = step(toy_grads(params, t), state, params)
        state = rt.on_step(t, state)
    rt.close()
    assert step._cache_size() == 1
    assert [(s, o, n) for s, _, o, n in rt.cadence_log] == \
        [(3, 1, 4), (6, 4, 7)]
    assert T.get_refresh_every(state) == {"default": 7}
    assert T.validate_dir(tmp_path) >= 9
    meta = rt.manifest_meta()["telemetry"]
    assert meta["cadence"] == {"default": 7}
    rt2 = T.TelemetryRuntime(TelemetryConfig(enabled=True,
                                             auto_refresh=True))
    rt2.restore_meta({"telemetry": json.loads(json.dumps(meta))})
    assert rt2.cadence_log == rt.cadence_log


def test_runtime_auto_refresh_requires_dynamic_cadence_at_step_one():
    """auto_refresh against an optimizer without dynamic_refresh must fail
    on the FIRST step, not at the first cadence decision interval-steps
    into the run."""
    params = toy_params()
    opt = build_optimizer(opt_cfg(telemetry=True))     # no dynamic_refresh
    state = opt.init(params)
    _, state = opt.update(toy_grads(params, 1), state, params)
    rt = T.TelemetryRuntime(TelemetryConfig(enabled=True,
                                            auto_refresh=True))
    with pytest.raises(ValueError, match="dynamic_refresh=True"):
        rt.on_step(1, state)
    # collection off entirely: snapshots are absent, which must ALSO fail
    # fast rather than silently skipping the controller forever
    state2 = build_optimizer(opt_cfg(dynamic_refresh=True)).init(params)
    rt2 = T.TelemetryRuntime(TelemetryConfig(enabled=True,
                                             auto_refresh=True))
    with pytest.raises(ValueError, match="telemetry=True"):
        rt2.on_step(1, state2)


def test_read_meta_missing_checkpoint_returns_empty(tmp_path):
    """CheckpointManager.read_meta degrades to {} for absent checkpoints
    — both the no-checkpoint-at-all and the pruned/never-saved-step
    cases — per its documented contract."""
    from repro.checkpoint import CheckpointConfig, CheckpointManager
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
    assert mgr.read_meta() == {}
    assert mgr.read_meta(step=500) == {}


def test_telemetry_config_validates():
    with pytest.raises(ValueError, match="emit_every"):
        TelemetryConfig(emit_every=0)
    with pytest.raises(ValueError, match="rotate_bytes"):
        TelemetryConfig(rotate_bytes=0)
    with pytest.raises(ValueError, match="hysteresis"):
        ControllerConfig(xi_low=0.5, xi_high=0.1)


class _QuadraticModel:
    """Minimal model satisfying the train-loop protocol (init + loss)."""

    def init(self, key):
        del key
        return {"w": jnp.ones((4, 4))}

    def loss(self, params, batch):
        del batch
        l = jnp.sum(jnp.square(params["w"])) * 1e-3
        return l, {"loss": l}


@pytest.mark.parametrize("cap,want", [(None, 6), (2, 2), (0, 0)])
def test_history_cap_bounds_metric_history(cap, want):
    """LoopConfig.history_cap keeps the most recent N entries; None is
    the historic unbounded list; 0 means 'no history', not 'unbounded'
    (falsy-check regression)."""
    from repro.data import DataConfig
    from repro.train import LoopConfig, train
    opt = build_optimizer(OptimizerConfig(name="adamw",
                                          schedule="constant", lr=1e-3))
    _, hist = train(_QuadraticModel(), opt,
                    DataConfig(vocab=8, seq_len=4, global_batch=2),
                    LoopConfig(total_steps=6, log_every=1,
                               history_cap=cap))
    assert len(hist) == want
    assert isinstance(hist, list)
    if want:
        assert hist[-1]["step"] == 6       # most recent entries kept


# ---------------------------------------------------------------------------
# Committed bench artifact: collection overhead pin
# ---------------------------------------------------------------------------

def test_bench_telemetry_overhead_within_3pct():
    """The committed BENCH_step_time.json carries the telemetry-on row;
    collection overhead vs the telemetry-off row is pinned <= 3% wall."""
    import pathlib
    p = pathlib.Path(__file__).parent.parent / "BENCH_step_time.json"
    data = json.loads(p.read_text())
    by_name = {r["name"]: r["ms_per_step"] for r in data["results"]}
    assert "adapprox_refresh5_warm1_telemetry" in by_name
    ratio = (by_name["adapprox_refresh5_warm1_telemetry"]
             / by_name["adapprox_refresh5_warm1"])
    assert ratio <= 1.03, f"telemetry overhead {ratio:.3f}x > 1.03x"
