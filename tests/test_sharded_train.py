"""Sharded training path on 8 virtual CPU devices (subprocess — needs its
own XLA device count): mesh-jitted train step with the mixed three-family
partition chain (count-min sketch on the token embedding, Adapprox on
matrices, dense Adam on the rest), live opt-state NamedShardings, and the
checkpoint resharding round trip.

Contracts pinned down (see the scripts for the assertions):

  * resharding is LOSSLESS: a checkpoint saved on a (4, 2) mesh restores
    bitwise-identically onto (2, 4), (8,) and a single device —
    ``PartitionState`` static labels and mid-``refresh_every`` factored
    state included;
  * same-mesh restart is bitwise-deterministic: save at step 3 of 5
    (mid-refresh-interval), restore on the same mesh, continue — losses
    and final params equal the uninterrupted run exactly;
  * checkpoint restore is equivalent to live resharding: a single-device
    continuation from the checkpoint matches a single-device continuation
    from the directly re-placed live state bitwise (serialization adds no
    error beyond placement);
  * continuation across DIFFERENT meshes matches to float-reassociation
    tolerance (GSPMD partitions matmul/grad reductions differently per
    mesh, so cross-mesh equality is ~1e-3 relative, not bitwise — the
    bitwise claims above are exactly the ones partitioning cannot touch).
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

COMMON = r"""
import os, shutil, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.config import OptimizerConfig, default_mixed_groups
from repro.core import build_optimizer
from repro.models import build_model
from repro.data import DataConfig
from repro.train import LoopConfig, train
from repro.distributed import sharding as SH
from repro.checkpoint import CheckpointConfig, CheckpointManager

VOCAB, SEQ, BATCH = 128, 32, 8

def make_opt():
    # refresh_every=2 so the step-3 checkpoint lands MID-interval: step 4
    # folds under the frozen basis, step 5 refreshes — the continuation
    # only stays exact if the factored state and step counter round-trip.
    # embedding_min_rows=64 puts the VOCAB=128 token embedding under the
    # count-min sketch, so all THREE state families ride the round trip
    # (the 32-row position embedding stays factored).
    return build_optimizer(OptimizerConfig(
        name="adapprox", schedule="constant", lr=1e-3, weight_decay=0.1,
        decay_mask="no_1d", min_dim_factor=32, k=4, rank_mode="static",
        implicit=False, refresh_every=2, groups=default_mixed_groups(),
        embedding_min_rows=64, sketch_width=256, sketch_depth=2))

def setup(mesh_spec):
    cfg = get_smoke_config("gpt2-117m", vocab=VOCAB, max_seq_len=SEQ)
    mesh = None
    if mesh_spec:
        axes = {1: ("data",), 2: ("data", "model")}[len(mesh_spec)]
        mesh = jax.make_mesh(mesh_spec, axes)
    model = build_model(cfg, mesh)
    opt = make_opt()
    ssh = bsh = None
    if mesh is not None:
        model.constrain = SH.make_act_constrainer(mesh, "train")
        bstruct = {"tokens": jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32)}
        ssh, bsh = SH.train_shardings(model, opt, mesh, bstruct)
    return model, opt, ssh, bsh

def run(mesh_spec, total, ckpt_dir=None, state=None):
    model, opt, ssh, bsh = setup(mesh_spec)
    ck = CheckpointConfig(directory=ckpt_dir, save_every=10**9,
                          async_save=False) if ckpt_dir else None
    st, hist = train(model, opt,
                     DataConfig(vocab=VOCAB, seq_len=SEQ, global_batch=BATCH),
                     LoopConfig(total_steps=total, log_every=1, ckpt=ck),
                     state=state, state_shardings=ssh, batch_shardings=bsh)
    return st, [h["loss"] for h in hist]

def leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
"""

ROUNDTRIP = COMMON + r"""
base = tempfile.mkdtemp()

# --- uninterrupted sharded reference: 5 steps on (4, 2) -------------------
state5, l5 = run((4, 2), 5)

# the bitwise claims below cover all three families: the token embedding
# really is under the count-min sketch
from repro.core.sketch import SketchLeaf, sketch_state
sk = sketch_state(state5.opt_state)
assert any(isinstance(l, SketchLeaf) for l in sk.leaves), sk.leaves
print("SKETCH_FAMILY_PRESENT_OK")

# --- 3 steps on (4, 2), blocking save (mid-refresh-interval) --------------
d0 = os.path.join(base, "save42"); os.makedirs(d0)
state3, l3 = run((4, 2), 3, ckpt_dir=d0)
assert l3 == l5[:3], (l3, l5)

# --- resharding is lossless: restore bitwise on every target mesh ---------
restored = {}
for tag, mesh_spec in [("24", (2, 4)), ("8", (8,)), ("1", None)]:
    model, opt, ssh, _ = setup(mesh_spec)
    mgr = CheckpointManager(CheckpointConfig(directory=d0))
    like = jax.tree.map(np.asarray, state3)     # host template
    st, step = mgr.restore(like, ssh)
    assert step == 3, step
    assert leaves_equal(st, state3), f"restore on {tag} not bitwise"
    restored[tag] = st
print("RESTORE_BITWISE_OK")

# spot-check the resharded placement really is sharded on (2, 4)
st24 = restored["24"]
specs = {tuple(l.sharding.spec) for l in jax.tree.leaves(st24.params)
         if hasattr(l, "sharding") and l.ndim >= 2}
assert any(any(ax is not None for ax in s) for s in specs), specs
print("RESHARD_PLACED_OK")

# --- same-mesh restart is bitwise-deterministic ---------------------------
d1 = os.path.join(base, "cont42"); shutil.copytree(d0, d1)
state5b, l45 = run((4, 2), 5, ckpt_dir=d1)
assert l45 == l5[3:], (l45, l5[3:])
assert leaves_equal(state5b.params, state5.params), "same-mesh params diverged"
print("SAME_MESH_BITWISE_OK")

# --- checkpoint restore == live resharding (single-device continuation) ---
live1 = jax.device_put(jax.tree.map(np.asarray, state3), None)
_, l_live = run(None, 5, state=live1)
d2 = os.path.join(base, "cont1"); shutil.copytree(d0, d2)
_, l_ckpt = run(None, 5, ckpt_dir=d2)
assert l_ckpt == l_live, (l_ckpt, l_live)
print("CKPT_EQ_LIVE_OK")

# --- cross-mesh continuation: fp-reassociation tolerance only ------------
for tag, mesh_spec in [("24", (2, 4)), ("8", (8,))]:
    d = os.path.join(base, "cont" + tag); shutil.copytree(d0, d)
    _, lc = run(mesh_spec, 5, ckpt_dir=d)
    np.testing.assert_allclose(lc, l5[3:], rtol=1e-3, atol=0,
                               err_msg=f"cross-mesh {tag}")
    np.testing.assert_allclose(lc, l_ckpt, rtol=1e-3, atol=0)
print("CROSS_MESH_TOL_OK")
print("ROUNDTRIP_OK")
"""

TELEMETRY = COMMON + r"""
import repro.telemetry as T
from jax.sharding import NamedSharding

def make_opt():          # override COMMON's: telemetry + dynamic cadence
    return build_optimizer(OptimizerConfig(
        name="adapprox", schedule="constant", lr=1e-3, weight_decay=0.1,
        decay_mask="no_1d", min_dim_factor=32, k=4, rank_mode="static",
        implicit=False, refresh_every=2, telemetry=True,
        dynamic_refresh=True, groups=default_mixed_groups()))

base = tempfile.mkdtemp()
d0 = os.path.join(base, "tel42"); os.makedirs(d0)
state3, l3 = run((4, 2), 3, ckpt_dir=d0)

# --- 8-virtual-device snapshot replication: every telemetry leaf (and
# the traced cadence scalar) is a REPLICATED NamedSharding on the mesh
snaps = T.named_snapshots(state3.opt_state)
assert list(snaps) == ["factored"], list(snaps)
for leaf in jax.tree.leaves(snaps["factored"]):
    assert isinstance(leaf.sharding, NamedSharding), leaf.sharding
    assert leaf.sharding.is_fully_replicated, leaf.sharding
re = T.named_states(state3.opt_state)["factored"].refresh_every
assert isinstance(re.sharding, NamedSharding) and \
    re.sharding.is_fully_replicated
assert T.get_refresh_every(state3.opt_state) == {"factored": 2}
snap = snaps["factored"]
assert int(snap.refresh_steps) == 2 and int(snap.fold_steps) == 1, \
    (int(snap.refresh_steps), int(snap.fold_steps))   # refresh at 1, 3
print("SNAPSHOT_REPLICATED_OK")

# --- sharding-spec round trip: train_shardings derives telemetry specs
# through the state_sharding_spec protocol (replicated), for a DIFFERENT
# target mesh
model, opt, ssh, _ = setup((2, 4))
sh_snaps = T.named_snapshots(ssh.opt_state)
assert list(sh_snaps) == ["factored"]
for sh in jax.tree.leaves(sh_snaps["factored"]):
    assert isinstance(sh, NamedSharding) and sh.is_fully_replicated, sh
print("SPEC_ROUNDTRIP_OK")

# --- resharded restore is bitwise, telemetry counters + cadence included
mgr = CheckpointManager(CheckpointConfig(directory=d0))
like = jax.tree.map(np.asarray, state3)
st, step = mgr.restore(like, ssh)
assert step == 3
assert leaves_equal(st, state3), "telemetry state not bitwise on (2,4)"
assert T.get_refresh_every(st.opt_state) == {"factored": 2}
print("TELEMETRY_RESTORE_OK")

# --- runtime cadence change on the live sharded state lands replicated
# and the continuation runs under the new cadence
new_opt = T.set_refresh_every(st.opt_state, {"factored": 3})
re2 = T.named_states(new_opt)["factored"].refresh_every
assert isinstance(re2.sharding, NamedSharding) and \
    re2.sharding.is_fully_replicated
import dataclasses as _dc
st5, l45 = run((2, 4), 5, state=_dc.replace(st, opt_state=new_opt))
assert T.get_refresh_every(st5.opt_state) == {"factored": 3}
snap5 = T.named_snapshots(st5.opt_state)["factored"]
# steps 4, 5 under T=3: 4 % 3 = 1 -> refresh, 5 % 3 = 2 -> fold
assert int(snap5.refresh_steps) == 3 and int(snap5.fold_steps) == 2, \
    (int(snap5.refresh_steps), int(snap5.fold_steps))
print("TELEMETRY_CONT_OK")
"""

LAUNCHER = r"""
import os
os.environ["REPRO_TRAIN_DEVICES"] = "8"
from repro.launch import train as LT
import jax, numpy as np
from jax.sharding import NamedSharding
from repro.core import PartitionState, adapprox_state
from repro.core.adamw import AdamWState
from repro.core import factored as F

state = LT.main(["--smoke", "--steps", "2", "--log-every", "1",
                 "--batch", "8", "--seq", "32",
                 "--mesh", "4,2", "--mixed-groups",
                 "--embedding-min-rows", "256", "--sketch-width", "256",
                 "--sketch-depth", "2"])

# partition state with static labels survived the mesh-jitted step; the
# 512-row smoke vocab clears --embedding-min-rows 256, so the token
# embedding rides the sketch group
pstate = state.opt_state
assert isinstance(pstate, PartitionState), type(pstate)
assert set(pstate.inner) == {"dense", "embeddings", "factored"}, \
    pstate.inner.keys()
assert set(pstate.labels) == {"dense", "embeddings", "factored"}

# every live opt-state leaf carries a NamedSharding from the mesh jit
for leaf in jax.tree.leaves(state.opt_state):
    assert isinstance(leaf.sharding, NamedSharding), leaf.sharding
print("OPT_STATE_NAMED_SHARDINGS_OK")

# matrices ride the factored Adapprox group (sharded q/u factors), 1-D
# leaves the dense Adam group
ad = adapprox_state(pstate.inner["factored"])
fls = [l for l in ad.leaves if isinstance(l, F.FactoredLeaf)]
assert fls, "no factored leaves under the adapprox group"
assert any(any(ax is not None for ax in l.q.sharding.spec) for l in fls), \
    "no factored q factor is actually sharded"
adam = [s for s in pstate.inner["dense"] if isinstance(s, AdamWState)]
assert adam and all(x.ndim <= 1 or min(x.shape[-2:]) < 64
                    for x in jax.tree.leaves(adam[0].m)), \
    "dense Adam group should hold only 1-D/small leaves"

# the embeddings group holds the sketched token embedding: the hashed
# table replaces the row axis, the exact first moment shards with FSDP
from repro.core.sketch import SketchLeaf, sketch_state
sk = sketch_state(pstate.inner["embeddings"])
sls = [l for l in sk.leaves if isinstance(l, SketchLeaf)]
assert sls, "no sketched leaves under the embeddings group"
assert all(l.table.shape[:2] == (2, 256) for l in sls), \
    [l.table.shape for l in sls]
assert any(any(ax is not None for ax in l.m.sharding.spec) for l in sls), \
    "no sketch first moment is actually sharded"
print("SKETCH_GROUP_SHARDED_OK")
# params sharded too (FSDP default on)
assert any(any(ax is not None for ax in l.sharding.spec)
           for l in jax.tree.leaves(state.params) if l.ndim >= 2)
print("LAUNCHER_MESH_OK")
"""


def _run(script: str, name: str, timeout=1800):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, \
        f"{name} failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_resharding_round_trip():
    out = _run(ROUNDTRIP, "resharding round trip")
    for marker in ("SKETCH_FAMILY_PRESENT_OK",
                   "RESTORE_BITWISE_OK", "RESHARD_PLACED_OK",
                   "SAME_MESH_BITWISE_OK", "CKPT_EQ_LIVE_OK",
                   "CROSS_MESH_TOL_OK", "ROUNDTRIP_OK"):
        assert marker in out, out


def test_launcher_mesh_smoke():
    out = _run(LAUNCHER, "launcher mesh smoke")
    assert "OPT_STATE_NAMED_SHARDINGS_OK" in out, out
    assert "SKETCH_GROUP_SHARDED_OK" in out, out
    assert "LAUNCHER_MESH_OK" in out, out


def test_telemetry_sharded_snapshot():
    """8 virtual devices: telemetry snapshot + dynamic cadence leaves are
    replicated on the mesh, their sharding specs round-trip through the
    state_sharding_spec protocol for other meshes, resharded restore is
    bitwise (counters + cadence included), and a live cadence change on
    the sharded state stays replicated through continuation."""
    out = _run(TELEMETRY, "telemetry sharded snapshot")
    for marker in ("SNAPSHOT_REPLICATED_OK", "SPEC_ROUNDTRIP_OK",
                   "TELEMETRY_RESTORE_OK", "TELEMETRY_CONT_OK"):
        assert marker in out, out
