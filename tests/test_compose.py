"""Composable optimizer API v2: primitives, partition, build_optimizer,
and the state_sharding_spec protocol.

The parity test reimplements the pre-refactor (seed) monolithic Adapprox
update inline — same math, same order, same PRNG folding — and checks the
chained optimizer reproduces it bit-for-bit on the paper-faithful default
config.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import OptimizerConfig
from repro.core import (AdamWConfig, AdapproxConfig, RankConfig, adamw,
                        adapprox, adapprox_state, apply_updates,
                        build_optimizer, chain, clip_update_rms,
                        make_optimizer, mask_nd, partition, scale,
                        scale_by_adam, scale_by_schedule)
from repro.core import rank as R
from repro.core import srsi as S
from repro.distributed import sharding as SH


def toy_params():
    key = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(key, (160, 144)) * 0.02,
        "b": jnp.zeros((144,)),
    }


def toy_grads(key, params, t):
    return jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, t * 100 + p.size),
                                    p.shape), params)


# ---------------------------------------------------------------------------
# Seed-parity oracle: the pre-refactor monolithic Adapprox update
# ---------------------------------------------------------------------------

def seed_adapprox_step(cfg: AdapproxConfig, grads, params, opt_key, t,
                       q, u, k, m1w, m1b, vb):
    """One step of the seed implementation for {b: 1-D dense, w: 2-D
    factored} params (flatten order: b then w), transcribed from the
    pre-refactor adapprox.py."""
    lr = cfg.lr
    step_key = jax.random.fold_in(opt_key, t)

    # leaf 0: dense "b"
    g32 = grads["b"].astype(jnp.float32)
    vb = cfg.b2 * vb + (1.0 - cfg.b2) * jnp.square(g32)
    u_hat = g32 / (jnp.sqrt(vb) + cfg.eps)
    u_hat = u_hat / jnp.maximum(
        1.0, jnp.sqrt(jnp.mean(jnp.square(u_hat)) + 1e-30) / cfg.clip_d)
    m1b = cfg.b1 * m1b + (1.0 - cfg.b1) * u_hat
    delta_b = -(lr * (m1b + cfg.weight_decay
                      * params["b"].astype(jnp.float32)))

    # leaf 1: factored "w"
    leaf_key = jax.random.fold_in(step_key, 1)
    r_store = q.shape[-1]
    p_eff = max(0, min(cfg.oversample,
                       min(params["w"].shape) - r_store))
    k_max_leaf = R.resolve_k_max(params["w"].shape, cfg.rank, cfg.k_max_frac)
    g32 = grads["w"].astype(jnp.float32)
    v_op = S.make_implicit_v(q, u, g32, cfg.b2)
    vmat = v_op.materialize()
    res = S.srsi_dense(vmat, r_store, p_eff, cfg.n_iter, leaf_key)
    k = R.select_rank(res.cum_energy, res.frob_sq, cfg.rank, k_max_leaf,
                      jnp.asarray(t, jnp.int32), jnp.minimum(k, k_max_leaf))
    mask = S.col_mask(r_store, k)
    q, u = res.q * mask[None, :], res.u * mask[None, :]
    u_hat = g32 / (jnp.sqrt(vmat) + cfg.eps)
    u_hat = u_hat / jnp.maximum(
        1.0, jnp.sqrt(jnp.mean(jnp.square(u_hat)) + 1e-30) / cfg.clip_d)
    m1w = cfg.b1 * m1w + (1.0 - cfg.b1) * u_hat
    delta_w = -(lr * (m1w + cfg.weight_decay
                      * params["w"].astype(jnp.float32)))

    return {"b": delta_b, "w": delta_w}, (q, u, k, m1w, m1b, vb)


def test_chained_adapprox_matches_seed_monolith():
    """Acceptance: the chain reproduces the seed implementation's updates
    bit-for-bit on the paper-faithful default config (+ weight decay)."""
    cfg = AdapproxConfig(weight_decay=0.1)       # paper defaults otherwise
    params = toy_params()
    opt = adapprox(cfg)
    state = opt.init(params)
    st = adapprox_state(state)
    # oracle state mirrors the seed init
    q, u = st.leaves[1].q, st.leaves[1].u
    k = st.leaves[1].k
    m1w = jnp.zeros_like(params["w"])
    m1b = jnp.zeros_like(params["b"])
    vb = jnp.zeros_like(params["b"])
    opt_key = jax.random.PRNGKey(cfg.seed)

    upd_fn = opt.update        # eager: op-for-op comparison vs the oracle
    gkey = jax.random.PRNGKey(42)
    p = params
    for t in range(1, 4):
        g = toy_grads(gkey, p, t)
        want, (q, u, k, m1w, m1b, vb) = seed_adapprox_step(
            cfg, g, p, opt_key, t, q, u, k, m1w, m1b, vb)
        got, state = upd_fn(g, state, p)
        for name in ("b", "w"):
            np.testing.assert_array_equal(np.asarray(got[name]),
                                          np.asarray(want[name]),
                                          err_msg=f"leaf {name} step {t}")
        p = apply_updates(p, got)
    # chain state tracks the oracle's factor state too
    st = adapprox_state(state)
    np.testing.assert_array_equal(np.asarray(st.leaves[1].q), np.asarray(q))
    assert int(st.leaves[1].k) == int(k)


def test_telemetry_disabled_chain_is_unchanged():
    """Acceptance (PR 5): with TelemetryConfig disabled — the default —
    the adapprox chain is bitwise-identical to the pre-telemetry chain:
    the state pytree carries no telemetry fields (treedef unchanged, so
    old checkpoints restore), and enabling collection changes ONLY the
    state, never the updates."""
    params = toy_params()
    cfg = OptimizerConfig(name="adapprox", schedule="constant", lr=1e-3,
                          weight_decay=0.1, k=4, rank_mode="static",
                          min_dim_factor=64, implicit=False)
    off = build_optimizer(cfg)
    on = build_optimizer(dataclasses.replace(cfg, telemetry=True,
                                             dynamic_refresh=True))
    s_off, s_on = off.init(params), on.init(params)
    sub = adapprox_state(s_off)
    assert sub.telemetry is None and sub.refresh_every is None
    # the default state flattens to exactly the pre-telemetry leaves
    # (None fields are empty pytrees: no extra leaves, no treedef change
    # for checkpoint round-trips)
    assert (len(jax.tree.leaves(s_off))
            == len(jax.tree.leaves(s_on))
            - len(jax.tree.leaves(adapprox_state(s_on).telemetry)) - 1)
    gkey = jax.random.PRNGKey(3)
    p = params
    for t in range(1, 4):
        g = toy_grads(gkey, p, t)
        u_off, s_off = off.update(g, s_off, p)
        u_on, s_on = on.update(g, s_on, p)
        for a, b in zip(jax.tree.leaves(u_off), jax.tree.leaves(u_on)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"step {t}")
        p = apply_updates(p, u_off)


def test_guards_disabled_chain_is_unchanged():
    """Acceptance (PR 7): with guards off — the default — the chain is
    bitwise-identical to the pre-resilience chain and the state pytree
    carries no guard leaves (treedef unchanged, old checkpoints restore).
    With guards ON but never tripping (xi_trip above any observable xi,
    no demotion budget), the updates are STILL bitwise identical — the
    watchdog only reads values the update already computes until it has
    to act."""
    params = toy_params()
    cfg = OptimizerConfig(name="adapprox", schedule="constant", lr=1e-3,
                          weight_decay=0.1, k=4, rank_mode="static",
                          min_dim_factor=64, implicit=False,
                          refresh_every=2)
    off = build_optimizer(cfg)
    on = build_optimizer(dataclasses.replace(
        cfg, guards=True, guard_xi_trip=10.0, max_demotions=0))
    s_off = off.init(params)
    assert adapprox_state(s_off).guards is None
    s_on = on.init(params)
    # guards=True wraps the chain: the inner state is one level down
    gkey = jax.random.PRNGKey(5)
    p_off = p_on = params
    for t in range(1, 5):
        g = toy_grads(gkey, p_off, t)
        u_off, s_off = off.update(g, s_off, p_off)
        u_on, s_on = on.update(g, s_on, p_on)
        for a, b in zip(jax.tree.leaves(u_off), jax.tree.leaves(u_on)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"step {t}")
        p_off = apply_updates(p_off, u_off)
        p_on = apply_updates(p_on, u_on)
    assert int(s_on.skipped) == 0
    assert int(adapprox_state(s_on.inner).guards.trip_total) == 0


def test_build_optimizer_matches_make_optimizer():
    """build_optimizer(OptimizerConfig) and the kwargs registry produce
    step-for-step identical updates for every family."""
    params = toy_params()
    gkey = jax.random.PRNGKey(7)
    cases = [
        (OptimizerConfig(name="adapprox", schedule="constant", lr=1e-3,
                         weight_decay=0.1, k=4, rank_mode="static",
                         min_dim_factor=64, implicit=False),
         ("adapprox", dict(lr=1e-3, weight_decay=0.1, k_init=4,
                           mode="static", min_dim_factor=64))),
        (OptimizerConfig(name="adamw", schedule="constant", lr=1e-3,
                         weight_decay=0.1),
         ("adamw", dict(lr=1e-3, weight_decay=0.1))),
        (OptimizerConfig(name="adafactor", schedule="constant", lr=1e-3,
                         weight_decay=0.1, b1=0.9, min_dim_factor=64),
         ("adafactor", dict(lr=1e-3, weight_decay=0.1, b1=0.9,
                            min_dim_factor=64))),
        (OptimizerConfig(name="came", schedule="constant", lr=1e-3,
                         weight_decay=0.1, min_dim_factor=64),
         ("came", dict(lr=1e-3, weight_decay=0.1, min_dim_factor=64))),
    ]
    for ocfg, (name, kw) in cases:
        a, b = build_optimizer(ocfg), make_optimizer(name, **kw)
        sa, sb = a.init(params), b.init(params)
        p_a = p_b = params
        for t in range(3):
            g = toy_grads(gkey, p_a, t)
            ua, sa = a.update(g, sa, p_a)
            ub, sb = b.update(g, sb, p_b)
            for leaf_a, leaf_b in zip(jax.tree.leaves(ua),
                                      jax.tree.leaves(ub)):
                np.testing.assert_array_equal(np.asarray(leaf_a),
                                              np.asarray(leaf_b),
                                              err_msg=f"{name} step {t}")
            p_a, p_b = apply_updates(p_a, ua), apply_updates(p_b, ub)


# ---------------------------------------------------------------------------
# partition
# ---------------------------------------------------------------------------

def _by_ndim(params):
    return jax.tree.map(
        lambda p: "factored" if p.ndim >= 2 else "dense", params)


def test_partition_routes_leaves_by_label_and_jits():
    params = toy_params()
    acfg = AdapproxConfig(rank=RankConfig(k_init=4, mode="static"),
                          min_dim_factor=64)
    sub_f = adapprox(acfg)
    sub_d = adamw(AdamWConfig(lr=1e-3))
    opt = partition(_by_ndim, {"factored": sub_f, "dense": sub_d})

    state = opt.init(params)
    g = toy_grads(jax.random.PRNGKey(1), params, 0)
    # jit round-trip: the PartitionState (with its static labels) must be a
    # valid jit argument and feed straight back in
    jupd, jstate2 = jax.jit(opt.update)(g, state, params)
    jax.jit(opt.update)(g, jstate2, params)
    assert jupd["w"].shape == params["w"].shape
    assert jupd["b"].shape == params["b"].shape
    upd, state2 = opt.update(g, state, params)

    # each group's update equals the sub-transform run on its leaves alone
    gf = {"w": g["w"], "b": None}
    gp = {"w": params["w"], "b": None}
    uf, _ = sub_f.update(gf, sub_f.init(gp), gp)
    np.testing.assert_array_equal(np.asarray(upd["w"]),
                                  np.asarray(uf["w"]))
    gd = {"w": None, "b": g["b"]}
    pd = {"w": None, "b": params["b"]}
    ud, _ = sub_d.update(gd, sub_d.init(pd), pd)
    np.testing.assert_array_equal(np.asarray(upd["b"]),
                                  np.asarray(ud["b"]))


def test_partition_unknown_label_raises():
    params = toy_params()
    opt = partition(lambda p: jax.tree.map(lambda _: "nope", p),
                    {"known": adamw(AdamWConfig())})
    with pytest.raises(ValueError, match="nope"):
        opt.init(params)


# ---------------------------------------------------------------------------
# decay mask
# ---------------------------------------------------------------------------

def test_decay_mask_excludes_1d_params():
    """decay_mask='no_1d': with zero grads, 2-D leaves shrink by
    lr*wd*W and 1-D leaves do not move at all."""
    params = {"w": jnp.full((8, 4), 2.0), "b": jnp.full((4,), 2.0)}
    zeros = jax.tree.map(jnp.zeros_like, params)
    opt = build_optimizer(OptimizerConfig(
        name="adamw", schedule="constant", lr=0.5, weight_decay=0.1,
        decay_mask="no_1d"))
    upd, _ = opt.update(zeros, opt.init(params), params)
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.1, atol=1e-7)
    np.testing.assert_allclose(np.asarray(upd["b"]), 0.0, atol=1e-7)

    # mask_nd is reusable standalone
    m = mask_nd(2)(params)
    assert m["w"] is True and m["b"] is False


def test_clip_update_rms_primitive():
    t = clip_update_rms(1.0)
    u = {"x": jnp.full((4, 4), 10.0)}
    out, _ = t.update(u, t.init(u), u)
    np.testing.assert_allclose(
        float(jnp.sqrt(jnp.mean(jnp.square(out["x"])))), 1.0, rtol=1e-5)


def test_custom_chain_scale_by_adam_schedule():
    """Primitives compose into a hand-rolled optimizer with a runtime LR
    schedule; step t=1 uses schedule(1)."""
    sched = lambda t: 0.1 / t.astype(jnp.float32)
    opt = chain(scale_by_adam(0.9, 0.999, 1e-8), scale_by_schedule(sched),
                scale(-1.0))
    params = {"x": jnp.ones((4,))}
    g = {"x": jnp.ones((4,))}
    st = opt.init(params)
    upd, st = opt.update(g, st, params)
    # Adam first-step direction is ~1 elementwise; lr(1) = 0.1
    np.testing.assert_allclose(np.asarray(upd["x"]), -0.1, rtol=1e-3)
    upd, st = opt.update(g, st, params)
    np.testing.assert_allclose(np.asarray(upd["x"]), -0.05, rtol=1e-3)


# ---------------------------------------------------------------------------
# state_sharding_spec protocol
# ---------------------------------------------------------------------------

def _mesh_1x1():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_opt_state_shardings_via_protocol_adapprox():
    mesh = _mesh_1x1()
    params = toy_params()
    opt = make_optimizer("adapprox", k_init=4, mode="static",
                         min_dim_factor=64)
    state_struct = jax.eval_shape(opt.init, params)
    pspecs = {"w": P("data", "model"), "b": P("model")}
    sh = SH.opt_state_shardings(opt, state_struct, pspecs, mesh)
    # same pytree structure as the state
    assert (jax.tree.structure(jax.tree.map(lambda _: 0, sh))
            == jax.tree.structure(jax.tree.map(lambda _: 0, state_struct)))
    st_sh = adapprox_state(sh)
    # factored leaf (flatten order: b=0, w=1): Q rows follow the param's
    # row axis, U rows the column axis, factor dim replicated
    assert st_sh.leaves[1].q.spec == P("data", None)
    assert st_sh.leaves[1].u.spec == P("model", None)
    assert st_sh.leaves[1].m1.spec == P("data", "model")
    assert st_sh.leaves[0].v.spec == P("model")
    assert st_sh.step.spec == P()


def test_opt_state_shardings_via_protocol_adamw():
    mesh = _mesh_1x1()
    params = toy_params()
    opt = make_optimizer("adamw")
    state_struct = jax.eval_shape(opt.init, params)
    pspecs = {"w": P("data", "model"), "b": P(None)}
    sh = SH.opt_state_shardings(opt, state_struct, pspecs, mesh)
    adam = sh[0]                       # chain stage 0: scale_by_adam
    assert adam.m["w"].spec == P("data", "model")
    assert adam.v["b"].spec == P(None)
    assert adam.step.spec == P()


def test_sharding_module_has_no_optimizer_isinstance():
    """Acceptance: distributed/sharding.py derives optimizer-state
    shardings purely through the protocol — no optimizer state classes."""
    import inspect
    src = inspect.getsource(SH)
    for name in ("AdapproxState", "AdamWState", "FactoredLeaf", "DenseLeaf"):
        assert name not in src, f"sharding.py still references {name}"
