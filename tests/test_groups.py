"""OptimizerConfig.groups lowering, per-group LR multipliers, preemption
handler chaining, and the sharded memory accounting (all single-device)."""
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GroupSpec, OptimizerConfig, default_mixed_groups
from repro.core import (CountState, PartitionState, build_optimizer,
                        scale_by_schedule)
from repro.core.adamw import AdamWState
from repro.core.adapprox import AdapproxState, adapprox_state
from repro.core import factored as F


def _params():
    return {"w": jnp.full((64, 96), 0.5), "b": jnp.full((64,), 0.5),
            "tiny": jnp.full((8, 8), 0.5)}


def _grads(params):
    return jax.tree.map(lambda p: jnp.full_like(p, 0.1), params)


BASE = dict(schedule="constant", lr=1e-3, weight_decay=0.0,
            min_dim_factor=32, k=4, rank_mode="static", implicit=False)


# ---------------------------------------------------------------------------
# groups lowering
# ---------------------------------------------------------------------------

def test_mixed_groups_routes_by_shape():
    """The production default: matrices >= min_dim_factor under Adapprox
    (factored), 1-D and small leaves under dense bias-corrected Adam."""
    opt = build_optimizer(OptimizerConfig(
        name="adapprox", groups=default_mixed_groups(), **BASE))
    params = _params()
    state = opt.init(params)
    # chain state -> (partition,) is not wrapped: partition IS the top level
    assert isinstance(state, PartitionState)
    # flatten order of the params dict: b, tiny, w
    assert state.labels == ("dense", "dense", "factored")
    ad = adapprox_state(state.inner["factored"])
    factored = [l for l in ad.leaves if isinstance(l, F.FactoredLeaf)]
    assert len(factored) == 1           # only w is factored
    assert any(isinstance(s, AdamWState)
               for s in state.inner["dense"])

    upd, state2 = jax.jit(opt.update)(_grads(params), state, params)
    assert jax.tree.structure(upd) == jax.tree.structure(params)
    for leaf in jax.tree.leaves(upd):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_mixed_groups_matches_per_family_chains():
    """Each group's update is bit-identical to running its family's chain
    alone on the leaves it owns."""
    params = _params()
    grads = _grads(params)
    mixed = build_optimizer(OptimizerConfig(
        name="adapprox", groups=default_mixed_groups(), **BASE))
    u_mix, _ = mixed.update(grads, mixed.init(params), params)

    adam = build_optimizer(OptimizerConfig(name="adamw", **BASE))
    u_adam, _ = adam.update(grads, adam.init(params), params)
    ada = build_optimizer(OptimizerConfig(name="adapprox", **BASE))
    u_ada, _ = ada.update(grads, ada.init(params), params)

    np.testing.assert_array_equal(np.asarray(u_mix["b"]),
                                  np.asarray(u_adam["b"]))
    np.testing.assert_array_equal(np.asarray(u_mix["tiny"]),
                                  np.asarray(u_adam["tiny"]))
    np.testing.assert_array_equal(np.asarray(u_mix["w"]),
                                  np.asarray(u_ada["w"]))


def test_groups_require_catchall():
    cfg = OptimizerConfig(name="adamw", groups=(
        ("m", GroupSpec(select="matrices")),), **BASE)
    with pytest.raises(ValueError, match="catch-all"):
        build_optimizer(cfg)


def test_groups_duplicate_label_rejected():
    cfg = OptimizerConfig(name="adamw", groups=(
        ("g", GroupSpec(select="matrices")),
        ("g", GroupSpec(select="rest"))), **BASE)
    with pytest.raises(ValueError, match="duplicate"):
        build_optimizer(cfg)


# ---------------------------------------------------------------------------
# per-group LR multipliers
# ---------------------------------------------------------------------------

def test_scale_by_schedule_lr_scale():
    """The labeled schedule stage: same schedule shape, scaled peak."""
    base = scale_by_schedule(lambda t: 2.0 * t, lr_scale=1.0)
    hot = scale_by_schedule(lambda t: 2.0 * t, lr_scale=0.25)
    u = {"x": jnp.ones((3,))}
    s0 = CountState(count=jnp.zeros((), jnp.int32))
    ub, _ = base.update(u, s0, None)
    uh, _ = hot.update(u, s0, None)
    np.testing.assert_allclose(np.asarray(uh["x"]),
                               0.25 * np.asarray(ub["x"]), rtol=1e-7)


def test_group_lr_scale_scales_only_that_group():
    """OptimizerConfig.groups[label].lr_scale multiplies that group's
    update and leaves the others untouched (exactly)."""
    params = _params()
    grads = _grads(params)
    plain = build_optimizer(OptimizerConfig(name="adamw", **BASE))
    u0, _ = plain.update(grads, plain.init(params), params)

    scaled = build_optimizer(OptimizerConfig(name="adamw", groups=(
        ("mat", GroupSpec(select="matrices", lr_scale=0.5)),
        ("rest", GroupSpec(select="rest"))), **BASE))
    u1, _ = scaled.update(grads, scaled.init(params), params)

    np.testing.assert_allclose(np.asarray(u1["w"]),
                               0.5 * np.asarray(u0["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(u1["tiny"]),
                               0.5 * np.asarray(u0["tiny"]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(u1["b"]), np.asarray(u0["b"]))


def test_lr_scale_one_is_bit_exact():
    params = _params()
    grads = _grads(params)
    plain = build_optimizer(OptimizerConfig(name="adamw", **BASE))
    grouped = build_optimizer(OptimizerConfig(name="adamw", groups=(
        ("all", GroupSpec(select="rest", lr_scale=1.0)),), **BASE))
    u0, _ = plain.update(grads, plain.init(params), params)
    u1, _ = grouped.update(grads, grouped.init(params), params)
    for a, b in zip(jax.tree.leaves(u0), jax.tree.leaves(u1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# preemption handler chaining
# ---------------------------------------------------------------------------

def test_preemption_handler_chains_and_restores(tmp_path):
    """install_preemption_handler must run a previously-installed handler
    after the flush (elastic-restart teardown composes with it) and put
    the original handlers back afterwards."""
    from repro.checkpoint import CheckpointConfig, CheckpointManager

    calls = []

    def prior(signum, frame):
        calls.append(("prior", signum))

    old = signal.signal(signal.SIGTERM, prior)
    try:
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                                 async_save=False))
        tree = {"x": jnp.arange(4.0)}
        mgr.install_preemption_handler(lambda: (tree, 7))
        signal.raise_signal(signal.SIGTERM)     # delivered synchronously

        assert calls == [("prior", signal.SIGTERM)]     # chained
        assert mgr.latest_step() == 7                   # flushed first
        # originals restored after the flush
        assert signal.getsignal(signal.SIGTERM) is prior
    finally:
        signal.signal(signal.SIGTERM, old)


def test_preemption_uninstall_restores(tmp_path):
    from repro.checkpoint import CheckpointConfig, CheckpointManager

    def prior(signum, frame):
        pass

    old_term = signal.signal(signal.SIGTERM, prior)
    old_int = signal.getsignal(signal.SIGINT)
    try:
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        mgr.install_preemption_handler(lambda: ({}, 0))
        assert signal.getsignal(signal.SIGTERM) is not prior
        mgr.uninstall_preemption_handler()
        assert signal.getsignal(signal.SIGTERM) is prior
        assert signal.getsignal(signal.SIGINT) == old_int
        mgr.uninstall_preemption_handler()      # idempotent
    finally:
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)


# ---------------------------------------------------------------------------
# sharded memory accounting (spec-only, no devices needed)
# ---------------------------------------------------------------------------

def test_bench_memory_per_device_shrinks():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.bench_memory import sharded_rows

    rows = [r for r in sharded_rows("gpt2-117m") if
            r["method"] == "mixed_groups"]
    sizes = [r["opt_state_bytes_per_device"] for r in rows]
    assert sizes == sorted(sizes, reverse=True) and sizes[0] > sizes[-1]
    for r in rows:
        g = r["group_bytes_per_device"]
        assert set(g) == {"dense", "factored"}
        assert g["dense"] > 0 and g["factored"] > 0
        # per-group split adds up to the per-device total
        assert g["dense"] + g["factored"] == r["opt_state_bytes_per_device"]
    # the per-group figures are per-device too: they shrink with the mesh
    dense = [r["group_bytes_per_device"]["dense"] for r in rows]
    assert dense == sorted(dense, reverse=True) and dense[0] > dense[-1]


def test_checkpoint_manifest_records_specs(tmp_path):
    """Sharded-v2 manifests carry per-leaf spec metadata (replicated here:
    single device -> spec is recorded for jax arrays, None for host)."""
    import json
    from repro.checkpoint import serialization as SER

    tree = {"a": jnp.ones((4, 4)), "b": np.ones((2,))}
    path = SER.save_pytree(tree, tmp_path, step=3)
    manifest = json.loads((path / "manifest.json").read_text())
    assert manifest["format"] == "sharded-v2"
    assert len(manifest["leaves"]) == 2
    assert all("spec" in l for l in manifest["leaves"])
    restored = SER.restore_pytree(path, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
