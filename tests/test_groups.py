"""OptimizerConfig.groups lowering, per-group LR multipliers, preemption
handler chaining, and the sharded memory accounting (all single-device)."""
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GroupSpec, OptimizerConfig, default_mixed_groups
from repro.core import (CountState, PartitionState, build_optimizer,
                        scale_by_schedule)
from repro.core.adamw import AdamWState
from repro.core.adapprox import AdapproxState, adapprox_state
from repro.core import factored as F
from repro.core.sketch import SketchLeaf, sketch_state


def _params():
    return {"w": jnp.full((64, 96), 0.5), "b": jnp.full((64,), 0.5),
            "tiny": jnp.full((8, 8), 0.5)}


def _grads(params):
    return jax.tree.map(lambda p: jnp.full_like(p, 0.1), params)


BASE = dict(schedule="constant", lr=1e-3, weight_decay=0.0,
            min_dim_factor=32, k=4, rank_mode="static", implicit=False)


# ---------------------------------------------------------------------------
# groups lowering
# ---------------------------------------------------------------------------

def test_mixed_groups_routes_by_shape():
    """The production default: embedding tables (>= embedding_min_rows
    rows) under the count-min sketch, matrices >= min_dim_factor under
    Adapprox (factored), 1-D and small leaves under dense Adam.  No leaf
    here reaches the default 1024-row threshold, so the embeddings group
    exists but owns nothing."""
    opt = build_optimizer(OptimizerConfig(
        name="adapprox", groups=default_mixed_groups(), **BASE))
    params = _params()
    state = opt.init(params)
    # chain state -> (partition,) is not wrapped: partition IS the top level
    assert isinstance(state, PartitionState)
    # every declared group gets inner state, owned leaves or not
    assert set(state.inner) == {"dense", "embeddings", "factored"}
    # flatten order of the params dict: b, tiny, w
    assert state.labels == ("dense", "dense", "factored")
    ad = adapprox_state(state.inner["factored"])
    factored = [l for l in ad.leaves if isinstance(l, F.FactoredLeaf)]
    assert len(factored) == 1           # only w is factored
    assert any(isinstance(s, AdamWState)
               for s in state.inner["dense"])

    upd, state2 = jax.jit(opt.update)(_grads(params), state, params)
    assert jax.tree.structure(upd) == jax.tree.structure(params)
    for leaf in jax.tree.leaves(upd):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_mixed_groups_matches_per_family_chains():
    """Each group's update is bit-identical to running its family's chain
    alone on the leaves it owns."""
    params = _params()
    grads = _grads(params)
    mixed = build_optimizer(OptimizerConfig(
        name="adapprox", groups=default_mixed_groups(), **BASE))
    u_mix, _ = mixed.update(grads, mixed.init(params), params)

    adam = build_optimizer(OptimizerConfig(name="adamw", **BASE))
    u_adam, _ = adam.update(grads, adam.init(params), params)
    ada = build_optimizer(OptimizerConfig(name="adapprox", **BASE))
    u_ada, _ = ada.update(grads, ada.init(params), params)

    np.testing.assert_array_equal(np.asarray(u_mix["b"]),
                                  np.asarray(u_adam["b"]))
    np.testing.assert_array_equal(np.asarray(u_mix["tiny"]),
                                  np.asarray(u_adam["tiny"]))
    np.testing.assert_array_equal(np.asarray(u_mix["w"]),
                                  np.asarray(u_ada["w"]))


def test_groups_require_catchall():
    cfg = OptimizerConfig(name="adamw", groups=(
        ("m", GroupSpec(select="matrices")),), **BASE)
    with pytest.raises(ValueError, match="catch-all"):
        build_optimizer(cfg)


def test_groups_duplicate_label_rejected():
    cfg = OptimizerConfig(name="adamw", groups=(
        ("g", GroupSpec(select="matrices")),
        ("g", GroupSpec(select="rest"))), **BASE)
    with pytest.raises(ValueError, match="duplicate"):
        build_optimizer(cfg)


def test_embeddings_selector_first_hit_wins():
    """(64, 96) qualifies for BOTH "embeddings" (64 rows >= min_rows=64)
    and "factored" (both dims >= min_dim_factor=32): group ORDER decides
    ownership, exactly like the other selectors."""
    kw = dict(BASE, embedding_min_rows=64)
    emb_first = (
        ("embeddings", GroupSpec(select="embeddings", name="sketch")),
        ("factored", GroupSpec(select="factored")),
        ("dense", GroupSpec(select="rest", name="adamw")))
    fac_first = (
        ("factored", GroupSpec(select="factored")),
        ("embeddings", GroupSpec(select="embeddings", name="sketch")),
        ("dense", GroupSpec(select="rest", name="adamw")))
    params = _params()
    s1 = build_optimizer(OptimizerConfig(
        name="adapprox", groups=emb_first, **kw)).init(params)
    s2 = build_optimizer(OptimizerConfig(
        name="adapprox", groups=fac_first, **kw)).init(params)
    # flatten order b, tiny, w
    assert s1.labels == ("dense", "dense", "embeddings")
    assert s2.labels == ("dense", "dense", "factored")
    st = sketch_state(s1.inner["embeddings"])
    assert sum(isinstance(l, SketchLeaf) for l in st.leaves) == 1


def test_mixed_groups_sketch_matches_standalone():
    """A sketched leaf's grouped update is bit-identical to the standalone
    sketch chain on the same leaf (the group sees only its own leaves, so
    leaf positions — and with them the hash seeds — line up)."""
    kw = dict(BASE, embedding_min_rows=64, sketch_width=128, sketch_depth=2)
    params = _params()
    grads = _grads(params)
    mixed = build_optimizer(OptimizerConfig(
        name="adapprox", groups=default_mixed_groups(), **kw))
    u_mix, _ = mixed.update(grads, mixed.init(params), params)

    solo = build_optimizer(OptimizerConfig(name="sketch", **kw))
    sub_p = {"w": params["w"]}
    sub_g = {"w": grads["w"]}
    u_solo, _ = solo.update(sub_g, solo.init(sub_p), sub_p)
    np.testing.assert_array_equal(np.asarray(u_mix["w"]),
                                  np.asarray(u_solo["w"]))


# ---------------------------------------------------------------------------
# per-group LR multipliers
# ---------------------------------------------------------------------------

def test_scale_by_schedule_lr_scale():
    """The labeled schedule stage: same schedule shape, scaled peak."""
    base = scale_by_schedule(lambda t: 2.0 * t, lr_scale=1.0)
    hot = scale_by_schedule(lambda t: 2.0 * t, lr_scale=0.25)
    u = {"x": jnp.ones((3,))}
    s0 = CountState(count=jnp.zeros((), jnp.int32))
    ub, _ = base.update(u, s0, None)
    uh, _ = hot.update(u, s0, None)
    np.testing.assert_allclose(np.asarray(uh["x"]),
                               0.25 * np.asarray(ub["x"]), rtol=1e-7)


def test_group_lr_scale_scales_only_that_group():
    """OptimizerConfig.groups[label].lr_scale multiplies that group's
    update and leaves the others untouched (exactly)."""
    params = _params()
    grads = _grads(params)
    plain = build_optimizer(OptimizerConfig(name="adamw", **BASE))
    u0, _ = plain.update(grads, plain.init(params), params)

    scaled = build_optimizer(OptimizerConfig(name="adamw", groups=(
        ("mat", GroupSpec(select="matrices", lr_scale=0.5)),
        ("rest", GroupSpec(select="rest"))), **BASE))
    u1, _ = scaled.update(grads, scaled.init(params), params)

    np.testing.assert_allclose(np.asarray(u1["w"]),
                               0.5 * np.asarray(u0["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(u1["tiny"]),
                               0.5 * np.asarray(u0["tiny"]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(u1["b"]), np.asarray(u0["b"]))


def test_lr_scale_per_family_three_groups():
    """lr_scale applies per group across all three state families: the
    sketch and factored groups scale independently, dense is untouched."""
    kw = dict(BASE, embedding_min_rows=64)

    def groups(emb_scale, fac_scale):
        return (
            ("embeddings", GroupSpec(select="embeddings", name="sketch",
                                     lr_scale=emb_scale)),
            ("factored", GroupSpec(select="factored", lr_scale=fac_scale)),
            ("dense", GroupSpec(select="rest", name="adamw")))

    # w (64, 96) -> embeddings; fm (48, 96) -> factored; b, tiny -> dense
    params = dict(_params(), fm=jnp.full((48, 96), 0.5))
    grads = _grads(params)
    base = build_optimizer(OptimizerConfig(
        name="adapprox", groups=groups(1.0, 1.0), **kw))
    u0, _ = base.update(grads, base.init(params), params)
    scaled = build_optimizer(OptimizerConfig(
        name="adapprox", groups=groups(0.5, 0.25), **kw))
    u1, _ = scaled.update(grads, scaled.init(params), params)

    np.testing.assert_allclose(np.asarray(u1["w"]),
                               0.5 * np.asarray(u0["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(u1["fm"]),
                               0.25 * np.asarray(u0["fm"]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(u1["b"]), np.asarray(u0["b"]))
    np.testing.assert_array_equal(np.asarray(u1["tiny"]),
                                  np.asarray(u0["tiny"]))


def test_lr_scale_one_is_bit_exact():
    params = _params()
    grads = _grads(params)
    plain = build_optimizer(OptimizerConfig(name="adamw", **BASE))
    grouped = build_optimizer(OptimizerConfig(name="adamw", groups=(
        ("all", GroupSpec(select="rest", lr_scale=1.0)),), **BASE))
    u0, _ = plain.update(grads, plain.init(params), params)
    u1, _ = grouped.update(grads, grouped.init(params), params)
    for a, b in zip(jax.tree.leaves(u0), jax.tree.leaves(u1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# preemption handler chaining
# ---------------------------------------------------------------------------

def test_preemption_handler_chains_and_restores(tmp_path):
    """install_preemption_handler must run a previously-installed handler
    after the flush (elastic-restart teardown composes with it) and put
    the original handlers back afterwards."""
    from repro.checkpoint import CheckpointConfig, CheckpointManager

    calls = []

    def prior(signum, frame):
        calls.append(("prior", signum))

    old = signal.signal(signal.SIGTERM, prior)
    try:
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                                 async_save=False))
        tree = {"x": jnp.arange(4.0)}
        mgr.install_preemption_handler(lambda: (tree, 7))
        signal.raise_signal(signal.SIGTERM)     # delivered synchronously

        assert calls == [("prior", signal.SIGTERM)]     # chained
        assert mgr.latest_step() == 7                   # flushed first
        # originals restored after the flush
        assert signal.getsignal(signal.SIGTERM) is prior
    finally:
        signal.signal(signal.SIGTERM, old)


def test_preemption_uninstall_restores(tmp_path):
    from repro.checkpoint import CheckpointConfig, CheckpointManager

    def prior(signum, frame):
        pass

    old_term = signal.signal(signal.SIGTERM, prior)
    old_int = signal.getsignal(signal.SIGINT)
    try:
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        mgr.install_preemption_handler(lambda: ({}, 0))
        assert signal.getsignal(signal.SIGTERM) is not prior
        mgr.uninstall_preemption_handler()
        assert signal.getsignal(signal.SIGTERM) is prior
        assert signal.getsignal(signal.SIGINT) == old_int
        mgr.uninstall_preemption_handler()      # idempotent
    finally:
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)


# ---------------------------------------------------------------------------
# sharded memory accounting (spec-only, no devices needed)
# ---------------------------------------------------------------------------

def test_bench_memory_per_device_shrinks():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.bench_memory import sharded_rows

    rows = [r for r in sharded_rows("gpt2-117m") if
            r["method"] == "mixed_groups"]
    sizes = [r["opt_state_bytes_per_device"] for r in rows]
    assert sizes == sorted(sizes, reverse=True) and sizes[0] > sizes[-1]
    for r in rows:
        g = r["group_bytes_per_device"]
        assert set(g) == {"dense", "embeddings", "factored"}
        # gpt2's wte/wpe clear the 1024-row threshold -> sketched
        assert g["dense"] > 0 and g["factored"] > 0 and g["embeddings"] > 0
        # per-group split adds up to the per-device total
        assert sum(g.values()) == r["opt_state_bytes_per_device"]
    # the per-group figures are per-device too: they shrink with the mesh
    dense = [r["group_bytes_per_device"]["dense"] for r in rows]
    assert dense == sorted(dense, reverse=True) and dense[0] > dense[-1]


def test_checkpoint_manifest_records_specs(tmp_path):
    """Sharded-v2 manifests carry per-leaf spec metadata (replicated here:
    single device -> spec is recorded for jax arrays, None for host)."""
    import json
    from repro.checkpoint import serialization as SER

    tree = {"a": jnp.ones((4, 4)), "b": np.ones((2,))}
    path = SER.save_pytree(tree, tmp_path, step=3)
    manifest = json.loads((path / "manifest.json").read_text())
    assert manifest["format"] == "sharded-v2"
    assert len(manifest["leaves"]) == 2
    assert all("spec" in l for l in manifest["leaves"])
    restored = SER.restore_pytree(path, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
