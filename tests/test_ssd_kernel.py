"""SSD intra-chunk Pallas kernel vs the jnp ssd_chunked oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2 as M
from repro.kernels.ssd_chunk import ssd_chunk_pallas


def run_reference(xs, b, c, dt, a, chunk):
    y, hf = M.ssd_chunked(xs, b, c, dt, a, chunk)
    return y


def run_kernel(xs, b, c, dt, a, d_skip, chunk):
    """Drive the kernel the way a fused mamba block would: jnp computes
    cumsums + the (cheap, sequential) inter-chunk state scan; the kernel
    fuses everything per-chunk."""
    bt, s, h, p = xs.shape
    n = b.shape[-1]
    nc = s // chunk

    r = lambda t, tail: t.reshape((bt, nc, chunk) + tail)
    xs_c, b_c, c_c = r(xs, (h, p)), r(b, (h, n)), r(c, (h, n))
    dt_c = r(dt, (h,))
    da_c = dt_c * a[None, None, None, :]
    cums = jnp.cumsum(da_c, axis=2)                       # (bt, nc, q, h)

    # inter-chunk recurrence (same as models/mamba2.py)
    bx = b_c * dt_c[..., None]
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)
    s_chunk = jnp.einsum("zcqh,zcqhn,zcqhp->zchnp", decay_to_end,
                         bx.astype(jnp.float32), xs_c.astype(jnp.float32))
    chunk_decay = jnp.exp(cums[:, :, -1, :])

    def scan_body(hstate, inp):
        s_c, dec = inp
        out = hstate
        hstate = hstate * dec[:, :, None, None] + s_c
        return hstate, out

    s_seq = jnp.moveaxis(s_chunk, 1, 0)
    d_seq = jnp.moveaxis(chunk_decay, 1, 0)
    h0 = jnp.zeros((bt, h, n, p), jnp.float32)
    _, h_in = jax.lax.scan(scan_body, h0, (s_seq, d_seq))
    h_in = jnp.moveaxis(h_in, 0, 1)                       # (bt, nc, h, n, p)

    # flatten (bt, nc) -> BC and heads to axis 1 for the kernel
    def fold(t, tail):
        t = jnp.moveaxis(t, 3, 2) if t.ndim == 5 else t   # not used
        return t

    xk = jnp.moveaxis(xs_c, 3, 2).reshape(bt * nc, h, chunk, p)
    bk = jnp.moveaxis(b_c, 3, 2).reshape(bt * nc, h, chunk, n)
    ck = jnp.moveaxis(c_c, 3, 2).reshape(bt * nc, h, chunk, n)
    dtk = jnp.moveaxis(dt_c, 3, 2).reshape(bt * nc, h, chunk)
    cumk = jnp.moveaxis(cums, 3, 2).reshape(bt * nc, h, chunk)
    hk = h_in.reshape(bt * nc, h, n, p)

    y = ssd_chunk_pallas(xk, bk, ck, dtk, cumk, hk, d_skip, interpret=True)
    y = y.reshape(bt, nc, h, chunk, p)
    return jnp.moveaxis(y, 2, 3).reshape(bt, s, h, p)


@pytest.mark.parametrize("chunk", [8, 16])
def test_ssd_kernel_matches_oracle(chunk):
    bt, s, h, p, n = 2, 32, 4, 8, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    xs = jax.random.normal(ks[0], (bt, s, h, p))
    b = jax.random.normal(ks[1], (bt, s, h, n)) * 0.5
    c = jax.random.normal(ks[2], (bt, s, h, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (bt, s, h)))
    a = -jnp.exp(jnp.linspace(-1.0, 1.0, h))
    d_skip = jnp.zeros((h,), jnp.float32)    # oracle's y excludes the skip

    got = run_kernel(xs, b, c, dt, a, d_skip, chunk)
    want = run_reference(xs, b, c, dt, a, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_with_skip_connection():
    bt, s, h, p, n = 1, 16, 2, 8, 8
    key = jax.random.PRNGKey(5)
    xs = jax.random.normal(key, (bt, s, h, p))
    b = jax.random.normal(jax.random.fold_in(key, 1), (bt, s, h, n))
    c = jax.random.normal(jax.random.fold_in(key, 2), (bt, s, h, n))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                           (bt, s, h)))
    a = -jnp.ones((h,))
    d_skip = jnp.asarray([0.5, 2.0])
    got = run_kernel(xs, b, c, dt, a, d_skip, 8)
    base = run_kernel(xs, b, c, dt, a, jnp.zeros((h,)), 8)
    np.testing.assert_allclose(
        np.asarray(got - base),
        np.asarray(d_skip[None, None, :, None] * xs),
        rtol=1e-4, atol=1e-5)
