"""Optimizer step wall-time comparison (jitted): per-step cost of the
update itself — AdamW vs Adafactor vs CAME vs Adapprox, including the
amortized-refresh configs (refresh_every / warm_start / bucketed) whose
trajectory this file tracks per PR via ``BENCH_step_time.json``.

The parameter set is a GPT-2-shaped transformer stack (scan-stacked
attention + MLP projections, ~117M-proportioned widths, layer count scaled
down so the CPU CI smoke run stays cheap) plus 1-D bias/norm leaves, so
bucketing and the dense fallback are both exercised.

Measurement protocol: one compile step, then ``reps`` timed steps (reps is
a multiple of refresh_every for every config here, so amortized configs are
charged their full share of refresh steps).

CLI:  python benchmarks/bench_step_time.py [--quick] [--out PATH.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.core import apply_updates, build_optimizer

# GPT-2-ish block stack: (L, d, *) scan-stacked projections.  full = bench
# fidelity (768-wide, 4 layers); quick = CI smoke (256-wide, 2 layers).
STACKS = {
    "full": {
        "qkv": (4, 768, 2304),
        "attn_out": (4, 768, 768),
        "mlp_in": (4, 768, 3072),
        "mlp_out": (4, 3072, 768),
        "ln_g": (4, 768),
        "ln_b": (4, 768),
    },
    "quick": {
        "qkv": (2, 256, 768),
        "attn_out": (2, 256, 256),
        "mlp_in": (2, 256, 1024),
        "mlp_out": (2, 1024, 256),
        "ln_g": (2, 256),
        "ln_b": (2, 256),
    },
}

# (case name, optimizer family, OptimizerConfig overrides).  The first
# adapprox entry is the PR-1 default config — the baseline the amortized
# configs are measured against.
CASES = [
    ("adamw", "adamw", {}),
    ("adafactor", "adafactor", {"b1": 0.9}),
    ("came", "came", {}),
    ("adapprox_default", "adapprox", {}),
    ("adapprox_bucketed", "adapprox", {"bucketed": True}),
    ("adapprox_warm1", "adapprox",
     {"warm_start": True, "n_iter_warm": 1}),
    ("adapprox_refresh5_warm1", "adapprox",
     {"refresh_every": 5, "warm_start": True, "n_iter_warm": 1}),
    ("adapprox_refresh5_warm1_bucketed", "adapprox",
     {"refresh_every": 5, "warm_start": True, "n_iter_warm": 1,
      "bucketed": True}),
]


def make_params(stack: str):
    key = jax.random.PRNGKey(0)
    return {name: jax.random.normal(jax.random.fold_in(key, i), shape) * 0.02
            for i, (name, shape) in enumerate(STACKS[stack].items())}


def time_opt(family: str, overrides: dict, stack: str, reps: int,
             min_dim_factor: int) -> float:
    """ms per optimizer step, jitted, averaged over ``reps`` post-compile
    steps."""
    params = make_params(stack)
    opt = build_optimizer(OptimizerConfig(
        name=family, schedule="constant", lr=1e-3, weight_decay=0.0,
        min_dim_factor=min_dim_factor, **overrides))
    state = opt.init(params)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape), params)

    @jax.jit
    def step(g, s, p):
        upd, s = opt.update(g, s, p)
        return apply_updates(p, upd), s

    params2, state = step(grads, state, params)   # compile (= step 1)
    jax.block_until_ready(params2)
    t0 = time.perf_counter()
    for _ in range(reps):
        params2, state = step(grads, state, params2)
    jax.block_until_ready(params2)
    return (time.perf_counter() - t0) / reps * 1e3


def collect(quick: bool = False) -> dict:
    stack = "quick" if quick else "full"
    reps = 5 if quick else 10          # multiple of refresh_every=5
    min_dim_factor = 128
    results = []
    for name, family, overrides in CASES:
        ms = time_opt(family, overrides, stack, reps, min_dim_factor)
        results.append({"name": name, "optimizer": family,
                        "config": overrides, "ms_per_step": round(ms, 3)})
    by_name = {r["name"]: r["ms_per_step"] for r in results}
    base = by_name["adapprox_default"]
    derived = {
        f"speedup_{n}_vs_adapprox_default": round(base / by_name[n], 2)
        for n in by_name if n.startswith("adapprox_") and
        n != "adapprox_default"
    }
    return {
        "benchmark": "optimizer_step_time",
        "stack": stack,
        "shapes": {k: list(v) for k, v in STACKS[stack].items()},
        "backend": jax.default_backend(),
        "reps": reps,
        "results": results,
        "derived": derived,
    }


def run() -> list[str]:
    """benchmarks.run harness entry point: CSV rows."""
    data = collect(quick=False)
    rows = ["steptime_optimizer,ms_per_step"]
    rows += [f"{r['name']},{r['ms_per_step']:.1f}" for r in data["results"]]
    rows += [f"{k},{v}" for k, v in data["derived"].items()]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small stack + fewer reps (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="write machine-readable JSON here")
    args = ap.parse_args()
    data = collect(quick=args.quick)
    for r in data["results"]:
        print(f"{r['name']},{r['ms_per_step']:.1f}ms")
    for k, v in data["derived"].items():
        print(f"{k},{v}x")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(data, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
